"""Benchmark aggregator: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only <name>]``
Prints ``name,us_per_call,derived`` CSV rows (stdout) per benchmark.

Side-effect files with stable schemas, tracked across PRs:
  * BENCH_probe.json — three-way host/device/plane probe comparison
    (bench_pruning) + e2e probe modes (bench_e2e);
  * BENCH_e2e.json   — schema_version, per-mode wall ms, launches/path,
    host<->device bytes (bench_e2e).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="partition|migration|cache|plan|pruning|e2e|"
                         "chaos|mesh")
    args = ap.parse_args()

    from benchmarks import (bench_cache, bench_chaos, bench_e2e,
                            bench_mesh, bench_migration, bench_partition,
                            bench_plan, bench_pruning)
    from benchmarks.common import emit

    suites = {
        "partition": bench_partition.run,
        "migration": bench_migration.run,
        "cache": bench_cache.run,
        "plan": bench_plan.run,
        "pruning": bench_pruning.run,
        "e2e": bench_e2e.run,
        "chaos": bench_chaos.run,
        "mesh": bench_mesh.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        t0 = time.time()
        try:
            emit(fn())
            print(f"# {name}: {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/SUITE_FAILED,0,{type(e).__name__}:{e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
