"""Paper Table: multi-GPU collaborative caching vs LRU/LFU (§5).

Claims checked: value-aware caching beats LRU by 15-20% hit rate on
skewed+polluted workloads; the two-level access priority cuts cross-node
accesses; AW-ResNet incremental training with rollback stays stable.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_engine, emit
from repro.cache.policy import LFUCache, LRUCache, ValueCache
from repro.data.synthetic import make_workload


def _replay(cache, stream, values) -> float:
    for k in stream:
        k = int(k)
        if cache.get(k) is None:
            cache.put(k, k, value=float(values[k]), avg_deg=1.0,
                      hit_rate=getattr(cache, "hit_rate", 0.5),
                      latency_ms=30.0)
    return cache.hit_rate


def run() -> list[tuple]:
    rows = []
    # synthetic skewed access trace with scan pollution
    rng = np.random.default_rng(0)
    n_keys, cap, n_access = 500, 50, 6000
    hot = rng.zipf(1.4, n_access) % 60
    scan = np.arange(n_access) % n_keys
    stream = np.where(rng.random(n_access) < 0.55, hot, scan)
    freq = np.bincount(stream.astype(int), minlength=n_keys).astype(float)
    # V(p) is a [0,1]-normalized fused score in the system (AW-ResNet over
    # normalized features); log-compress raw counts to match that regime.
    freq = np.log1p(freq) / np.log1p(freq.max())
    hr_v = _replay(ValueCache(cap), stream, freq)
    hr_l = _replay(LRUCache(cap), stream, freq)
    hr_f = _replay(LFUCache(cap), stream, freq)
    rows.append(("cache/hit_rate_vs_baselines", 0.0,
                 f"value={hr_v:.3f};lru={hr_l:.3f};lfu={hr_f:.3f};"
                 f"vs_lru=+{(hr_v - hr_l) * 100:.1f}pp"))

    # end-to-end engine: cache on vs off latency + hit rate
    g, eng = bench_engine(n_machines=3, spm=3, n_vertices=600, seed=2)
    qs = make_workload(g, 20, seed=2, hot_fraction=0.7, n_hot=3)
    eng.use_cache = False
    lat_off = sum(eng.query(q)[1].latency_ms for q in qs)
    eng.use_cache = True
    lat_on = sum(eng.query(q)[1].latency_ms for q in qs)
    rows.append(("cache/e2e_latency", 0.0,
                 f"off_ms={lat_off:.0f};on_ms={lat_on:.0f};"
                 f"speedup={lat_off / max(lat_on, 1e-9):.2f}x;"
                 f"hit_rate={eng.cache.hit_rate:.3f}"))

    # two-level priority: fraction of hits served without a cross-node hop
    local = eng.cache.master.hits
    total = eng.cache.total_accesses
    cross = eng.cache.cross_node_accesses
    rows.append(("cache/access_priority", 0.0,
                 f"master_hits={local};accesses={total};"
                 f"cross_node_frac={cross / max(total, 1):.2f}"))

    # AW-ResNet stability
    if eng.aw is not None:
        rows.append(("cache/awresnet", 0.0,
                     f"updates={eng.aw.n_updates};"
                     f"rollbacks={eng.aw.n_rollbacks}"))
    return rows


if __name__ == "__main__":
    emit(run())
