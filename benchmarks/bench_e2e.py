"""Paper Table: end-to-end distributed query latency + throughput (§7).

Compares the full system (all three innovations) against: (a) the
networkx VF2 baseline (classical backtracking), (b) the engine with
pruning disabled at the plan level (natural order, no cache), and (c)
the same engine with the batched device probe (`device_probe=True`).
The paper's headline is 1-2 orders of magnitude vs baselines; here the
same direction is measured wall-clock on CPU at laptop scale.  The
host-vs-device end-to-end numbers are merged into BENCH_probe.json.
"""

from __future__ import annotations

import json
import time

from benchmarks.common import bench_engine, emit
from repro.data.synthetic import make_workload
from tests.conftest import vf2_oracle


def run() -> list[tuple]:
    g, eng = bench_engine(n_machines=4, spm=4, n_vertices=800, seed=5)
    qs = make_workload(g, 10, seed=5, hot_fraction=0.5)
    rows = []

    t0 = time.perf_counter()
    n_match = 0
    for q in qs:
        m, _ = eng.query(q)
        n_match += len(m)
    t_sys = time.perf_counter() - t0

    t0 = time.perf_counter()
    n_vf2 = sum(len(vf2_oracle(g, q)) for q in qs)
    t_vf2 = time.perf_counter() - t0
    assert n_match == n_vf2, "exactness violated in benchmark"

    eng.use_cache = False
    t0 = time.perf_counter()
    for q in qs:
        eng.query(q, plan_mode="natural")
    t_plain = time.perf_counter() - t0

    # host vs batched device probe, end to end (cache off so every query
    # exercises the probe path); counts must agree bit for bit
    t0 = time.perf_counter()
    n_host = sum(len(eng.query(q, device_probe=False)[0]) for q in qs)
    t_host = time.perf_counter() - t0
    t0 = time.perf_counter()
    n_dev = sum(len(eng.query(q, device_probe=True)[0]) for q in qs)
    t_dev = time.perf_counter() - t0
    assert n_host == n_dev == n_vf2, "device probe exactness violated"
    eng.use_cache = True
    try:
        with open("BENCH_probe.json") as f:
            merged = json.load(f)
    except (OSError, json.JSONDecodeError):
        merged = {}
    merged["e2e"] = {"host_s": round(t_host, 4),
                     "device_s": round(t_dev, 4),
                     "matches": n_dev, "n_queries": len(qs)}
    with open("BENCH_probe.json", "w") as f:
        json.dump(merged, f, indent=2)
    rows.append(("e2e/probe_host_vs_device", t_dev * 1e6,
                 f"host_s={t_host:.2f};device_s={t_dev:.2f};"
                 f"matches={n_dev}"))

    rows.append(("e2e/latency_10q", t_sys * 1e6,
                 f"system_s={t_sys:.2f};vf2_s={t_vf2:.2f};"
                 f"no_innov_s={t_plain:.2f};matches={n_match};"
                 f"speedup_vs_vf2={t_vf2 / max(t_sys, 1e-9):.1f}x"))
    rows.append(("e2e/throughput", 0.0,
                 f"qps={len(qs) / max(t_sys, 1e-9):.2f};"
                 f"virtual_ms_mean={sum(t.latency_ms for t in eng.run_workload(qs[:3], rebalance=False)) / 3:.1f}"))
    return rows


if __name__ == "__main__":
    emit(run())
