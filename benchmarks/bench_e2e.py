"""Paper Table: end-to-end distributed query latency + throughput (§7).

Compares the full system (all three innovations) against: (a) the
networkx VF2 baseline (classical backtracking), (b) the engine with
pruning disabled at the plan level (natural order, no cache), and (c)
the same engine under all three probe paths (host / per-path device /
resident plane).  The paper's headline is 1-2 orders of magnitude vs
baselines; here the same direction is measured wall-clock on CPU at
laptop scale.  The probe numbers are merged into BENCH_probe.json, and
a STABLE-SCHEMA BENCH_e2e.json (schema_version, per-mode wall ms,
launches/path, host<->device bytes) tracks the perf trajectory across
PRs — `benchmarks/run.py` emits it on every e2e run.
"""

from __future__ import annotations

import json
import time

from benchmarks.common import bench_engine, emit, merge_json
from repro.data.synthetic import make_workload
from tests.conftest import vf2_oracle

E2E_SCHEMA_VERSION = 1
WORKLOAD_SCHEMA_VERSION = 1

# counters that must agree bit for bit between the serial plane path and
# megabatch execution (wall time / launch attribution are mode-specific)
_IDENTical = ("comm_bytes", "cross_shard_rows", "shards_skipped",
              "paths_executed", "paths_skipped", "n_matches", "cache_hits")


def workload_comparison(g=None, eng=None, n_vertices: int = 300,
                        n_machines: int = 3, spm: int = 2,
                        n_queries: int = 24, batch: int = 12,
                        seed: int = 5) -> dict:
    """Serial-plane vs megabatch workload throughput + BENCH_workload.json.

    Asserts (CI smoke contract): bit-identical per-query counters and
    comm bytes, batched launches-per-query < 0.25, and a strictly
    smaller per-query device->host readback than the serial plane path
    (the in-kernel mask filter ships candidates pre-filtered).
    """
    if eng is None:
        g, eng = bench_engine(n_machines=n_machines, spm=spm,
                              n_vertices=n_vertices, seed=seed)
    elif g is None:
        g = eng.graph
    qs = make_workload(g, n_queries, seed=seed, hot_fraction=0.5)
    cache_was = eng.use_cache
    try:
        eng.use_cache = False
        # warm both paths so one-off jit compiles don't skew wall time
        eng.run_workload(qs[:4], probe_mode="plane")
        eng.run_workload(qs, probe_mode="plane", batch_size=batch)

        t0 = time.perf_counter()
        tels_s = eng.run_workload(qs, probe_mode="plane")
        t_serial = time.perf_counter() - t0
        t0 = time.perf_counter()
        tels_b = eng.run_workload(qs, probe_mode="plane",
                                  batch_size=batch)
        t_mega = time.perf_counter() - t0
    finally:
        eng.use_cache = cache_was

    for i, (t_s, t_b) in enumerate(zip(tels_s, tels_b)):
        for f in _IDENTical:
            assert getattr(t_s, f) == getattr(t_b, f), \
                f"megabatch bit-identity violated: query {i} field {f}"

    def _mode(tels, wall_s):
        nq = max(len(tels), 1)
        return {
            "qps": round(len(tels) / max(wall_s, 1e-9), 2),
            "wall_ms_per_query": round(wall_s * 1e3 / nq, 3),
            "launches_per_query": round(
                sum(t.probe_launches for t in tels) / nq, 4),
            "h2d_bytes_per_query": round(
                sum(t.probe_h2d_bytes for t in tels) / nq, 1),
            "d2h_bytes_per_query": round(
                sum(t.probe_d2h_bytes for t in tels) / nq, 1),
        }

    serial, mega = _mode(tels_s, t_serial), _mode(tels_b, t_mega)
    assert mega["launches_per_query"] < 0.25, \
        f"megabatch launch amortization regressed: {mega}"
    assert mega["d2h_bytes_per_query"] < serial["d2h_bytes_per_query"], \
        "megabatch readback is not pre-filtered below the plane path"
    out = {
        "schema_version": WORKLOAD_SCHEMA_VERSION,
        "workload": {"n_queries": len(qs), "n_vertices": g.n_vertices,
                     "n_shards": len(eng.shards), "batch_size": batch,
                     "matches": sum(t.n_matches for t in tels_b)},
        "serial": serial,
        "megabatch": mega,
        "speedup": round(t_serial / max(t_mega, 1e-9), 2),
    }
    with open("BENCH_workload.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


def run() -> list[tuple]:
    g, eng = bench_engine(n_machines=4, spm=4, n_vertices=800, seed=5)
    qs = make_workload(g, 10, seed=5, hot_fraction=0.5)
    rows = []

    t0 = time.perf_counter()
    n_match = 0
    for q in qs:
        m, _ = eng.query(q)
        n_match += len(m)
    t_sys = time.perf_counter() - t0

    t0 = time.perf_counter()
    n_vf2 = sum(len(vf2_oracle(g, q)) for q in qs)
    t_vf2 = time.perf_counter() - t0
    assert n_match == n_vf2, "exactness violated in benchmark"

    eng.use_cache = False
    t0 = time.perf_counter()
    for q in qs:
        eng.query(q, plan_mode="natural")
    t_plain = time.perf_counter() - t0

    # probe paths end to end (cache off so every query exercises the
    # probe; device/plane warmed so jit compiles don't skew wall time);
    # match counts must agree bit for bit across all three
    for q in qs:
        eng.query(q, probe_mode="device")
        eng.query(q, probe_mode="plane")
    modes: dict[str, dict] = {}
    n_by_mode: dict[str, int] = {}
    for mode in ("host", "device", "plane"):
        t0 = time.perf_counter()
        n_m = launches = paths = h2d = d2h = 0
        for q in qs:
            m, tel = eng.query(q, probe_mode=mode)
            n_m += len(m)
            launches += tel.probe_launches
            paths += tel.paths_executed
            h2d += tel.probe_h2d_bytes
            d2h += tel.probe_d2h_bytes
        n_by_mode[mode] = n_m
        modes[mode] = {
            "wall_ms": round((time.perf_counter() - t0) * 1e3, 2),
            "launches_per_path": round(launches / max(paths, 1), 4),
            "h2d_bytes": h2d,
            "d2h_bytes": d2h,
        }
    assert len(set(n_by_mode.values())) == 1 \
        and n_by_mode["host"] == n_vf2, "probe exactness violated"
    eng.use_cache = True

    merge_json("BENCH_probe.json", "e2e",
               {"modes": modes, "matches": n_vf2, "n_queries": len(qs)})
    # stable cross-PR schema: one file, fixed keys, per-mode metrics
    with open("BENCH_e2e.json", "w") as f:
        json.dump({
            "schema_version": E2E_SCHEMA_VERSION,
            "workload": {"n_queries": len(qs), "n_vertices": g.n_vertices,
                         "n_shards": len(eng.shards), "matches": n_vf2},
            "modes": modes,
            "system": {"wall_ms": round(t_sys * 1e3, 2),
                       "vf2_ms": round(t_vf2 * 1e3, 2),
                       "no_innovation_ms": round(t_plain * 1e3, 2)},
        }, f, indent=2)
    rows.append(("e2e/probe_host_vs_device_vs_plane",
                 modes["plane"]["wall_ms"] * 1e3,
                 f"host_ms={modes['host']['wall_ms']};"
                 f"device_ms={modes['device']['wall_ms']};"
                 f"plane_ms={modes['plane']['wall_ms']};"
                 "plane_launches_per_path="
                 f"{modes['plane']['launches_per_path']};"
                 f"matches={n_vf2}"))

    # megabatch workload execution: serial plane vs B=16 fused batches
    # on the same 800-vertex engine (asserts bit-identity + amortized
    # launches internally; emits stable-schema BENCH_workload.json)
    wl = workload_comparison(g=g, eng=eng, n_queries=32, batch=16, seed=5)
    rows.append(("e2e/megabatch_workload",
                 wl["megabatch"]["wall_ms_per_query"] * 1e3,
                 f"serial_qps={wl['serial']['qps']};"
                 f"mega_qps={wl['megabatch']['qps']};"
                 f"speedup={wl['speedup']}x;"
                 f"launches_per_query={wl['megabatch']['launches_per_query']};"
                 f"d2h_per_query={wl['megabatch']['d2h_bytes_per_query']}"))

    rows.append(("e2e/latency_10q", t_sys * 1e6,
                 f"system_s={t_sys:.2f};vf2_s={t_vf2:.2f};"
                 f"no_innov_s={t_plain:.2f};matches={n_match};"
                 f"speedup_vs_vf2={t_vf2 / max(t_sys, 1e-9):.1f}x"))
    rows.append(("e2e/throughput", 0.0,
                 f"qps={len(qs) / max(t_sys, 1e-9):.2f};"
                 f"virtual_ms_mean={sum(t.latency_ms for t in eng.run_workload(qs[:3], rebalance=False)) / 3:.1f}"))
    return rows


if __name__ == "__main__":
    emit(run())
