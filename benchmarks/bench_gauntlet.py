"""Gauntlet benchmark matrix -> BENCH_gauntlet.json (ISSUE 6 part c/d).

Emits a stable-schema JSON matrix future perf PRs must not regress:

  gauntlet.schema_version     int (bump only on layout changes)
  gauntlet.cells[cell]        wall_ms, matches_per_sec, launches_per_query,
                              prune_ratio, n_matches, counters
  gauntlet.plans[family]      ranked (pescore) vs degree vs random plan
                              wall-clock + deterministic virtual latency

`--smoke` runs 2 cells of one topology with ALL THREE oracles asserted
(the CI gate) and fails if the ranked plan's wall-clock regresses >20%
vs the degree baseline (with a small absolute floor so micro-cells don't
flake on timer noise).  The full run covers the standing matrix.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import merge_json
from repro.core.matching import build_shard_index, exact_match
from repro.data.gauntlet import (TOPOLOGY_BUILDERS, CellSpec, Gauntlet,
                                 build_topology, default_matrix)

SCHEMA_VERSION = 1
PLAN_MODES = ("pescore", "degree", "random")
SMOKE_CELLS = (CellSpec("community", "triangle_tail", "dense"),
               CellSpec("community", "star", "free"))
# smoke regression gate: ranked <= 1.2x degree, +20ms absolute slack
PLAN_GATE_RATIO = 1.2
PLAN_GATE_SLACK_MS = 20.0


def _median_wall_ms(fn, n: int = 3) -> float:
    fn()                                     # warm plan/JIT caches
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def bench_cell(gnt: Gauntlet, spec: CellSpec, global_index) -> dict:
    """One cell's perf row; oracle checks live in run_cell/tests."""
    eng = gnt.eng
    query = gnt.make_query(spec)
    matches, tel = eng.query(query, probe_mode="host")
    wall_ms = _median_wall_ms(
        lambda: eng.query(query, probe_mode="host"))
    stats = exact_match(query, gnt.graph, global_index, eng.params,
                        eng.cfg, max_path_length=eng.max_path_length)[1]
    return {
        "n_matches": len(matches),
        "wall_ms": round(wall_ms, 3),
        "matches_per_sec": round(len(matches) / max(wall_ms, 1e-6) * 1e3, 1),
        "launches_per_query": tel.probe_launches,
        "prune_ratio": round(stats.pruning_rate, 4),
        "counters": Gauntlet.counters(tel),
    }


def bench_plans(gnt: Gauntlet, queries) -> dict:
    """Ranked-vs-degree-vs-random wall-clock over one family's queries."""
    eng = gnt.eng
    out = {}
    for mode in PLAN_MODES:
        def run_all(mode=mode):
            for q in queries:
                eng.query(q, plan_mode=mode, probe_mode="host")
        wall = _median_wall_ms(run_all)
        virt = sum(eng.query(q, plan_mode=mode, probe_mode="host")[1]
                   .latency_ms for q in queries)
        comm = sum(eng.query(q, plan_mode=mode, probe_mode="host")[1]
                   .comm_bytes for q in queries)
        out[mode] = {"wall_ms": round(wall, 3),
                     "virtual_ms": round(virt, 3),
                     "comm_bytes": comm}
    return out


def run_matrix(cells, scale: float = 1.0, oracles: bool = False) -> dict:
    """Benchmark the given cells, one engine per topology (engines are
    shared across a topology's cells, matching how tests exercise
    accumulated migration/update state when oracles=True)."""
    report = {"schema_version": SCHEMA_VERSION, "scale": scale,
              "cells": {}, "plans": {}}
    by_topo: dict[str, list[CellSpec]] = {}
    for spec in cells:
        by_topo.setdefault(spec.topology, []).append(spec)
    for tname, specs in by_topo.items():
        graph = build_topology(tname, scale=scale)
        gnt = Gauntlet(graph, seed=0)
        gidx = build_shard_index(graph, gnt.eng.params, gnt.eng.cfg,
                                 max_length=gnt.eng.max_path_length)
        for spec in specs:
            if oracles:
                rep = gnt.run_cell(spec, invariance=False)
                assert rep.ok, f"oracle failed on {spec.name}"
            report["cells"][spec.name] = bench_cell(gnt, spec, gidx)
        dense_qs = [gnt.make_query(s) for s in specs if s.regime == "dense"]
        if dense_qs:
            report["plans"][tname] = bench_plans(gnt, dense_qs)
    return report


def check_plan_gate(report: dict) -> list[str]:
    """Ranked-plan regression gate: >20% slower than degree fails."""
    failures = []
    for family, plans in report["plans"].items():
        pe = plans["pescore"]["wall_ms"]
        dg = plans["degree"]["wall_ms"]
        if pe > dg * PLAN_GATE_RATIO + PLAN_GATE_SLACK_MS:
            failures.append(
                f"{family}: pescore {pe:.1f}ms > "
                f"{PLAN_GATE_RATIO}x degree {dg:.1f}ms + "
                f"{PLAN_GATE_SLACK_MS:.0f}ms slack")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="2-cell CI gate: oracles + plan regression check")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default="BENCH_gauntlet.json")
    args = ap.parse_args(argv)

    if args.smoke:
        cells = list(SMOKE_CELLS)
    else:
        topos = {name: build_topology(name, scale=args.scale)
                 for name in TOPOLOGY_BUILDERS}
        cells = default_matrix(topos)
    report = run_matrix(cells, scale=args.scale, oracles=args.smoke)
    merge_json(args.out, "gauntlet", report)

    for cell, row in report["cells"].items():
        print(f"{cell}: {row['n_matches']} matches, {row['wall_ms']}ms, "
              f"prune={row['prune_ratio']}, "
              f"launches={row['launches_per_query']}")
    for family, plans in report["plans"].items():
        print(f"plans[{family}]: " + "  ".join(
            f"{m}={plans[m]['wall_ms']}ms/{plans[m]['comm_bytes']}B"
            for m in PLAN_MODES))

    failures = check_plan_gate(report)
    for f in failures:
        print(f"PLAN GATE FAIL: {f}", file=sys.stderr)
    print(f"wrote {args.out}" + (" (smoke)" if args.smoke else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
