"""Paper Table: partitioning quality (§4.2.1).

Claims checked: METIS-role partitioner cuts 30-40% fewer cross-shard edges
than random; shard size balance <= 15%; hardware-aware initial allocation
variance < 10%.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.data.synthetic import nws_graph
from repro.dist.cluster import DistributedGNNPE
from repro.dist.partition import (edge_cut, hash_partition,
                                  metis_like_partition, random_partition,
                                  size_balance)


def run() -> list[tuple]:
    g = nws_graph(3000, 6, 0.1, 8, seed=0)
    rows = []
    for parts in (32, 64):
        t0 = time.perf_counter()
        pm = metis_like_partition(g, parts, seed=0)
        dt = (time.perf_counter() - t0) * 1e6
        cm = edge_cut(g, pm)
        cr = edge_cut(g, random_partition(g, parts))
        ch = edge_cut(g, hash_partition(g, parts))
        rows.append((f"partition/metis_like_m{parts}", dt,
                     f"cut={cm};vs_random=-{1 - cm / cr:.1%};"
                     f"vs_hash=-{1 - cm / ch:.1%};"
                     f"balance={size_balance(pm):.1%}"))
    # hardware-aware initial allocation variance (paper: < 10%)
    t0 = time.perf_counter()
    eng = DistributedGNNPE.build(nws_graph(600, 6, 0.1, 6, seed=1), 4,
                                 shards_per_machine=4, gnn_train_steps=5,
                                 seed=1)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("partition/hw_aware_alloc", dt,
                 f"alloc_imbalance={eng.offline_report['alloc_imbalance']:.1%}"
                 f";train_alloc={eng.offline_report['train_alloc']}"))
    return rows


if __name__ == "__main__":
    emit(run())
