"""Paper Table: load balancing + hot migration (§4, Algorithm 1).

Claims checked: sigma-triggered migration lowers cluster load std;
migration is CRC-verified with no aR-tree change (no false negatives);
per-shard overhead stays in the tens-of-ms band (simulated link model).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_engine, emit
from repro.data.synthetic import make_workload
from repro.dist.migration import hot_migrate


def run() -> list[tuple]:
    g, eng = bench_engine(n_machines=4, spm=4, n_vertices=700)
    rows = []

    # skewed workload -> imbalance -> rebalance.  Caching/early-stop are
    # disabled here so probes carry their full cost: this benchmark
    # exercises the BALANCER, and the paper's own overload scenario assumes
    # the un-optimized load profile.
    eng.use_cache = False
    qs = make_workload(g, 40, seed=3, hot_fraction=0.9, n_hot=1,
                       size_range=(5, 7))
    eng.run_workload(qs, rebalance=False)
    s_before = eng.load_sigma()
    eng.run_workload(qs, rebalance=True)
    s_after = eng.load_sigma()
    eng.use_cache = True
    n_moves = sum(len(m.migrated) for m in eng.migrations)
    rows.append(("migration/sigma_reduction", 0.0,
                 f"sigma_before={s_before:.3f};sigma_after={s_after:.3f};"
                 f"moves={n_moves}"))

    # single-shard migration overhead + consistency
    sid = next(iter(eng.shards))
    src = eng.routing[sid]
    before = eng.shards[sid].index.trees[1].serialize()
    t0 = time.perf_counter()
    res = hot_migrate(eng.shards, [(sid, src, (src + 1) % 4)], eng.routing,
                      rng=np.random.default_rng(0))
    dt = (time.perf_counter() - t0) * 1e6
    ok = eng.shards[sid].index.trees[1].serialize() == before
    rows.append(("migration/single_shard", dt,
                 f"virtual_ms={res.virtual_ms:.1f};bytes={res.bytes_moved};"
                 f"index_identical={ok}"))

    # batch (K=5) with fault injection
    sids = list(eng.shards)[:5]
    moves = [(s, eng.routing[s], (eng.routing[s] + 1) % 4) for s in sids]
    res = hot_migrate(eng.shards, moves, eng.routing,
                      rng=np.random.default_rng(1), corrupt_prob=0.3)
    rows.append(("migration/batch_k5_faulty", 0.0,
                 f"virtual_ms={res.virtual_ms:.1f};"
                 f"retransmissions={res.retransmissions};crc_ok={res.crc_ok}"))
    return rows


if __name__ == "__main__":
    emit(run())
