import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Scan-correct roofline calibration for the LM family.

XLA's cost_analysis counts a lax.scan body ONCE, so the main dry-run
under-counts per-step FLOPs/bytes/collectives by ~n_layers for the
scan-over-layers LMs.  This pass lowers small UNROLLED depths and
extrapolates linearly:

  dense-only stacks:      F(L) = nonscan + L*dense_body
      -> lower L in {1, 2}; body = F(2) - F(1)
  mixed stacks (f dense + m moe; deepseek f=3):
      F(L) = nonscan + f*dense + (L-f)*moe for L > f
      -> lower L in {f-1, f, f+1, f+2}: dense = F(f)-F(f-1),
         moe = F(f+1)-F(f)  (and F(f+2) validates linearity)

The corrected totals feed benchmarks/roofline.py via calib_results.json.

  DRYRUN_DEVICES=512 PYTHONPATH=src python -m benchmarks.flops_calib \
      [--out calib_results.json]
"""

import argparse
import dataclasses
import json

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_spec
from repro.dist.sharding import clear_rules, set_mesh, set_rules
from repro.launch.dryrun import _rules_for, collective_bytes
from repro.launch.mesh import dp_axes_of, make_production_mesh

LM_ARCHS = ["yi-6b", "h2o-danube-1.8b", "glm4-9b", "qwen2-moe-a2.7b",
            "deepseek-v3-671b"]


def lower_metrics(arch: str, shape_id: str, n_layers: int,
                  mesh) -> dict[str, float]:
    """Compile an unrolled depth-n_layers variant; return raw metrics."""
    spec = get_spec(arch)
    shape = spec.shapes[shape_id]
    dp = dp_axes_of(mesh)
    set_rules(_rules_for(spec.family, dp))
    set_mesh(mesh)
    try:
        cfg = spec.make_config()
        fd = cfg.moe.first_dense if cfg.moe is not None else 0
        moe = cfg.moe
        if moe is not None and n_layers <= fd:
            # depth below the dense prefix: pure-dense variant
            moe = None if n_layers < fd else moe
        cfg = dataclasses.replace(
            cfg, n_layers=n_layers, unroll=True, mtp=cfg.mtp,
            moe=dataclasses.replace(moe, first_dense=min(fd, n_layers))
            if moe is not None else None)
        cell = spec.build_cell(cfg, shape, dp)
        to_ns = lambda s: jax.tree.map(
            lambda x: NamedSharding(mesh, x) if isinstance(x, P) else x,
            s, is_leaf=lambda x: isinstance(x, P))
        with mesh:
            compiled = jax.jit(
                cell.step_fn, in_shardings=to_ns(cell.in_shardings),
                out_shardings=to_ns(cell.out_shardings),
                donate_argnums=cell.donate_argnums,
            ).lower(*cell.abstract_args).compile()
        cost = compiled.cost_analysis()
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(collective_bytes(compiled.as_text())["total"]),
        }
    finally:
        clear_rules()


def calibrate_cell(arch: str, shape_id: str, mesh) -> dict[str, float]:
    spec = get_spec(arch)
    cfg = spec.make_config()
    l_full = cfg.n_layers
    fd = cfg.moe.first_dense if cfg.moe is not None else 0
    out: dict[str, float] = {}
    if cfg.moe is None or fd == 0:
        f1 = lower_metrics(arch, shape_id, 1, mesh)
        f2 = lower_metrics(arch, shape_id, 2, mesh)
        for k in f1:
            body = f2[k] - f1[k]
            out[k] = f1[k] + (l_full - 1) * body
    else:
        fm1 = lower_metrics(arch, shape_id, fd - 1, mesh)   # dense-only
        f0 = lower_metrics(arch, shape_id, fd, mesh)        # dense-only
        f1 = lower_metrics(arch, shape_id, fd + 1, mesh)    # + 1 moe
        for k in f0:
            dense = f0[k] - fm1[k]
            moe = f1[k] - f0[k]
            nonscan = f0[k] - fd * dense
            out[k] = nonscan + fd * dense + (l_full - fd) * moe
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="calib_results.json")
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False, scale=args.scale)
    results = []
    archs = [args.arch] if args.arch else LM_ARCHS
    for arch in archs:
        spec = get_spec(arch)
        for sid in spec.shapes:
            if sid in spec.skip_shapes:
                continue
            try:
                m = calibrate_cell(arch, sid, mesh)
                rec = {"arch": arch, "shape": sid, "status": "ok", **m}
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": sid, "status": "failed",
                       "error": f"{type(e).__name__}: {e}"}
            print(rec)
            results.append(rec)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
