"""Shared benchmark helpers: timing + the standard engine fixture."""

from __future__ import annotations

import json
import time
from typing import Callable

import numpy as np

from repro.data.synthetic import nws_graph
from repro.dist.cluster import DistributedGNNPE


def merge_json(path: str, key: str, value: dict) -> None:
    """Merge one top-level key into a JSON report file (creates it if
    absent/corrupt) — shared by the BENCH_*.json emitters."""
    try:
        with open(path) as f:
            merged = json.load(f)
    except (OSError, json.JSONDecodeError):
        merged = {}
    merged[key] = value
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)


def timeit(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Median wall microseconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def bench_engine(n_machines: int = 4, spm: int = 4, n_vertices: int = 800,
                 seed: int = 0) -> tuple:
    g = nws_graph(n_vertices, 6, 0.1, 8, seed=seed)
    eng = DistributedGNNPE.build(g, n_machines, shards_per_machine=spm,
                                 gnn_train_steps=25, seed=seed)
    return g, eng


def emit(rows: list[tuple]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
