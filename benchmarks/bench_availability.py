"""Degraded-mode serving benchmark: recovery, degraded latency, availability.

Three CI-gated measurements, emitted into a stable-schema
BENCH_availability.json:

  * **crash -> first correct answer** — after a machine crash, wall time
    to failover plus the first bit-correct post-crash query, comparing
    ``failover_mode="route"`` (reads served from CRC-verified standbys,
    promotion deferred) against ``"promote"`` (PR-8 promote-then-serve)
    and the k=0 legacy byte-image rebuild.  Routed-standby recovery must
    be STRICTLY faster than promote-then-serve: deferral moves the
    serialize+CRC re-sync off the read critical path.
  * **fault-free routing overhead** — the same mixed workload with the
    router resolving every shard access vs the PR-8 promote engine.
    Must stay <= 5% wall-clock: when nothing is degraded, ``resolve``
    is a two-dict lookup and ``read`` returns without virtual cost.
  * **degraded serving quality** — p99 virtual latency of standby-served
    reads after a crash (vs the healthy twin), and availability %% over
    fault schedules: route k=2 must answer EVERY query (<=2 crashes
    always leave a live copy — the tentpole contract, benchmarked),
    every shed query must carry a typed genuine-loss reason, and the
    k=1 route-vs-promote split is reported honestly (promotion eagerly
    re-replicates at each crash; route defers repair to ``recover()``).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import merge_json
from repro.data.synthetic import make_workload, nws_graph
from repro.dist.chaos import (CRASH, HOOK_QUERY, FaultPlan, FaultSpec,
                              Unavailable, default_script,
                              random_fault_plan, run_script,
                              script_queries)
from repro.dist.cluster import DistributedGNNPE

AVAIL_SCHEMA_VERSION = 1
MAX_ROUTE_OVERHEAD = 0.05


def _build(g, base, *, k: int, mode: str, seed: int, spm: int,
           gnn_train_steps: int) -> DistributedGNNPE:
    """A twin of `base` (same assignment + GNN params, so answers and
    counters are bit-comparable) with its own replication/failover."""
    return DistributedGNNPE.build(
        g, base.replicas.n_machines, shards_per_machine=spm,
        gnn_train_steps=gnn_train_steps, seed=seed,
        assignment=base.assignment, params=base.params,
        replication=k, failover_mode=mode)


def recovery(n_vertices: int = 800, n_machines: int = 3, spm: int = 4,
             seed: int = 5, gnn_train_steps: int = 8,
             reps: int = 3) -> dict:
    """Crash -> first bit-correct answer for the three failover paths.

    ``failover_ms`` is `handle_machine_failure` (route mode: mark dead +
    invalidate planes; promote mode: the full promotion + re-sync
    transaction), ``first_answer_ms`` the first post-crash query, which
    must equal the pre-crash answer exactly on every path.  The replica
    paths compare at k=1, where promote-then-serve must serialize+CRC
    re-replicate every promoted shard on the critical path (at k=2 on
    three machines every survivor already holds a copy and the re-sync
    ships nothing, hiding the structural difference).
    """
    g = nws_graph(n_vertices, 6, 0.1, 8, seed=seed)
    base = DistributedGNNPE.build(g, n_machines, shards_per_machine=spm,
                                  gnn_train_steps=gnn_train_steps,
                                  seed=seed)
    q = make_workload(g, 1, seed=seed + 1, hot_fraction=0.0)[0]
    out: dict = {"schema_version": AVAIL_SCHEMA_VERSION,
                 "config": {"n_vertices": n_vertices,
                            "n_machines": n_machines,
                            "shards_per_machine": spm, "reps": reps}}
    for label, k, mode in (("routed_standby", 1, "route"),
                           ("promote_then_serve", 1, "promote"),
                           ("legacy_k0", 0, "promote")):
        fail_ms, first_ms, total_ms = [], [], []
        for _ in range(reps):
            eng = _build(g, base, k=k, mode=mode, seed=seed, spm=spm,
                         gnn_train_steps=gnn_train_steps)
            # the pre-crash answer must not park in the result cache:
            # first_answer_ms has to measure real post-crash serving
            # (standby reads on the routed path), not a cache lookup
            eng.use_cache = False
            want, _ = eng.query(q, probe_mode="host")
            t0 = time.perf_counter()
            victims = eng.handle_machine_failure(1)
            t_fail = time.perf_counter() - t0
            t0 = time.perf_counter()
            got, tel = eng.query(q, probe_mode="host")
            t_first = time.perf_counter() - t0
            assert got == want, f"{label}: post-crash answer diverged"
            assert eng.consistency_audit() == []
            fail_ms.append(t_fail * 1e3)
            first_ms.append(t_first * 1e3)
            total_ms.append((t_fail + t_first) * 1e3)
        out[label] = {
            "victim_shards": len(victims),
            "failover_ms": round(float(np.median(fail_ms)), 3),
            "first_answer_ms": round(float(np.median(first_ms)), 3),
            "recovery_ms": round(float(np.median(total_ms)), 3),
            "promotions": eng.replicas.stats()["promotions"],
            "standby_reads": eng.router.stats()["standby_reads"],
            "bytes_synced": eng.replicas.stats()["bytes_synced"],
        }
    routed = out["routed_standby"]["recovery_ms"]
    promote = out["promote_then_serve"]["recovery_ms"]
    assert routed < promote, (
        f"routed-standby recovery ({routed}ms) must beat "
        f"promote-then-serve ({promote}ms): deferral keeps the "
        "serialize+CRC re-sync off the read critical path")
    merge_json("BENCH_availability.json", "recovery", out)
    return out


def fault_free_overhead(n_vertices: int = 300, n_machines: int = 3,
                        spm: int = 2, n_queries: int = 24,
                        seed: int = 5, gnn_train_steps: int = 8,
                        reps: int = 6) -> dict:
    """Wall-clock cost of router resolution when nothing is degraded.

    Two checks, both against the promote twin (the PR-8 behaviour):

      * **simulated cost** — fault-free comm bytes and read stalls
        must be bit-identical per query: `read` adds 0 simulated ms
        with no chaos plan attached.  (Full `latency_ms` is a hybrid
        metric — it folds in wall `join_ms`/`plan_ms` diagnostics —
        so only its deterministic components can be asserted.)
      * **wall cost** — the added layer is one `router.read` per
        (query, shard); its wall cost is micro-timed directly and
        gated at 5%% of the median query wall.  (Differencing two
        whole-engine wall clocks cannot support a 5%% gate here:
        per-engine allocation luck alone swings +-4%% on this host.)

    The paired per-query wall times of both engines are still
    reported — unasserted — so drift shows up in the JSON history.
    """
    g = nws_graph(n_vertices, 6, 0.1, 8, seed=seed)
    base = DistributedGNNPE.build(g, n_machines, shards_per_machine=spm,
                                  gnn_train_steps=gnn_train_steps,
                                  seed=seed)
    promote = _build(g, base, k=2, mode="promote", seed=seed, spm=spm,
                     gnn_train_steps=gnn_train_steps)
    route = _build(g, base, k=2, mode="route", seed=seed, spm=spm,
                   gnn_train_steps=gnn_train_steps)
    qs = make_workload(g, n_queries, seed=seed, hot_fraction=0.5)
    # result caches off: a cache hit returns before the router runs,
    # which would make the comparison vacuous after the first pass
    promote.use_cache = route.use_cache = False
    # one untimed pass per engine absorbs warm-up effects
    for eng in (promote, route):
        for q in qs:
            eng.query(q, probe_mode="host")

    cells = {promote: np.zeros((len(qs), reps)),
             route: np.zeros((len(qs), reps))}
    m_promote = m_route = 0
    lat_promote: list = []
    lat_route: list = []
    for rep in range(reps):
        for qi, q in enumerate(qs):      # tightest possible pairing:
            order = ((promote, route) if (rep + qi) % 2 == 0
                     else (route, promote))
            for eng in order:            # alternate first slot too
                t0 = time.perf_counter()
                m, tel = eng.query(q, probe_mode="host")
                cells[eng][qi, rep] = time.perf_counter() - t0
                if eng is promote:
                    m_promote += len(m)
                    lat_promote.append((tel.comm_bytes,
                                        tel.outcome.stall_ms))
                else:
                    m_route += len(m)
                    lat_route.append((tel.comm_bytes,
                                      tel.outcome.stall_ms))
    assert m_promote == m_route, \
        f"routing changed answers: {m_promote} vs {m_route}"
    assert lat_promote == lat_route, \
        "routing changed fault-free comm bytes / read stalls"
    wall_promote = float(np.median(cells[promote], axis=1).sum())
    wall_route = float(np.median(cells[route], axis=1).sum())

    # the added layer, micro-timed: one router.read per (query, shard)
    sids = sorted(route.shards)
    n_iters = 2000
    t0 = time.perf_counter()
    for _ in range(n_iters):
        for sid in sids:
            route.router.read(sid)
    read_s_per_query = (time.perf_counter() - t0) / n_iters
    med_query_s = float(np.median(cells[route]))
    overhead = read_s_per_query / max(med_query_s, 1e-9)
    assert overhead <= MAX_ROUTE_OVERHEAD, \
        f"fault-free routing overhead {overhead:.1%} exceeds " \
        f"{MAX_ROUTE_OVERHEAD:.0%}"
    out = {
        "schema_version": AVAIL_SCHEMA_VERSION,
        "config": {"n_vertices": n_vertices, "n_queries": n_queries,
                   "reps": reps},
        "promote_wall_s": round(wall_promote, 3),
        "route_wall_s": round(wall_route, 3),
        "router_us_per_query": round(read_s_per_query * 1e6, 2),
        "overhead_frac": round(overhead, 4),
        "matches": m_route,
    }
    merge_json("BENCH_availability.json", "fault_free_overhead", out)
    return out


def degraded_serving(n_vertices: int = 300, n_machines: int = 3,
                     spm: int = 2, n_queries: int = 24, seed: int = 5,
                     gnn_train_steps: int = 8,
                     n_schedules: int = 6) -> dict:
    """p99 standby-read latency + availability %% over fault schedules.

    Latencies are VIRTUAL ms (deterministic simulated clock), so no
    timing reps are needed.  Availability runs schedules with up to two
    crashes against route k=2 (must answer everything — a live copy
    always exists), and route/promote at k=1 where double crashes can
    genuinely lose a shard's last copy.
    """
    g = nws_graph(n_vertices, 6, 0.1, 8, seed=seed)
    base = DistributedGNNPE.build(g, n_machines, shards_per_machine=spm,
                                  gnn_train_steps=gnn_train_steps,
                                  seed=seed)
    qs = make_workload(g, n_queries, seed=seed, hot_fraction=0.5)

    # -- p99 degraded-read virtual latency vs the healthy twin -------- #
    healthy = _build(g, base, k=2, mode="route", seed=seed, spm=spm,
                     gnn_train_steps=gnn_train_steps)
    degraded = _build(g, base, k=2, mode="route", seed=seed, spm=spm,
                      gnn_train_steps=gnn_train_steps)
    degraded.handle_machine_failure(1)
    lat_healthy, lat_degraded = [], []
    n_deg = 0
    for q in qs:
        _, tel = healthy.query(q, probe_mode="host")
        lat_healthy.append(tel.latency_ms)
        m, tel = degraded.query(q, probe_mode="host")
        lat_degraded.append(tel.latency_ms)
        n_deg += int(tel.outcome.served_degraded)
    assert degraded.replicas.stats()["promotions"] == 0
    p99_h = float(np.percentile(lat_healthy, 99))
    p99_d = float(np.percentile(lat_degraded, 99))

    # -- availability over seeded schedules, route vs promote at k=1 -- #
    ops = default_script(g, seed, n_queries=6)
    n_per = script_queries(ops)
    # double-crash schedules losing a shard's last k=1 copy (primary +
    # its single ring replica), early and late, plus random schedules
    schedules = [
        [FaultSpec(kind=CRASH, hook=HOOK_QUERY, at=1, machine=0),
         FaultSpec(kind=CRASH, hook=HOOK_QUERY, at=2, machine=1)],
        [FaultSpec(kind=CRASH, hook=HOOK_QUERY, at=2, machine=1),
         FaultSpec(kind=CRASH, hook=HOOK_QUERY, at=3, machine=2)],
    ]
    schedules += [random_fault_plan(1000 + s, n_faults=4,
                                    n_machines=n_machines).faults
                  for s in range(n_schedules - len(schedules))]
    configs = (("route_k2", "route", 2), ("route_k1", "route", 1),
               ("promote_k1", "promote", 1))
    answered = {label: 0 for label, _, _ in configs}
    total = n_per * len(schedules)
    for s, faults in enumerate(schedules):
        for label, mode, k in configs:
            eng = _build(g, base, k=k, mode=mode, seed=seed, spm=spm,
                         gnn_train_steps=gnn_train_steps)
            answers, _ = run_script(eng, ops,
                                    FaultPlan(tuple(faults), seed=s),
                                    on_unavailable="continue")
            for a in answers:
                if isinstance(a, Unavailable):
                    # every shed must be a typed genuine quorum loss
                    assert a.reason in ("no-live-copy",
                                        "no-survivors"), a
                else:
                    answered[label] += 1
    avail = {label: answered[label] / total for label, _, _ in configs}
    # the tentpole contract, benchmarked: k=2 keeps a live copy of
    # every shard through any <=2-crash schedule, so routed serving
    # must answer EVERY query (bit-identity is the oracle's job)
    assert avail["route_k2"] == 1.0, (
        f"route k=2 availability {avail['route_k2']:.1%}: a schedule "
        "shed a query while a live copy existed")
    # NOTE promote_k1 can exceed route_k1 under SEQUENTIAL crashes:
    # promotion eagerly re-replicates at each crash, while route mode
    # defers redundancy repair to recover() — that trade is the price
    # of the faster crash->first-answer path above, reported here
    # honestly rather than asserted away.
    out = {
        "schema_version": AVAIL_SCHEMA_VERSION,
        "config": {"n_vertices": n_vertices, "n_queries": n_queries,
                   "n_schedules": len(schedules)},
        "p99_latency_ms_healthy": round(p99_h, 4),
        "p99_latency_ms_degraded": round(p99_d, 4),
        "degraded_reads": n_deg,
        "standby_reads": degraded.router.stats()["standby_reads"],
        "availability": {label: round(v, 4)
                         for label, v in avail.items()},
    }
    merge_json("BENCH_availability.json", "degraded_serving", out)
    return out


def run() -> list[tuple]:
    rec = recovery()
    over = fault_free_overhead()
    deg = degraded_serving()
    return [
        ("availability/recovery_routed_standby",
         rec["routed_standby"]["recovery_ms"] * 1e3,
         f"failover {rec['routed_standby']['failover_ms']}ms + first "
         f"answer {rec['routed_standby']['first_answer_ms']}ms"),
        ("availability/recovery_promote_then_serve",
         rec["promote_then_serve"]["recovery_ms"] * 1e3,
         f"failover {rec['promote_then_serve']['failover_ms']}ms + "
         f"first answer "
         f"{rec['promote_then_serve']['first_answer_ms']}ms"),
        ("availability/recovery_legacy_k0",
         rec["legacy_k0"]["recovery_ms"] * 1e3,
         "byte-image rebuild path"),
        ("availability/route_overhead_frac",
         over["overhead_frac"] * 1e6,
         f"route {over['route_wall_s']}s vs promote "
         f"{over['promote_wall_s']}s fault-free"),
        ("availability/p99_degraded_latency",
         deg["p99_latency_ms_degraded"] * 1e3,
         f"healthy p99 {deg['p99_latency_ms_healthy']}ms, "
         f"{deg['degraded_reads']}/{deg['config']['n_queries']} "
         "standby-served"),
        ("availability/availability_route_k2",
         deg["availability"]["route_k2"] * 1e6,
         f"k=1: route {deg['availability']['route_k1']:.1%} vs "
         f"promote {deg['availability']['promote_k1']:.1%} over "
         f"{deg['config']['n_schedules']} schedules"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
