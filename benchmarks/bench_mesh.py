"""Mesh transport benchmark: seam overhead + bytes-on-wire validation.

Two CI-gated measurements, emitted into a stable-schema BENCH_mesh.json:

  * **loopback overhead** — the same plane-mode workload on a
    SimTransport engine vs a world-1 MeshTransport engine (every
    delivered byte round-trips through the local JAX device).  Answers
    and the logical wire ledger must agree exactly; the wall-clock
    overhead of the seam must stay <= 25%.
  * **census** — the 300-vertex bench through the in-process census
    scenario: the dryrun-side collective-byte prediction
    (``predicted_wire`` over the sim ledger) vs the mesh transport's
    *measured* physical traffic, gated at <= 10% relative error per
    channel (``launch/dryrun.py --validate-census`` runs the same
    comparison, optionally over real process ranks).
"""

from __future__ import annotations

import time

from benchmarks.common import merge_json
from repro.data.synthetic import make_workload
from repro.dist.meshrun import bench_graph, build_pair, run_scenario
from repro.dist.transport import CHANNELS, MeshTransport

MESH_SCHEMA_VERSION = 1
MAX_OVERHEAD_FRAC = 0.25


def loopback_overhead(n_vertices: int = 300, n_queries: int = 12,
                      reps: int = 2) -> dict:
    """Wall-clock cost of metering every byte through the seam's mesh
    delivery path, against the sim oracle on an identical workload."""
    g = bench_graph(n_vertices=n_vertices)
    sim, mesh = build_pair(g, MeshTransport(), probe_mode="plane")
    sim.use_cache = mesh.use_cache = False   # time probes, not lookups
    qs = make_workload(g, n_queries, seed=11, hot_fraction=0.4)
    for q in qs:                          # compile warmup for both
        sim.query(q, probe_mode="plane")
        mesh.query(q, probe_mode="plane")
    t_sim = t_mesh = 0.0
    m_sim = m_mesh = 0
    for _ in range(reps):                 # interleave to balance drift
        t0 = time.perf_counter()
        for q in qs:
            m_sim += sim.query(q, probe_mode="plane")[1].n_matches
        t_sim += time.perf_counter() - t0
        t0 = time.perf_counter()
        for q in qs:
            m_mesh += mesh.query(q, probe_mode="plane")[1].n_matches
        t_mesh += time.perf_counter() - t0
    assert m_sim == m_mesh, \
        f"mesh backend changed answers: {m_sim} vs {m_mesh}"
    assert dict(sim.transport.wire) == dict(mesh.transport.wire), \
        "sim/mesh logical wire ledgers diverged"
    overhead = (t_mesh - t_sim) / max(t_sim, 1e-9)
    assert overhead <= MAX_OVERHEAD_FRAC, \
        f"mesh seam overhead {overhead:.1%} exceeds " \
        f"{MAX_OVERHEAD_FRAC:.0%}"
    out = {
        "config": {"n_vertices": n_vertices, "n_queries": n_queries,
                   "reps": reps},
        "sim_wall_s": round(t_sim, 3),
        "mesh_wall_s": round(t_mesh, 3),
        "overhead_frac": round(overhead, 4),
        "matches": m_sim,
        "wire_bytes": {ch: int(sim.transport.wire[ch])
                       for ch in CHANNELS},
        "measured_bytes": mesh.transport.measured(),
    }
    merge_json("BENCH_mesh.json", "loopback_overhead",
               {"schema_version": MESH_SCHEMA_VERSION, **out})
    return out


def census() -> dict:
    """Predicted vs measured bytes-on-wire (the <=10% dryrun gate)."""
    rec = run_scenario("census")
    assert rec["ledger_identical"], "sim/mesh wire ledgers diverged"
    assert rec["within_10pct"], \
        f"census breach: worst channel error {rec['worst_rel_err']:.1%}"
    out = {"schema_version": MESH_SCHEMA_VERSION,
           "world": rec["world"],
           "channels": rec["channels"],
           "total": rec["total"],
           "worst_rel_err": round(rec["worst_rel_err"], 4),
           "within_10pct": rec["within_10pct"]}
    merge_json("BENCH_mesh.json", "census", out)
    return out


def run() -> list[tuple]:
    over = loopback_overhead()
    cen = census()
    return [
        ("mesh/loopback_overhead_frac", over["overhead_frac"] * 1e6,
         f"wall {over['mesh_wall_s']}s vs {over['sim_wall_s']}s"),
        ("mesh/census_worst_rel_err", cen["worst_rel_err"] * 1e6,
         f"total {cen['total']['measured']}B vs "
         f"{cen['total']['predicted']}B predicted (world="
         f"{cen['world']})"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
