"""Chaos/replication benchmark: overhead of standing replicas + recovery.

Two CI-gated measurements, emitted into a stable-schema BENCH_chaos.json:

  * **replication overhead** — the same mixed workload (megabatch query
    epochs + streaming updates, the only paths replica sync rides on)
    on the 300-vertex e2e bench config with k=0 vs k=2 standby
    replicas.  Fault-free overhead must stay <= 15% wall-clock, and the
    two engines' match counts must agree exactly (replica sync consumes
    no engine rng, so the runs are bit-comparable).
  * **recovery time** — after a machine crash, time-to-failover (the
    transaction that re-homes every victim shard) and
    time-to-first-correct-answer, with the k=1 promotion path compared
    against the k=0 legacy byte-image rebuild path.  Both must return
    the exact pre-crash answer.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import merge_json
from repro.core.graph import GraphDelta
from repro.data.synthetic import make_workload, nws_graph
from repro.dist.cluster import DistributedGNNPE

CHAOS_SCHEMA_VERSION = 1
MAX_OVERHEAD_FRAC = 0.15


def _mk_delta(graph, seed: int) -> GraphDelta:
    """Deterministic small update batch: 2 fresh edges + 1 deletion.
    Engines with bit-identical graphs derive bit-identical deltas."""
    rng = np.random.default_rng(seed * 31 + 17)
    adds = []
    while len(adds) < 2:
        u, v = (int(x) for x in rng.integers(0, graph.n_vertices, size=2))
        if u != v and not graph.has_edge(u, v) and (u, v) not in adds:
            adds.append((u, v))
    del_e = graph.edge_list[int(rng.integers(graph.n_edges))]
    return GraphDelta.make(add_edges=adds, del_edges=[del_e])


def _phase(eng, qs, batch: int, rep: int) -> int:
    """One mixed epoch: megabatch workload, a streaming update, another
    workload on the post-update graph.  Returns total matches."""
    tels = eng.run_workload(qs, probe_mode="plane", batch_size=batch)
    eng.apply_updates(_mk_delta(eng.graph, seed=rep), refit_pe=False)
    tels += eng.run_workload(qs, probe_mode="plane", batch_size=batch)
    return sum(t.n_matches for t in tels)


def replication_overhead(n_vertices: int = 300, n_machines: int = 3,
                         spm: int = 2, n_queries: int = 24,
                         batch: int = 12, seed: int = 5, k: int = 2,
                         gnn_train_steps: int = 8, reps: int = 2) -> dict:
    """Fault-free wall-clock cost of k standby replicas vs none.

    Replica sync piggybacks ONLY on update/migration byte movement, so
    a query-heavy epoch should pay nearly nothing; the 15% gate keeps
    replication honest as the delta protocol evolves.
    """
    g = nws_graph(n_vertices, 6, 0.1, 8, seed=seed)
    base = DistributedGNNPE.build(g, n_machines, shards_per_machine=spm,
                                  gnn_train_steps=gnn_train_steps,
                                  seed=seed)
    twin = DistributedGNNPE.build(g, n_machines, shards_per_machine=spm,
                                  gnn_train_steps=gnn_train_steps,
                                  seed=seed, assignment=base.assignment,
                                  params=base.params, replication=k)
    qs = make_workload(g, n_queries, seed=seed, hot_fraction=0.5)
    # a throwaway engine walks the full phase trajectory first: every
    # jit compile (including post-update plane shapes) lands in the
    # process-wide cache, so neither timed engine pays compilation
    warm = DistributedGNNPE.build(g, n_machines, shards_per_machine=spm,
                                  gnn_train_steps=gnn_train_steps,
                                  seed=seed, assignment=base.assignment,
                                  params=base.params)
    for rep in range(reps):
        _phase(warm, qs, batch, rep)

    t_base = t_twin = 0.0
    m_base = m_twin = 0
    for rep in range(reps):              # interleave to balance drift
        t0 = time.perf_counter()
        m_base += _phase(base, qs, batch, rep)
        t_base += time.perf_counter() - t0
        t0 = time.perf_counter()
        m_twin += _phase(twin, qs, batch, rep)
        t_twin += time.perf_counter() - t0

    assert m_base == m_twin, \
        f"replication changed answers: {m_base} vs {m_twin}"
    overhead = (t_twin - t_base) / max(t_base, 1e-9)
    assert overhead <= MAX_OVERHEAD_FRAC, \
        f"replication overhead {overhead:.1%} exceeds " \
        f"{MAX_OVERHEAD_FRAC:.0%} (k={k})"
    out = {
        "config": {"n_vertices": n_vertices, "n_machines": n_machines,
                   "shards_per_machine": spm, "n_queries": n_queries,
                   "batch": batch, "k": k, "reps": reps},
        "k0_wall_s": round(t_base, 3),
        "k_wall_s": round(t_twin, 3),
        "overhead_frac": round(overhead, 4),
        "matches": m_base,
        "replicas": twin.replicas.stats(),
    }
    merge_json("BENCH_chaos.json",
               "replication_overhead", {"schema_version":
                                        CHAOS_SCHEMA_VERSION, **out})
    return out


def recovery_time(n_vertices: int = 300, n_machines: int = 3,
                  spm: int = 2, seed: int = 5,
                  gnn_train_steps: int = 8) -> dict:
    """Crash -> first bit-correct answer, promotion vs legacy rebuild.

    ``failover_ms`` is the crash-consistent transaction re-homing every
    victim shard; ``first_answer_ms`` the first post-crash query, which
    must equal the pre-crash answer exactly on both paths.
    """
    g = nws_graph(n_vertices, 6, 0.1, 8, seed=seed)
    base = DistributedGNNPE.build(g, n_machines, shards_per_machine=spm,
                                  gnn_train_steps=gnn_train_steps,
                                  seed=seed)
    q = make_workload(g, 1, seed=seed + 1, hot_fraction=0.0)[0]
    out: dict = {"schema_version": CHAOS_SCHEMA_VERSION}
    for kk in (1, 0):
        eng = DistributedGNNPE.build(g, n_machines,
                                     shards_per_machine=spm,
                                     gnn_train_steps=gnn_train_steps,
                                     seed=seed,
                                     assignment=base.assignment,
                                     params=base.params, replication=kk)
        want, _ = eng.query(q, probe_mode="host")
        t0 = time.perf_counter()
        victims = eng.handle_machine_failure(1)
        t_fail = time.perf_counter() - t0
        t0 = time.perf_counter()
        got, _ = eng.query(q, probe_mode="host")
        t_first = time.perf_counter() - t0
        assert got == want, f"k={kk}: post-crash answer diverged"
        assert eng.consistency_audit() == []
        out[f"k{kk}"] = {
            "victim_shards": len(victims),
            "failover_ms": round(t_fail * 1e3, 3),
            "first_answer_ms": round(t_first * 1e3, 3),
            "recovery_ms": round((t_fail + t_first) * 1e3, 3),
            "promotions": eng.replicas.stats()["promotions"],
        }
    merge_json("BENCH_chaos.json", "recovery", out)
    return out


def run() -> list[tuple]:
    over = replication_overhead()
    rec = recovery_time()
    return [
        ("chaos/replication_overhead_frac",
         over["overhead_frac"] * 1e6,
         f"k={over['config']['k']} wall {over['k_wall_s']}s vs "
         f"{over['k0_wall_s']}s"),
        ("chaos/recovery_promotion", rec["k1"]["recovery_ms"] * 1e3,
         f"failover {rec['k1']['failover_ms']}ms + first answer "
         f"{rec['k1']['first_answer_ms']}ms"),
        ("chaos/recovery_legacy", rec["k0"]["recovery_ms"] * 1e3,
         f"failover {rec['k0']['failover_ms']}ms + first answer "
         f"{rec['k0']['first_answer_ms']}ms"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
