"""Streaming graph updates: incremental re-index vs full rebuild.

Claims checked (ISSUE 5 tentpole):

  * rebuild equivalence — after a stream of update batches the engine's
    shard byte images, matches and deterministic per-query counters are
    bit-identical to a from-scratch build on the updated graph;
  * invalidation scope — only touched shards repack resident probe
    planes; untouched shards ship ZERO slab h2d bytes after an update
    (their plane tokens never change);
  * incrementality — only paths through dirty vertices re-embed, and
    the CRC'd delta images are a fraction of the full-cluster image.

Emits stable-schema BENCH_updates.json (updates/sec, re-indexed paths
vs full rebuild, delta bytes).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, merge_json
from repro.core.graph import GraphDelta, LabeledGraph
from repro.data.synthetic import make_workload
from repro.dist.cluster import DistributedGNNPE

UPDATES_SCHEMA_VERSION = 1

_COUNTERS = ("comm_bytes", "cross_shard_rows", "shards_skipped",
             "paths_executed", "paths_skipped", "n_matches")


def clustered_graph(n_comp: int = 4, size: int = 150, n_labels: int = 6,
                    seed: int = 0) -> LabeledGraph:
    """Disjoint sparse communities: the topology where updates HAVE
    locality (with 2-hop halos a small-world update legitimately touches
    every shard, so the invalidation-scope claim needs community
    structure; sparse communities keep the 2-hop dirty ball — and hence
    the re-embed set — small relative to the shard)."""
    rng = np.random.default_rng(seed)
    edges, labels = [], []
    for c in range(n_comp):
        base = c * size
        for i in range(size):
            edges.append([base + i, base + (i + 1) % size])
        extra = rng.integers(0, size, (size // 2, 2)) + base
        edges.extend(extra.tolist())
        labels.extend(rng.integers(0, n_labels, size).tolist())
    return LabeledGraph.from_edges(n_comp * size, np.asarray(edges),
                                   np.asarray(labels))


def random_delta(graph: LabeledGraph, rng: np.random.Generator,
                 component: int, size: int) -> GraphDelta:
    base = component * size
    comp_edges = graph.edge_list[
        (graph.edge_list[:, 0] >= base)
        & (graph.edge_list[:, 0] < base + size)]
    dels = comp_edges[rng.choice(comp_edges.shape[0], 2, replace=False)]
    adds = rng.integers(base, base + size, (2, 2))
    deleted = {tuple(sorted(e)) for e in dels.tolist()}
    adds = np.asarray([e for e in adds.tolist()
                       if tuple(sorted(e)) not in deleted],
                      np.int64).reshape(-1, 2)
    return GraphDelta.make(add_edges=adds, del_edges=dels)


def update_comparison(n_comp: int = 4, size: int = 150, n_updates: int = 6,
                      seed: int = 0) -> dict:
    """Apply a stream of localized update batches; verify equivalence,
    locality and incrementality; emit BENCH_updates.json."""
    g = clustered_graph(n_comp=n_comp, size=size, seed=seed)
    assignment = np.repeat(np.arange(n_comp), size).astype(np.int32)
    t0 = time.perf_counter()
    eng = DistributedGNNPE.build(g, 2, shards_per_machine=n_comp // 2,
                                 gnn_train_steps=15, seed=seed,
                                 assignment=assignment)
    build_s = time.perf_counter() - t0
    eng.use_cache = False
    qs = make_workload(g, 3, seed=seed + 1)
    for q in qs:
        eng.query(q, probe_mode="plane")         # warm every plane
    tokens_before = dict(eng.planes.tokens())

    rng = np.random.default_rng(seed + 7)
    reports = []
    t0 = time.perf_counter()
    for k in range(n_updates):
        reports.append(eng.apply_updates(
            random_delta(eng.graph, rng, component=k % 2, size=size)))
    wall_s = time.perf_counter() - t0

    # invalidation scope: planes of never-touched shards keep tokens
    touched_ever = set().union(*[set(r.touched_shards) for r in reports])
    for q in qs:
        eng.query(q, probe_mode="plane")
    tokens_after = eng.planes.tokens()
    untouched = [k for k in tokens_before if k[0] not in touched_ever]
    assert untouched, "bench fixture must leave untouched shards"
    assert all(tokens_after.get(k) == tokens_before[k] for k in untouched), \
        "untouched shard shipped slab h2d bytes"

    # rebuild equivalence: shard images + query counters vs fresh build
    t0 = time.perf_counter()
    ref = eng.rebuild_reference()
    rebuild_s = time.perf_counter() - t0
    ref.use_cache = False
    for sid in eng.shards:
        assert eng.shards[sid].serialize() == ref.shards[sid].serialize(), \
            f"shard {sid} diverged from the rebuild oracle"
    for q in make_workload(eng.graph, 3, seed=seed + 2):
        m1, t1 = eng.query(q, probe_mode="plane")
        m2, t2 = ref.query(q, probe_mode="plane")
        assert m1 == m2
        assert all(getattr(t1, f) == getattr(t2, f) for f in _COUNTERS)

    reused = sum(r.paths_reused for r in reports)
    reembedded = sum(r.paths_reembedded for r in reports)
    delta_bytes = sum(r.delta_bytes for r in reports)
    full_bytes = reports[-1].full_image_bytes
    full_rebuild_paths = n_updates * sum(
        ep.n_paths for s in eng.shards.values()
        for ep in s.index.embedded.values())
    out = {
        "schema_version": UPDATES_SCHEMA_VERSION,
        "n_vertices": int(eng.graph.n_vertices),
        "n_shards": len(eng.shards),
        "n_updates": n_updates,
        "updates_per_sec": round(n_updates / wall_s, 3),
        "update_wall_s": round(wall_s, 3),
        "build_s": round(build_s, 3),
        "rebuild_s": round(rebuild_s, 3),
        "touched_shards_mean": round(
            np.mean([len(r.touched_shards) for r in reports]), 2),
        "paths_reembedded": reembedded,
        "paths_reused": reused,
        "paths_reembedded_full_rebuild": full_rebuild_paths,
        "reembed_fraction_vs_rebuild": round(
            reembedded / max(full_rebuild_paths, 1), 4),
        "delta_bytes_total": delta_bytes,
        "full_image_bytes": full_bytes,
        "delta_fraction": round(delta_bytes / max(n_updates * full_bytes, 1),
                                4),
        "retransmissions": sum(r.retransmissions for r in reports),
        "untouched_planes_kept": len(untouched),
        "equivalence": "bit-identical",
    }
    merge_json("BENCH_updates.json", "update_comparison", out)
    return out


def run() -> list[tuple]:
    r = update_comparison()
    rows = [
        ("updates/throughput", 0.0,
         f"updates_per_sec={r['updates_per_sec']};"
         f"touched_mean={r['touched_shards_mean']}/{r['n_shards']}"),
        ("updates/incrementality", 0.0,
         f"reembedded={r['paths_reembedded']};reused={r['paths_reused']};"
         f"vs_full_rebuild={r['reembed_fraction_vs_rebuild']}"),
        ("updates/delta_bytes", 0.0,
         f"delta={r['delta_bytes_total']};"
         f"full_image={r['full_image_bytes']};"
         f"fraction={r['delta_fraction']}"),
        ("updates/equivalence", 0.0,
         f"shards=bit-identical;untouched_planes_kept="
         f"{r['untouched_planes_kept']}"),
    ]
    return rows


if __name__ == "__main__":
    emit(run())
