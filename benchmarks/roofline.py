"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch x shape) on the single-pod mesh:
  compute_s    = HLO_FLOPs_per_device / peak_FLOP/s      (197e12 bf16, v5e)
  memory_s     = HLO_bytes_per_device / HBM_bw           (819e9 B/s)
  collective_s = collective_bytes_per_device / ICI_bw    (50e9 B/s/link)

plus MODEL_FLOPS (6·N·D dense, 6·N_active·D MoE; family-specific analytic
counts for GNN/recsys) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

  PYTHONPATH=src python -m benchmarks.roofline [--json dryrun_results.json]
"""

from __future__ import annotations

import argparse
import json

from repro.launch.mesh import HW


def model_flops_per_device(arch: str, shape: str, n_devices: int) -> float:
    """Analytic 'useful' FLOPs per step per device."""
    from repro.configs import get_spec
    spec = get_spec(arch)
    cfg = spec.make_config()
    sh = spec.shapes[shape]
    if spec.family == "lm":
        n_act = cfg.active_param_count()
        b = sh.dims["global_batch"]
        s = sh.dims["seq_len"]
        if sh.kind == "train":
            tokens = b * s
            total = 6.0 * n_act * tokens          # fwd+bwd
        elif sh.kind == "prefill":
            total = 2.0 * n_act * b * s
        else:                                     # decode: 1 token/request
            total = 2.0 * n_act * b
            if sh.kind == "decode":               # + attention over KV
                t = s if cfg.sliding_window is None \
                    else min(s, cfg.sliding_window)
                if cfg.mla is not None:
                    kv_d = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
                    total += 2.0 * b * cfg.n_layers * cfg.n_heads * t * kv_d * 2
                else:
                    total += 2.0 * b * cfg.n_layers * cfg.n_heads * t \
                        * cfg.head_dim * 2
        return total / n_devices
    if spec.family == "gnn":
        n = sh.dims["n_nodes"] * sh.dims.get("batch", 1)
        e = 2 * sh.dims["n_edges"] * sh.dims.get("batch", 1)
        d = cfg.d_hidden
        l = cfg.n_layers
        # message MLP + node update per edge/node per layer, fwd+bwd (x3)
        per_layer = e * (2 * d * d * 2) + n * (2 * d * d * 2)
        if cfg.arch == "nequip":
            per_layer = e * (9 * 9 * 9 * d + cfg.n_rbf * 2 * d * 3) * 2 \
                + n * 9 * d * d * 2
        return 3.0 * l * per_layer / n_devices
    if spec.family == "recsys":
        b = sh.dims["batch"]
        s = cfg.seq_len
        d = cfg.embed_dim
        blk = cfg.n_blocks * (8 * d * d + 4 * d * cfg.d_ff
                              + 4 * s * d) * s * b
        if shape == "train_batch":
            blk *= 3
        if shape == "retrieval_cand":
            blk += 2.0 * sh.dims["n_candidates"] * d
        if shape == "serve_bulk":
            blk += 2.0 * b * cfg.n_items * d
        return blk / n_devices
    return float("nan")


def analyze(results: list[dict], calib: list[dict] | None = None
            ) -> list[dict]:
    """calib: scan-corrected totals from benchmarks/flops_calib.py — the
    LM family's scan-over-layers bodies are counted once by cost_analysis,
    so calibrated numbers override the raw dry-run ones where present."""
    cal = {(c["arch"], c["shape"]): c for c in (calib or [])
           if c.get("status") == "ok"}
    rows = []
    for r in results:
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        nd = r["n_devices"]
        c = cal.get((r["arch"], r["shape"]))
        flops = c["flops"] if c else r["flops_per_device"]
        byts = c["bytes"] if c else r["bytes_accessed_per_device"]
        collb = c["coll"] if c else r["collectives"]["total"]
        comp = flops / HW["peak_flops_bf16"]
        mem = byts / HW["hbm_bw"]
        coll = collb / HW["ici_bw"]
        dom = max(("compute", comp), ("memory", mem), ("collective", coll),
                  key=lambda kv: kv[1])
        try:
            mf = model_flops_per_device(r["arch"], r["shape"], nd)
        except Exception:  # noqa: BLE001
            mf = float("nan")
        ratio = mf / max(flops, 1.0)
        bound = max(comp, mem, coll)
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": comp, "memory_s": mem, "collective_s": coll,
            "dominant": dom[0],
            "model_flops_per_dev": mf,
            "useful_ratio": ratio,
            "roofline_fraction": comp / bound if bound > 0 else 0.0,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--calib", default=None)
    ap.add_argument("--md", action="store_true", help="markdown table")
    args = ap.parse_args()
    results = json.load(open(args.json))
    calib = json.load(open(args.calib)) if args.calib else None
    rows = analyze(results, calib)
    hdr = ("arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "useful_ratio", "roofline_fraction")
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for w in rows:
            print("| " + " | ".join(
                f"{w[h]:.3e}" if isinstance(w[h], float) else str(w[h])
                for h in hdr) + " |")
    else:
        print(",".join(hdr))
        for w in rows:
            print(",".join(
                f"{w[h]:.4e}" if isinstance(w[h], float) else str(w[h])
                for h in hdr))


if __name__ == "__main__":
    main()
