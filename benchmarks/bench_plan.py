"""Paper Table: PE-score query plan ranking vs degree order (§6).

Claims checked: PE-score ordering cuts cross-shard candidate transmission
(paper: 60-70% on their clusters); plan inference overhead is negligible
(< 5% of query latency; < 1ms/path).
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.data.synthetic import make_workload


def run() -> list[tuple]:
    # skewed (Zipf) labels: rare labels carry the pruning signal the
    # ranker exploits — the regime the paper's claim targets
    from repro.data.synthetic import nws_graph
    from repro.dist.cluster import DistributedGNNPE
    g = nws_graph(800, 6, 0.1, 12, seed=4, label_skew=0.6)
    eng = DistributedGNNPE.build(g, 4, shards_per_machine=4,
                                 gnn_train_steps=25, seed=4)
    qs = make_workload(g, 12, seed=4)
    eng.use_cache = False
    rows = []
    stats = {}
    for mode in ("pescore", "degree", "natural"):
        tels = [eng.query(q, plan_mode=mode)[1] for q in qs]
        stats[mode] = {
            "bytes": sum(t.comm_bytes for t in tels),
            "rows": sum(t.cross_shard_rows for t in tels),
            "ms": sum(t.latency_ms for t in tels),
        }
    pe, dg = stats["pescore"], stats["degree"]
    red = 1 - pe["bytes"] / max(dg["bytes"], 1)
    rows.append(("plan/cross_shard_transfer", 0.0,
                 f"pescore_B={pe['bytes']};degree_B={dg['bytes']};"
                 f"natural_B={stats['natural']['bytes']};"
                 f"reduction_vs_degree={red:.1%}"))
    rows.append(("plan/latency", 0.0,
                 f"pescore_ms={pe['ms']:.0f};degree_ms={dg['ms']:.0f};"
                 f"natural_ms={stats['natural']['ms']:.0f}"))

    # plan inference overhead per path (claim: < 1 ms/path)
    from repro.core.plan import rank_query_plan
    q = qs[0]
    t0 = time.perf_counter()
    n_rep = 20
    for _ in range(n_rep):
        plan = rank_query_plan(q, eng.pe_model, max_path_length=2)
    us = (time.perf_counter() - t0) / n_rep * 1e6
    per_path_ms = us / 1e3 / max(len(plan.order), 1)
    rows.append(("plan/rank_overhead", us,
                 f"paths={len(plan.order)};ms_per_path={per_path_ms:.3f}"))
    return rows


if __name__ == "__main__":
    emit(run())
