"""Paper Table: dominance-embedding pruning power (§3.2 / GNN-PE Table 4).

Claims checked: index-level pruning removes the overwhelming majority of
candidate paths (GNN-PE reports ~99.5% on US-Patents); training the
certified-monotone GNN improves pruning over untrained params.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import gnn as gnn_lib
from repro.core.artree import query_stats
from repro.core.embedding import train_dominance_gnn
from repro.core.matching import build_shard_index
from repro.data.synthetic import make_dataset


def _pruning(g, params, cfg) -> dict[str, float]:
    index = build_shard_index(g, params, cfg, max_length=2)
    out = {}
    for l, tree in index.trees.items():
        ep = index.embedded[l]
        sel = [query_stats(tree, ep.embeddings[i])["selectivity"]
               for i in range(0, ep.n_paths, max(ep.n_paths // 100, 1))]
        prr = [query_stats(tree, ep.embeddings[i])["pruning_rate"]
               for i in range(0, ep.n_paths, max(ep.n_paths // 100, 1))]
        out[l] = (float(np.mean(sel)), float(np.mean(prr)))
    return out


def run() -> list[tuple]:
    rows = []
    for name in ("dblp-s", "nws-s"):
        g = make_dataset(name)
        cfg = gnn_lib.GNNConfig(n_labels=g.n_labels)
        p0 = gnn_lib.init_params(cfg, jax.random.PRNGKey(0))
        trained = train_dominance_gnn(g, cfg, n_steps=80, seed=0)
        before = _pruning(g, p0, cfg)
        after = _pruning(g, trained, cfg)
        for l in sorted(after):
            rows.append((f"pruning/{name}_len{l}", 0.0,
                         f"selectivity={after[l][0]:.4f};"
                         f"index_prune={after[l][1]:.4f};"
                         f"untrained_sel={before[l][0]:.4f}"))
    return rows


if __name__ == "__main__":
    emit(run())
