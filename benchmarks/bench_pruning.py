"""Paper Table: dominance-embedding pruning power (§3.2 / GNN-PE Table 4).

Claims checked: index-level pruning removes the overwhelming majority of
candidate paths (GNN-PE reports ~99.5% on US-Patents); training the
certified-monotone GNN improves pruning over untrained params.  Also
runs the three-way probe comparison — per-(path, shard) host traversal
vs per-path device slab (`probe_mode="device"`) vs device-resident
probe planes (`probe_mode="plane"`, one fused launch per query plan,
candidate-id-only readback) — and emits it to BENCH_probe.json.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import gnn as gnn_lib
from repro.core.artree import query_stats
from repro.core.embedding import train_dominance_gnn
from repro.core.matching import build_shard_index
from repro.data.synthetic import make_dataset


def _pruning(g, params, cfg) -> dict[str, float]:
    index = build_shard_index(g, params, cfg, max_length=2)
    out = {}
    for l, tree in index.trees.items():
        ep = index.embedded[l]
        sel = [query_stats(tree, ep.embeddings[i])["selectivity"]
               for i in range(0, ep.n_paths, max(ep.n_paths // 100, 1))]
        prr = [query_stats(tree, ep.embeddings[i])["pruning_rate"]
               for i in range(0, ep.n_paths, max(ep.n_paths // 100, 1))]
        out[l] = (float(np.mean(sel)), float(np.mean(prr)))
    return out


def probe_comparison(path: str = "BENCH_probe.json") -> dict:
    """Host vs per-path-device vs resident-plane probe, same workload.

    Three-way comparison of the probe paths (all bit-identical in
    matches and comm accounting):

      * host:   one traversal per (path, shard) — no device traffic;
      * device: one launch per path, but the slab is re-packed on the
        host per path and the dense ok mask ships back (PR 2);
      * plane:  ONE fused launch per query plan over the device-resident
        planes — warm queries ship query rows up and candidate ids down,
        never the slab.

    The result (launch counts + host<->device bytes per query) is merged
    into BENCH_probe.json.
    """
    from benchmarks.common import bench_engine
    from repro.data.synthetic import make_workload

    g, eng = bench_engine(n_machines=3, spm=3, n_vertices=400, seed=0)
    qs = make_workload(g, 6, seed=0)
    eng.use_cache = False
    for q in qs:                          # jit + plane warmup (all modes)
        eng.query(q, probe_mode="device")
        eng.query(q, probe_mode="plane")
    report: dict = {"n_queries": len(qs), "n_shards": len(eng.shards)}
    matches: dict[str, int] = {}
    for mode in ("host", "device", "plane"):
        t0 = time.perf_counter()
        launches = paths = comm = rows = h2d = d2h = 0
        n_matches = 0
        for q in qs:
            m, tel = eng.query(q, probe_mode=mode)
            launches += tel.probe_launches
            paths += tel.paths_executed
            comm += tel.comm_bytes
            rows += tel.cross_shard_rows
            h2d += tel.probe_h2d_bytes
            d2h += tel.probe_d2h_bytes
            n_matches += len(m)
        matches[mode] = n_matches
        report[mode] = {
            "wall_s": round(time.perf_counter() - t0, 4),
            "probe_launches": launches,
            "paths_executed": paths,
            "launches_per_path": round(launches / max(paths, 1), 3),
            "launches_per_query": round(launches / len(qs), 3),
            "comm_bytes": comm,
            "cross_shard_rows": rows,
            "h2d_bytes_per_query": round(h2d / len(qs), 1),
            "d2h_bytes_per_query": round(d2h / len(qs), 1),
        }
    assert matches["host"] == matches["device"] == matches["plane"], \
        "device/plane probe not exact"
    assert report["host"]["comm_bytes"] == report["device"]["comm_bytes"] \
        == report["plane"]["comm_bytes"]
    assert report["device"]["probe_launches"] \
        <= report["device"]["paths_executed"], \
        "device probe must launch at most once per query path"
    assert report["plane"]["probe_launches"] <= len(qs), \
        "plane probe must launch at most once per query plan"
    assert report["plane"]["h2d_bytes_per_query"] \
        < report["device"]["h2d_bytes_per_query"], \
        "resident planes must ship fewer slab bytes than per-path packing"
    report["plane"]["resident_bytes"] = eng.planes.resident_bytes()
    report["plane"]["cache_stats"] = dict(eng.planes.stats)
    from benchmarks.common import merge_json
    merge_json(path, "probe", report)
    return report


def run() -> list[tuple]:
    rows = []
    for name in ("dblp-s", "nws-s"):
        g = make_dataset(name)
        cfg = gnn_lib.GNNConfig(n_labels=g.n_labels)
        p0 = gnn_lib.init_params(cfg, jax.random.PRNGKey(0))
        trained = train_dominance_gnn(g, cfg, n_steps=80, seed=0)
        before = _pruning(g, p0, cfg)
        after = _pruning(g, trained, cfg)
        for l in sorted(after):
            rows.append((f"pruning/{name}_len{l}", 0.0,
                         f"selectivity={after[l][0]:.4f};"
                         f"index_prune={after[l][1]:.4f};"
                         f"untrained_sel={before[l][0]:.4f}"))
    probe = probe_comparison()
    rows.append(("pruning/probe_host_vs_device_vs_plane",
                 probe["plane"]["wall_s"] * 1e6,
                 f"host_launches={probe['host']['probe_launches']};"
                 f"device_launches={probe['device']['probe_launches']};"
                 f"plane_launches={probe['plane']['probe_launches']};"
                 "plane_launches_per_query="
                 f"{probe['plane']['launches_per_query']};"
                 f"device_h2d_per_q={probe['device']['h2d_bytes_per_query']};"
                 f"plane_h2d_per_q={probe['plane']['h2d_bytes_per_query']};"
                 f"comm_bytes={probe['plane']['comm_bytes']}"))
    return rows


if __name__ == "__main__":
    emit(run())
