"""Paper Table: dominance-embedding pruning power (§3.2 / GNN-PE Table 4).

Claims checked: index-level pruning removes the overwhelming majority of
candidate paths (GNN-PE reports ~99.5% on US-Patents); training the
certified-monotone GNN improves pruning over untrained params.  Also
compares the per-(path, shard) host probe against the batched device
probe (`device_probe=True`, one launch per query path over the padded
[S, max_leaves, D] slab) and emits the comparison to BENCH_probe.json.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import gnn as gnn_lib
from repro.core.artree import query_stats
from repro.core.embedding import train_dominance_gnn
from repro.core.matching import build_shard_index
from repro.data.synthetic import make_dataset


def _pruning(g, params, cfg) -> dict[str, float]:
    index = build_shard_index(g, params, cfg, max_length=2)
    out = {}
    for l, tree in index.trees.items():
        ep = index.embedded[l]
        sel = [query_stats(tree, ep.embeddings[i])["selectivity"]
               for i in range(0, ep.n_paths, max(ep.n_paths // 100, 1))]
        prr = [query_stats(tree, ep.embeddings[i])["pruning_rate"]
               for i in range(0, ep.n_paths, max(ep.n_paths // 100, 1))]
        out[l] = (float(np.mean(sel)), float(np.mean(prr)))
    return out


def probe_comparison(path: str = "BENCH_probe.json") -> dict:
    """Host vs batched-device probe on the same engine and workload.

    The defining property of the device path: exactly one probe dispatch
    (device launch) per executed query path, against one per
    (path, shard) on the host — with bit-identical matches and comm
    accounting.  The result is merged into BENCH_probe.json.
    """
    from benchmarks.common import bench_engine
    from repro.data.synthetic import make_workload

    g, eng = bench_engine(n_machines=3, spm=3, n_vertices=400, seed=0)
    qs = make_workload(g, 6, seed=0)
    eng.use_cache = False
    report: dict = {"n_queries": len(qs), "n_shards": len(eng.shards)}
    matches: dict[str, int] = {}
    for mode, flag in (("host", False), ("device", True)):
        t0 = time.perf_counter()
        launches = paths = comm = rows = 0
        n_matches = 0
        for q in qs:
            m, tel = eng.query(q, device_probe=flag)
            launches += tel.probe_launches
            paths += tel.paths_executed
            comm += tel.comm_bytes
            rows += tel.cross_shard_rows
            n_matches += len(m)
        matches[mode] = n_matches
        report[mode] = {
            "wall_s": round(time.perf_counter() - t0, 4),
            "probe_launches": launches,
            "paths_executed": paths,
            "launches_per_path": round(launches / max(paths, 1), 3),
            "comm_bytes": comm,
            "cross_shard_rows": rows,
        }
    assert matches["host"] == matches["device"], "device probe not exact"
    assert report["host"]["comm_bytes"] == report["device"]["comm_bytes"]
    assert report["device"]["probe_launches"] \
        <= report["device"]["paths_executed"], \
        "device probe must launch at most once per query path"
    try:
        with open(path) as f:
            merged = json.load(f)
    except (OSError, json.JSONDecodeError):
        merged = {}
    merged["probe"] = report
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
    return report


def run() -> list[tuple]:
    rows = []
    for name in ("dblp-s", "nws-s"):
        g = make_dataset(name)
        cfg = gnn_lib.GNNConfig(n_labels=g.n_labels)
        p0 = gnn_lib.init_params(cfg, jax.random.PRNGKey(0))
        trained = train_dominance_gnn(g, cfg, n_steps=80, seed=0)
        before = _pruning(g, p0, cfg)
        after = _pruning(g, trained, cfg)
        for l in sorted(after):
            rows.append((f"pruning/{name}_len{l}", 0.0,
                         f"selectivity={after[l][0]:.4f};"
                         f"index_prune={after[l][1]:.4f};"
                         f"untrained_sel={before[l][0]:.4f}"))
    probe = probe_comparison()
    rows.append(("pruning/probe_host_vs_device",
                 probe["device"]["wall_s"] * 1e6,
                 f"host_launches={probe['host']['probe_launches']};"
                 f"device_launches={probe['device']['probe_launches']};"
                 f"device_launches_per_path="
                 f"{probe['device']['launches_per_path']};"
                 f"comm_bytes={probe['device']['comm_bytes']}"))
    return rows


if __name__ == "__main__":
    emit(run())
