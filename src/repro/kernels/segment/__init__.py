from repro.kernels.segment.ops import csr_gather_sum
from repro.kernels.segment.ref import csr_gather_sum_ref

__all__ = ["csr_gather_sum", "csr_gather_sum_ref"]
