"""Public wrapper for the CSR gather-sum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment.kernel import csr_gather_sum_pallas
from repro.kernels.segment.ref import csr_gather_sum_ref


def csr_gather_sum(neighbors: jnp.ndarray, weights: jnp.ndarray,
                   feats: jnp.ndarray, use_pallas: bool | None = None
                   ) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return csr_gather_sum_pallas(
            neighbors, weights, feats,
            interpret=jax.default_backend() != "tpu")
    return csr_gather_sum_ref(neighbors, weights, feats)
