"""Pure-jnp oracle: padded-CSR gather-sum == edge-list segment_sum."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def csr_gather_sum_ref(neighbors: jnp.ndarray, weights: jnp.ndarray,
                       feats: jnp.ndarray) -> jnp.ndarray:
    """neighbors [N, K] (pad -1), weights [N, K], feats [V, F] -> [N, F]."""
    valid = neighbors >= 0
    rows = feats[jnp.maximum(neighbors, 0)]              # [N, K, F]
    return jnp.sum(rows * (weights * valid)[..., None], axis=1)


def edges_to_padded_csr(edge_src, edge_dst, n_nodes: int, k_max: int):
    """Edge-list -> padded CSR (numpy helper for tests/loaders)."""
    import numpy as np
    nbr = -np.ones((n_nodes, k_max), dtype=np.int32)
    cnt = np.zeros(n_nodes, dtype=np.int64)
    for s, d in zip(np.asarray(edge_src), np.asarray(edge_dst)):
        if cnt[d] < k_max:
            nbr[d, cnt[d]] = s
            cnt[d] += 1
    return nbr
