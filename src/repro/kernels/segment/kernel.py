"""Pallas TPU kernel: padded-CSR neighbor gather-sum (MPNN primitive).

The message-passing hot loop shared by GNN-PE's encoder and the GNN zoo:
out[n] = sum_k w[n, k] * feat[nbr[n, k]] over each node's (padded) neighbor
list.  The edge-list `segment_sum` formulation is re-blocked into padded
CSR (rows = destination nodes, K_max neighbor slots) so each grid cell owns
one contiguous node block — scatter-free accumulation, the TPU-native
shape of the op (DESIGN.md §3: gather/scatter regime).

VMEM strategy: neighbor ids [BLOCK_N, K] live in VMEM; the feature table
stays un-blocked (memory_space=ANY on real TPU with per-row DMA; the
interpret-mode build loads it whole, which is also the correct CPU
fallback).  The inner loop walks K neighbor slots with a masked gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256


def _gather_sum_kernel(nbr_ref, wgt_ref, feat_ref, out_ref):
    nbr = nbr_ref[...]                    # [BN, K] int32 (-1 = pad)
    wgt = wgt_ref[...]                    # [BN, K]
    feat = feat_ref[...]                  # [V, F] (whole table)
    bn, k = nbr.shape
    acc = jnp.zeros((bn, feat.shape[1]), jnp.float32)
    for i in range(k):
        ids = nbr[:, i]
        valid = ids >= 0
        rows = feat[jnp.maximum(ids, 0)]
        acc = acc + jnp.where(valid[:, None],
                              rows * wgt[:, i][:, None], 0.0)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def csr_gather_sum_pallas(neighbors: jnp.ndarray, weights: jnp.ndarray,
                          feats: jnp.ndarray, block_n: int = BLOCK_N,
                          interpret: bool = True) -> jnp.ndarray:
    """neighbors [N, K] int32 (pad -1), weights [N, K], feats [V, F] ->
    [N, F] weighted neighbor sums."""
    n, k = neighbors.shape
    v, f = feats.shape
    n_pad = pl.cdiv(n, block_n) * block_n
    nb = jnp.pad(neighbors, ((0, n_pad - n), (0, 0)), constant_values=-1)
    wg = jnp.pad(weights, ((0, n_pad - n), (0, 0)))
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        _gather_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((v, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, f), feats.dtype),
        interpret=interpret,
    )(nb, wg, feats)
    return out[:n]
