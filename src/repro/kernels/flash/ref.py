"""Pure-jnp oracle for the flash kernel: dense GQA attention."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        scale: float | None = None, causal: bool = True,
                        window: int | None = None) -> jnp.ndarray:
    """q [B, S, H, D], k/v [B, S, KV, D] -> [B, S, H, D]."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= kj <= qi
    if window is not None:
        ok &= (qi - kj) < window
    scores = jnp.where(ok, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)
