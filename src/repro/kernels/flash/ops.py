"""Public wrapper for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import flash_attention_ref


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    scale: float | None = None, causal: bool = True,
                    window: int | None = None,
                    use_pallas: bool | None = None) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, scale, causal, window,
            interpret=jax.default_backend() != "tpu")
    return flash_attention_ref(q, k, v, scale, causal, window)
