from repro.kernels.flash.ops import flash_attention
from repro.kernels.flash.ref import flash_attention_ref

__all__ = ["flash_attention", "flash_attention_ref"]
