"""Pallas TPU kernel: tiled causal attention with online softmax (GQA).

FlashAttention re-thought for the TPU memory hierarchy (DESIGN.md §3):
grid = (batch*q_heads, n_q_blocks, n_kv_blocks) with the kv axis marked
'arbitrary' (sequential); running max / sum / output accumulators live in
VMEM scratch and persist across kv iterations of the same (bh, q) cell.
Q/K/V tiles are MXU-aligned: BLOCK_Q x D and BLOCK_K x D with D padded to
128 lanes.  GQA is expressed through the K/V index_map (q-head h reads
kv-head h // group_size) — no KV duplication in HBM.

Causal + sliding-window masking is positional (block-diagonal skip is an
optimization left to the scheduler; masked lanes compute zeros).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas-TPU renamed TPUCompilerParams -> CompilerParams across JAX
# releases; resolve whichever this version ships
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; update the compat alias in "
        "repro/kernels/flash/kernel.py for this JAX version")

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # [BQ, D]
    k = k_ref[0]                                   # [BK, D]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                            # [BQ, 1]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                         # [BQ, BK]
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           scale: float | None = None, causal: bool = True,
                           window: int | None = None,
                           block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                           interpret: bool = True) -> jnp.ndarray:
    """q [B, S, H, D], k/v [B, S, KV, D] -> [B, S, H, D].  H % KV == 0."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s_pad = pl.cdiv(s, max(block_q, block_k)) * max(block_q, block_k)
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    # layout: [B*H, S, D] for q/o; [B*KV, S, D] for k/v
    qb = qp.transpose(0, 2, 1, 3).reshape(b * h, s_pad, d)
    kb = kp.transpose(0, 2, 1, 3).reshape(b * kv, s_pad, d)
    vb = vp.transpose(0, 2, 1, 3).reshape(b * kv, s_pad, d)
    n_q = s_pad // block_q
    n_k = s_pad // block_k
    grid = (b * h, n_q, n_k)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        return ((bh // g), j, 0)           # GQA: share kv head across group

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_kv_blocks=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),     # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qb, kb, vb)
    return out.reshape(b, h, s_pad, d).transpose(0, 2, 1, 3)[:, :s]
