from repro.kernels.dominance.ops import (batched_dominance_mask,
                                         dominance_mask)
from repro.kernels.dominance.ref import (dominance_mask_3d_ref,
                                         dominance_mask_ref)

__all__ = ["dominance_mask", "dominance_mask_ref",
           "batched_dominance_mask", "dominance_mask_3d_ref"]
