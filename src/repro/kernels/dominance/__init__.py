from repro.kernels.dominance.ops import dominance_mask
from repro.kernels.dominance.ref import dominance_mask_ref

__all__ = ["dominance_mask", "dominance_mask_ref"]
