from repro.kernels.dominance.ops import (DEPTH_BUCKET, LANE_BUCKET,
                                         QUERY_BUCKET, ROW_BUCKET,
                                         SHARD_BUCKET,
                                         batched_dominance_mask,
                                         dominance_mask, fused_plan_descent,
                                         gather_pack_lanes_jit,
                                         megabatch_leaf_probe,
                                         readback_id_dtype)
from repro.kernels.dominance.ref import (dominance_mask_3d_ref,
                                         dominance_mask_ref,
                                         megabatch_leaf_probe_ref,
                                         packed_mask_pass_ref,
                                         survivor_propagation_ref)

__all__ = ["dominance_mask", "dominance_mask_ref",
           "batched_dominance_mask", "dominance_mask_3d_ref",
           "fused_plan_descent", "survivor_propagation_ref",
           "megabatch_leaf_probe", "megabatch_leaf_probe_ref",
           "packed_mask_pass_ref", "gather_pack_lanes_jit",
           "readback_id_dtype",
           "SHARD_BUCKET", "ROW_BUCKET", "QUERY_BUCKET", "DEPTH_BUCKET",
           "LANE_BUCKET"]
