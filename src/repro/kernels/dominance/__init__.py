from repro.kernels.dominance.ops import (DEPTH_BUCKET, QUERY_BUCKET,
                                         ROW_BUCKET, SHARD_BUCKET,
                                         batched_dominance_mask,
                                         dominance_mask, fused_plan_descent)
from repro.kernels.dominance.ref import (dominance_mask_3d_ref,
                                         dominance_mask_ref,
                                         survivor_propagation_ref)

__all__ = ["dominance_mask", "dominance_mask_ref",
           "batched_dominance_mask", "dominance_mask_3d_ref",
           "fused_plan_descent", "survivor_propagation_ref",
           "SHARD_BUCKET", "ROW_BUCKET", "QUERY_BUCKET", "DEPTH_BUCKET"]
