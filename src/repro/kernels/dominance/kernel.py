"""Pallas TPU kernel: batched box-dominance test (aR-tree pruning filter).

The paper's hot filter op: Q query embeddings probe N boxes (aR-tree node
upper bounds or leaf points); survivor mask[q, n] = all_d(query[q, d] <=
box[n, d] + eps).  Memory-bound streaming compare+reduce.

TPU mapping (DESIGN.md §3): tiles of (BLOCK_Q, D) x (BLOCK_N, D) are
streamed through VMEM; the compare happens on the VPU with an AND-reduce
over the (small, lane-padded) D axis.  BLOCK_N is lane-aligned (128) and
BLOCK_Q sublane-aligned (8).  Output is int8 (bool vectors pack poorly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_N = 256
BLOCK_S_Q = 8                       # batched kernel: query block (sublane)
BLOCK_S_N = 256                     # batched kernel: box block (lanes)


def _dominance_kernel(q_ref, boxes_ref, out_ref, *, eps: float):
    q = q_ref[...]                        # [BQ, D]
    b = boxes_ref[...]                    # [BN, D]
    ok = (q[:, None, :] <= b[None, :, :] + eps).all(axis=-1)
    out_ref[...] = ok.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("eps", "block_q", "block_n",
                                             "interpret"))
def dominance_pallas(queries: jnp.ndarray, boxes: jnp.ndarray,
                     eps: float = 1e-5, block_q: int = BLOCK_Q,
                     block_n: int = BLOCK_N,
                     interpret: bool = True) -> jnp.ndarray:
    """queries [Q, D], boxes [N, D] -> int8 [Q, N] dominance mask.

    Q and N are padded to block multiples; D is loaded whole (d <= 32 in
    GNN-PE: (l+1)*(d_e+d_l) <= 6*4 = 24 pads to one lane tile).
    """
    q, d = queries.shape
    n = boxes.shape[0]
    q_pad = pl.cdiv(q, block_q) * block_q
    n_pad = pl.cdiv(n, block_n) * block_n
    qq = jnp.pad(queries, ((0, q_pad - q), (0, 0)),
                 constant_values=jnp.inf)     # padded queries match nothing
    bb = jnp.pad(boxes, ((0, n_pad - n), (0, 0)),
                 constant_values=-jnp.inf)    # padded boxes dominate nothing
    grid = (q_pad // block_q, n_pad // block_n)
    out = pl.pallas_call(
        functools.partial(_dominance_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q_pad, n_pad), jnp.int8),
        interpret=interpret,
    )(qq, bb)
    return out[:q, :n]


def _dominance_kernel_3d(q_ref, boxes_ref, out_ref, *, eps: float):
    q = q_ref[...]                        # [BQ, D]
    b = boxes_ref[0]                      # [BN, D] (shard-sliced)
    ok = (q[:, None, :] <= b[None, :, :] + eps).all(axis=-1)
    out_ref[0] = ok.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("eps", "block_q", "block_n",
                                             "interpret"))
def dominance_pallas_3d(queries: jnp.ndarray, boxes: jnp.ndarray,
                        eps: float = 1e-5, block_q: int = BLOCK_S_Q,
                        block_n: int = BLOCK_S_N,
                        interpret: bool = True) -> jnp.ndarray:
    """queries [Q, D], boxes [S, L, D] -> int8 [S, Q, L] dominance mask.

    The batched device-probe layout: S shards, each padded to L =
    max_leaves box rows (aR-tree node uppers or leaf points).  Pad rows
    must hold -inf so they dominate nothing; the grid streams one shard
    slab per program along the first axis, so the whole cluster's leaf
    filter for one query path is a single launch.
    """
    s, l, d = boxes.shape
    q = queries.shape[0]
    q_pad = pl.cdiv(q, block_q) * block_q
    l_pad = pl.cdiv(max(l, 1), block_n) * block_n
    qq = jnp.pad(queries, ((0, q_pad - q), (0, 0)),
                 constant_values=jnp.inf)     # padded queries match nothing
    bb = jnp.pad(boxes, ((0, 0), (0, l_pad - l), (0, 0)),
                 constant_values=-jnp.inf)    # padded boxes dominate nothing
    grid = (s, q_pad // block_q, l_pad // block_n)
    out = pl.pallas_call(
        functools.partial(_dominance_kernel_3d, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda k, i, j: (i, 0)),
            pl.BlockSpec((1, block_n, d), lambda k, i, j: (k, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, block_n),
                               lambda k, i, j: (k, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, q_pad, l_pad), jnp.int8),
        interpret=interpret,
    )(qq, bb)
    return out[:, :q, :l]
