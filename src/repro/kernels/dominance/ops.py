"""Public wrapper: Pallas on TPU, interpret-mode Pallas elsewhere.

The aR-tree device path (repro/core/artree batched traversal) calls this
for leaf-level filtering when `use_pallas` is on; the CPU dry-run lowers
the pure-jnp reference instead (Mosaic kernels do not compile on the CPU
backend).

`fused_plan_descent` is the whole-plan probe: dominance compare AND
level-order survivor propagation in one launch, returning compact
candidate row ids + counters instead of the dense ok mask (the probe-
plane readback contract — see repro/core/probeplane.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dominance.kernel import (BLOCK_N, BLOCK_Q, BLOCK_S_N,
                                            BLOCK_S_Q, dominance_pallas,
                                            dominance_pallas_3d)
from repro.kernels.dominance.ref import (dominance_mask_3d_ref,
                                         dominance_mask_ref,
                                         megabatch_leaf_probe_ref,
                                         packed_mask_pass_ref,
                                         survivor_propagation_ref)

# Slab-shape buckets.  The probed shard set, row counts, query-plan size
# and tree depth all vary per query, and exact-shape slabs would retrace
# the jitted probe on nearly every call; rounding every axis up to these
# buckets bounds the distinct compiled shapes to one per (S-bucket,
# R-bucket) pair (times the handful of Q/depth buckets) while capping the
# padded compute at one extra block per dim.  ROW_BUCKET matches the 3-D
# kernel's lane block (BLOCK_S_N) and SHARD_BUCKET/QUERY_BUCKET its
# sublane block (BLOCK_S_Q); DEPTH_BUCKET exploits that propagation
# iterations past the tree depth are idempotent.
SHARD_BUCKET = 8
ROW_BUCKET = 256
QUERY_BUCKET = 8
DEPTH_BUCKET = 4
# megabatch: candidate-bearing (plane, query-row) lanes gathered by the
# second stage are padded to this bucket, and the packed-bit readback
# width is the row bucket (ROW_BUCKET is a multiple of 8, so the packed
# byte axis is always exact)
LANE_BUCKET = 64
# megabatch query rows per length vary with every batch's plan mix, so
# past QUERY_BUCKET * 4 rows they bucket much coarser: at B=16 a batch
# packs hundreds of rows, so 64-row steps cap the padded compute at
# ~15% while bounding distinct compiled shapes to a handful per length
# block.  Small batches (B=1..2) keep the fine bucket so their counts
# readback stays below the serial plane path's.
MEGA_QUERY_BUCKET = 64
# the shared packed-mask operand has one bit row per (query, query
# vertex), so its row count varies with every batch's query mix; pad
# rows are all-zero bits and never referenced by any mask_rows index
# (at B=16 with <=8-vertex queries this is <=128 rows, so 32-row steps
# bound the operand to a handful of compiled shapes)
MASK_ROW_BUCKET = 32


def mega_query_bucket(n_rows: int) -> int:
    """Bucketed megabatch query-row count: fine steps while small,
    MEGA_QUERY_BUCKET steps beyond QUERY_BUCKET * 4 rows."""
    if n_rows <= 4 * QUERY_BUCKET:
        return bucket(n_rows, QUERY_BUCKET)
    return bucket(n_rows, MEGA_QUERY_BUCKET)


def bucket(n: int, b: int) -> int:
    """Round n up to a multiple of bucket size b (0 stays 0)."""
    return -(-n // b) * b


def readback_id_dtype(n_rows: int):
    """Smallest id dtype whose range holds every slab row id AND the
    sentinel value ``n_rows`` used for non-candidates.

    int16 halves the candidate-id readback, but is only safe while the
    sentinel fits: n_rows <= int16 max (32767).  Row counts are bucketed
    (ROW_BUCKET multiples), so the first unsafe slab is exactly 2**15
    rows — callers must widen to int32 there, not overflow the sentinel
    to -32768 (regression-tested in tests/test_megabatch.py).
    """
    return jnp.int16 if n_rows < 2 ** 15 else jnp.int32


def dominance_mask(queries: jnp.ndarray, boxes: jnp.ndarray,
                   eps: float = 1e-5, use_pallas: bool | None = None
                   ) -> jnp.ndarray:
    """queries [Q, D], boxes [N, D] -> int8 [Q, N] dominance mask."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return dominance_pallas(queries, boxes, eps,
                                interpret=jax.default_backend() != "tpu")
    return dominance_mask_ref(queries, boxes, eps)


def batched_dominance_mask(queries: jnp.ndarray, boxes: jnp.ndarray,
                           counts: jnp.ndarray | None = None,
                           eps: float = 1e-5,
                           use_pallas: bool | None = None) -> jnp.ndarray:
    """Batched probe: queries [Q, D], boxes [S, L, D] -> int8 [S, Q, L].

    `counts` ([S] int32, optional) gives each shard's number of valid box
    rows; rows at or past the count are forced to 0 in the mask, so the
    caller may pad the slab with arbitrary values (the kernel itself only
    guarantees this for -inf padding).
    """
    s, l, _ = boxes.shape
    if s == 0 or l == 0:
        return jnp.zeros((s, queries.shape[0], l), jnp.int8)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        out = dominance_pallas_3d(queries, boxes, eps,
                                  interpret=jax.default_backend() != "tpu")
    else:
        out = dominance_mask_3d_ref(queries, boxes, eps)
    if counts is not None:
        valid = jnp.arange(l)[None, None, :] < counts[:, None, None]
        out = out * valid.astype(jnp.int8)
    return out


@functools.partial(jax.jit, static_argnames=("eps", "n_iter", "use_pallas"))
def fused_plan_descent_jit(queries: jnp.ndarray, slab: jnp.ndarray,
                           counts: jnp.ndarray, parent: jnp.ndarray,
                           is_root: jnp.ndarray, internal: jnp.ndarray,
                           leaf: jnp.ndarray, pair_valid: jnp.ndarray,
                           *, eps: float, n_iter: int, use_pallas: bool
                           ) -> tuple[jnp.ndarray, ...]:
    """Whole-plan fused descent: dominance + survivorship in ONE launch.

    queries    [Q, D]    all (path, orientation) rows of a query plan,
                         -inf-padded past each path's own width (passes
                         every box dim, so lengths share the launch) and
                         +inf pad rows past the real count.
    slab       [S, R, D] assembled shard planes, -inf pad rows.
    counts     [S]       valid rows per plane.
    parent     [S, R]    packed-parent pointers (self at roots/pads).
    is_root / internal / leaf [S, R]  row-role masks.
    pair_valid [S, Q]    length(plane) == length(query row).

    Returns per-(plane, query-row): candidate count [S, Q], slab row ids
    sorted candidates-first ascending [S, Q, R], and the host traversal's
    nodes_visited / nodes_pruned / leaves_tested counters [S, Q].  Only
    the counts, the leading id columns, and the counters are meant to
    cross back to the host — never a dense ok mask.
    """
    if use_pallas:
        ok8 = dominance_pallas_3d(queries, slab, eps,
                                  interpret=jax.default_backend() != "tpu")
    else:
        ok8 = dominance_mask_3d_ref(queries, slab, eps)
    r = slab.shape[1]
    valid_rows = jnp.arange(r)[None, None, :] < counts[:, None, None]
    ok = ok8.astype(bool) & valid_rows & pair_valid[:, :, None]
    _, anc = survivor_propagation_ref(ok, parent, is_root, n_iter)
    # anc is True at root rows even for pair_valid-gated (plane, query)
    # combinations, so the counters need the gate re-applied — a gated
    # pair was never probed and must report zeros, not its root fan-out
    gate = pair_valid.astype(jnp.int32)
    nodes_visited = (anc & internal[:, None, :]).sum(-1,
                                                     dtype=jnp.int32) * gate
    nodes_pruned = (anc & ~ok & internal[:, None, :]).sum(
        -1, dtype=jnp.int32) * gate
    leaves_tested = (anc & leaf[:, None, :]).sum(-1, dtype=jnp.int32) * gate
    final = anc & ok & leaf[:, None, :]
    n_cand = final.sum(-1, dtype=jnp.int32)
    # compaction: sort each row's ids with non-candidates pushed to the
    # sentinel r, so the leading n_cand VALUES are the candidate rows in
    # ascending order — exactly the host flatnonzero order.  Sorting the
    # id values directly (not argsort) is ~7x faster, and int16 ids
    # halve the readback whenever the sentinel fits the dtype (see
    # readback_id_dtype for the 2**15 widening boundary).
    id_dtype = readback_id_dtype(r)
    row_ids = jnp.arange(r, dtype=id_dtype)[None, None, :]
    order = jnp.sort(jnp.where(final, row_ids, id_dtype(r)), axis=-1)
    return n_cand, order, nodes_visited, nodes_pruned, leaves_tested


def fused_plan_descent(queries, slab, counts, parent, is_root, internal,
                       leaf, pair_valid, eps: float = 1e-5,
                       n_iter: int = 0, use_pallas: bool | None = None):
    """See `fused_plan_descent_jit`; resolves use_pallas=None by backend."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    return fused_plan_descent_jit(queries, slab, counts, parent, is_root,
                                  internal, leaf, pair_valid, eps=eps,
                                  n_iter=n_iter, use_pallas=use_pallas)


# --------------------------------------------------------------------------- #
# megabatch workload launches (multi-query fused probe, PR 4)
# --------------------------------------------------------------------------- #

@functools.partial(jax.jit, static_argnames=("eps", "use_pallas"))
def megabatch_leaf_probe_jit(blocks: tuple, mask_bits: jnp.ndarray,
                             *, eps: float, use_pallas: bool) -> tuple:
    """ONE launch probing every query row of B query plans at once.

    ``blocks`` holds one entry per path length in the megabatch —
    ``(queries [Q_l, D_l], leaves [S_l, N_l, D_l], counts [S_l],
    gverts [S_l, N_l, l+1], mask_rows [Q_l, l+1])`` — and ``mask_bits``
    is the shared ``[B * V_max, W]`` packed candidate-mask operand
    derived from each query's per-vertex label/degree masks.  Splitting
    the slab per length (instead of one dense -inf-padded slab with a
    pair_valid gate) removes the cross-length compute waste: each block
    only compares rows of its own length and width.

    Leaf-only slabs are sufficient for candidates: the aR-tree dominance
    certificate guarantees an ancestor box can never fail for a passing
    leaf, so the whole-tree descent reduces to the leaf's own box test
    (the propagation/counters of `fused_plan_descent` are a traversal
    diagnostic the megabatch path does not ship).

    Returns one ``(final [S_l, Q_l, N_l] bool device-resident, n_cand
    [S_l, Q_l] int32)`` pair per block.  Only the counts are meant to
    cross back; candidate ids ship via `gather_pack_lanes` on the
    candidate-bearing lanes only.
    """
    out = []
    for queries, leaves, counts, gverts, mask_rows in blocks:
        if use_pallas:
            ok = dominance_pallas_3d(
                queries, leaves, eps,
                interpret=jax.default_backend() != "tpu").astype(bool)
            n = leaves.shape[1]
            valid = jnp.arange(n)[None, None, :] < counts[:, None, None]
            final = (ok & valid
                     & packed_mask_pass_ref(gverts, mask_rows, mask_bits))
            out.append((final, final.sum(-1, dtype=jnp.int32)))
        else:
            out.append(megabatch_leaf_probe_ref(
                queries, leaves, counts, gverts, mask_rows, mask_bits,
                eps=eps))
    return tuple(out)


def megabatch_leaf_probe(blocks, mask_bits, eps: float = 1e-5,
                         use_pallas: bool | None = None) -> tuple:
    """See `megabatch_leaf_probe_jit`; resolves use_pallas=None by backend."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    return megabatch_leaf_probe_jit(tuple(tuple(b) for b in blocks),
                                    mask_bits, eps=eps,
                                    use_pallas=use_pallas)


@jax.jit
def gather_pack_lanes_jit(finals: tuple, lane_s: tuple, lane_q: tuple
                          ) -> jnp.ndarray:
    """Gather candidate-bearing (plane, query-row) lanes and bit-pack.

    ``finals`` are the device-resident per-length masks from
    `megabatch_leaf_probe`; ``lane_s[k]`` / ``lane_q[k]`` (int32,
    LANE_BUCKET-padded — pads repeat lane 0 and are dropped on the host)
    select the lanes of block k whose candidate count is nonzero.  Each
    gathered lane is packed 8 leaf rows per byte (little bit order, so
    ``np.unpackbits(..., bitorder="little")`` restores ascending leaf
    ids) and every block is padded to the widest block's byte width.

    The readback therefore scales with the number of lanes that HAVE
    candidates, never with S * Q * N — this plus the in-kernel mask
    filter is what ships megabatch candidate rows pre-filtered.
    """
    n_max = max(int(f.shape[2]) for f in finals)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))[None, None, :]
    packed = []
    for f, ls, lq in zip(finals, lane_s, lane_q):
        rows = f[ls, lq]                                   # [K_b, N_l]
        k_b, n_l = rows.shape
        if n_l < n_max:
            rows = jnp.pad(rows, ((0, 0), (0, n_max - n_l)))
        by = rows.reshape(k_b, n_max // 8, 8).astype(jnp.uint8)
        packed.append((by * weights).sum(-1).astype(jnp.uint8))
    return jnp.concatenate(packed, axis=0)


# --------------------------------------------------------------------------- #
# declared kernel contracts (reprolint RPR001/RPR006 + padding-edge tests)
# --------------------------------------------------------------------------- #

# One entry per jit-boundary callee, keyed by the terminal call name.
# The table is read two ways:
#   * at runtime by tests/test_kernels.py, which drives the padding-edge
#     assertions (pad values really are inert) from these declarations;
#   * by `python -m repro.analysis` (reprolint), which PARSES it from
#     the AST — so every value must stay a literal or a module-constant
#     Name, never a computed expression.
# Fields:
#   caller_bucketed  operand name -> positional index; the CALLER must
#                    round these operands' data-cardinality dims to a
#                    bucket (RPR001).  Callees absent from the table
#                    (e.g. `mega_dispatch`'s qmat/mask_rows, or the
#                    internally padded eager ref paths) bucket for you.
#   blocks           operand -> kernel block size its bucket must divide
#                    into (RPR006 checks bucket % block == 0).
#   buckets          operand -> the bucket constant for that axis.
#   pads             operand -> required pad fill: "+inf" pad rows match
#                    nothing (queries), "-inf" pad rows dominate nothing
#                    (boxes/slabs/leaves) (RPR006 + padding-edge tests).
#   dtypes           operand -> required wire dtype; "uint32" marks the
#                    packed-bit mask operand (RPR006).
#   packed_multiple  operand -> axis divisibility needed by bit packing.
KERNEL_CONTRACTS = {
    # 2-D kernel: pads to its own blocks internally (pl.cdiv), so no
    # bucket % block relation is declared — bucketing the inputs still
    # bounds the jit retraces, hence caller_bucketed.
    "dominance_pallas": dict(
        caller_bucketed=dict(queries=0, boxes=1),
        blocks=dict(queries=BLOCK_Q, boxes=BLOCK_N),
        pads=dict(queries="+inf", boxes="-inf"),
        dtypes=dict(out="int8")),
    "dominance_pallas_3d": dict(
        caller_bucketed=dict(queries=0, boxes=1),
        blocks=dict(queries=BLOCK_S_Q, boxes=BLOCK_S_N),
        buckets=dict(queries=QUERY_BUCKET, boxes=ROW_BUCKET),
        pads=dict(queries="+inf", boxes="-inf"),
        dtypes=dict(out="int8")),
    "batched_dominance_mask": dict(
        caller_bucketed=dict(queries=0, boxes=1, counts=2),
        blocks=dict(queries=BLOCK_S_Q, boxes=BLOCK_S_N),
        buckets=dict(queries=QUERY_BUCKET, boxes=ROW_BUCKET),
        pads=dict(queries="+inf", boxes="-inf"),
        dtypes=dict(out="int8")),
    "fused_plan_descent": dict(
        caller_bucketed=dict(queries=0, slab=1, counts=2, parent=3,
                             is_root=4, internal=5, leaf=6, pair_valid=7),
        blocks=dict(queries=BLOCK_S_Q, slab=BLOCK_S_N),
        buckets=dict(queries=QUERY_BUCKET, slab=ROW_BUCKET),
        pads=dict(queries="+inf", slab="-inf"),
        packed_multiple=dict(slab=8)),
    "fused_plan_descent_jit": dict(
        caller_bucketed=dict(queries=0, slab=1, counts=2, parent=3,
                             is_root=4, internal=5, leaf=6, pair_valid=7),
        blocks=dict(queries=BLOCK_S_Q, slab=BLOCK_S_N),
        buckets=dict(queries=QUERY_BUCKET, slab=ROW_BUCKET),
        pads=dict(queries="+inf", slab="-inf"),
        packed_multiple=dict(slab=8)),
    "megabatch_leaf_probe": dict(
        caller_bucketed=dict(blocks=0, mask_bits=1),
        blocks=dict(queries=BLOCK_S_Q, leaves=BLOCK_S_N),
        buckets=dict(queries=MEGA_QUERY_BUCKET, leaves=ROW_BUCKET,
                     mask_bits=MASK_ROW_BUCKET),
        pads=dict(queries="+inf", leaves="-inf"),
        dtypes=dict(mask_bits="uint32"),
        packed_multiple=dict(leaves=8)),
    "megabatch_leaf_probe_jit": dict(
        caller_bucketed=dict(blocks=0, mask_bits=1),
        blocks=dict(queries=BLOCK_S_Q, leaves=BLOCK_S_N),
        buckets=dict(queries=MEGA_QUERY_BUCKET, leaves=ROW_BUCKET,
                     mask_bits=MASK_ROW_BUCKET),
        pads=dict(queries="+inf", leaves="-inf"),
        dtypes=dict(mask_bits="uint32"),
        packed_multiple=dict(leaves=8)),
    # mega_dispatch buckets qmat/mask_rows itself (mega_query_bucket)
    # but forwards the shared mask operand untouched — the caller owns
    # its row bucket (regression-tested in tests/test_megabatch.py).
    "mega_dispatch": dict(
        caller_bucketed=dict(mask_bits=3),
        buckets=dict(mask_bits=MASK_ROW_BUCKET),
        dtypes=dict(mask_bits="uint32")),
    "gather_pack_lanes_jit": dict(
        caller_bucketed=dict(lane_s=1, lane_q=2),
        buckets=dict(lane_s=LANE_BUCKET, lane_q=LANE_BUCKET),
        packed_multiple=dict(lane_s=8, lane_q=8)),
}
