"""Public wrapper: Pallas on TPU, interpret-mode Pallas elsewhere.

The aR-tree device path (repro/core/artree batched traversal) calls this
for leaf-level filtering when `use_pallas` is on; the CPU dry-run lowers
the pure-jnp reference instead (Mosaic kernels do not compile on the CPU
backend).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dominance.kernel import (dominance_pallas,
                                            dominance_pallas_3d)
from repro.kernels.dominance.ref import (dominance_mask_3d_ref,
                                         dominance_mask_ref)


def dominance_mask(queries: jnp.ndarray, boxes: jnp.ndarray,
                   eps: float = 1e-5, use_pallas: bool | None = None
                   ) -> jnp.ndarray:
    """queries [Q, D], boxes [N, D] -> int8 [Q, N] dominance mask."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return dominance_pallas(queries, boxes, eps,
                                interpret=jax.default_backend() != "tpu")
    return dominance_mask_ref(queries, boxes, eps)


def batched_dominance_mask(queries: jnp.ndarray, boxes: jnp.ndarray,
                           counts: jnp.ndarray | None = None,
                           eps: float = 1e-5,
                           use_pallas: bool | None = None) -> jnp.ndarray:
    """Batched probe: queries [Q, D], boxes [S, L, D] -> int8 [S, Q, L].

    `counts` ([S] int32, optional) gives each shard's number of valid box
    rows; rows at or past the count are forced to 0 in the mask, so the
    caller may pad the slab with arbitrary values (the kernel itself only
    guarantees this for -inf padding).
    """
    s, l, _ = boxes.shape
    if s == 0 or l == 0:
        return jnp.zeros((s, queries.shape[0], l), jnp.int8)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        out = dominance_pallas_3d(queries, boxes, eps,
                                  interpret=jax.default_backend() != "tpu")
    else:
        out = dominance_mask_3d_ref(queries, boxes, eps)
    if counts is not None:
        valid = jnp.arange(l)[None, None, :] < counts[:, None, None]
        out = out * valid.astype(jnp.int8)
    return out
