"""Public wrapper: Pallas on TPU, interpret-mode Pallas elsewhere.

The aR-tree device path (repro/core/artree batched traversal) calls this
for leaf-level filtering when `use_pallas` is on; the CPU dry-run lowers
the pure-jnp reference instead (Mosaic kernels do not compile on the CPU
backend).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dominance.kernel import dominance_pallas
from repro.kernels.dominance.ref import dominance_mask_ref


def dominance_mask(queries: jnp.ndarray, boxes: jnp.ndarray,
                   eps: float = 1e-5, use_pallas: bool | None = None
                   ) -> jnp.ndarray:
    """queries [Q, D], boxes [N, D] -> int8 [Q, N] dominance mask."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return dominance_pallas(queries, boxes, eps,
                                interpret=jax.default_backend() != "tpu")
    return dominance_mask_ref(queries, boxes, eps)
