"""Pure-jnp oracle for the dominance kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def dominance_mask_ref(queries: jnp.ndarray, boxes: jnp.ndarray,
                       eps: float = 1e-5) -> jnp.ndarray:
    """queries [Q, D], boxes [N, D] -> int8 [Q, N]."""
    ok = jnp.all(queries[:, None, :] <= boxes[None, :, :] + eps, axis=-1)
    return ok.astype(jnp.int8)


@jax.jit
def dominance_mask_3d_ref(queries: jnp.ndarray, boxes: jnp.ndarray,
                          eps: float = 1e-5) -> jnp.ndarray:
    """queries [Q, D], boxes [S, L, D] -> int8 [S, Q, L]."""
    ok = jnp.all(queries[None, :, None, :] <= boxes[:, None, :, :] + eps,
                 axis=-1)
    return ok.astype(jnp.int8)


def packed_mask_pass_ref(gverts: jnp.ndarray, mask_rows: jnp.ndarray,
                         mask_bits: jnp.ndarray) -> jnp.ndarray:
    """In-kernel candidate-mask filter over packed per-query vertex masks.

    gverts    [S, N, P] int32  global data-vertex id at each position of
                               every leaf path (pad rows/planes hold 0).
    mask_rows [Q, P]    int32  row of `mask_bits` holding the candidate
                               mask for the query vertex each position of
                               query row q must match (reversed-orientation
                               rows simply carry their positions reversed).
    mask_bits [M, W]    uint32 bit-packed masks: bit (v & 31) of word
                               [m, v >> 5] is mask m at data vertex v.

    Returns bool [S, Q, N]: True iff every position's data vertex passes
    its query vertex's mask — the same AND the host loop computes one
    (path, shard) pair at a time from the dense [V, n_d] masks.
    """
    s, n, p = gverts.shape
    w = mask_bits.shape[1]
    flat = mask_bits.reshape(-1)                     # [M * W]
    pass_all = None
    for i in range(p):
        gv = gverts[:, :, i]                         # [S, N]
        rows = mask_rows[:, i]                       # [Q]
        idx = rows[None, :, None] * w + (gv[:, None, :] >> 5)
        word = jnp.take(flat, idx, axis=0)           # [S, Q, N]
        bit = (word >> (gv[:, None, :] & 31).astype(jnp.uint32)) & 1
        hit = bit.astype(bool)
        pass_all = hit if pass_all is None else pass_all & hit
    return pass_all


def megabatch_leaf_probe_ref(queries: jnp.ndarray, leaves: jnp.ndarray,
                             counts: jnp.ndarray, gverts: jnp.ndarray,
                             mask_rows: jnp.ndarray, mask_bits: jnp.ndarray,
                             eps: float = 1e-5
                             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One length-block of the megabatch probe: leaf dominance + mask.

    By the aR-tree's zero-false-dismissal property a leaf passes the whole
    root-to-leaf descent iff it passes its OWN box test (every ancestor
    upper bound dominates the leaf point), so the megabatch path never
    materializes internal rows or runs survivor propagation: candidates
    are exactly ``dominated & mask_pass`` over the leaf slab.

    queries [Q, D] (+inf pad rows match nothing), leaves [S, N, D] packed
    leaf points (-inf pad rows match nothing), counts [S] valid leaves,
    gverts/mask_rows/mask_bits as in `packed_mask_pass_ref`.

    Returns (final [S, Q, N] bool, n_cand [S, Q] int32).
    """
    ok = jnp.all(queries[None, :, None, :] <= leaves[:, None, :, :] + eps,
                 axis=-1)
    n = leaves.shape[1]
    valid = jnp.arange(n)[None, None, :] < counts[:, None, None]
    final = ok & valid & packed_mask_pass_ref(gverts, mask_rows, mask_bits)
    return final, final.sum(-1, dtype=jnp.int32)


def survivor_propagation_ref(ok: jnp.ndarray, parent: jnp.ndarray,
                             is_root: jnp.ndarray, n_iter: int
                             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Level-order survivor propagation over packed-parent pointers.

    ok [S, Q, R] bool node tests, parent [S, R] int32 (roots and pad rows
    point at themselves), is_root [S, R] bool.  One iteration ANDs every
    row with its parent, so after `n_iter` >= max tree depth iterations
    ``alive[s, q, r]`` is the AND of ok over the row and ALL its
    ancestors; extra iterations are idempotent (callers round n_iter up
    to a bucket to bound jit retraces).  ``anc`` is the same AND over
    *strict* ancestors only (True at roots) — the "candidate before its
    own box test" mask the host traversal's counters are defined on.
    """
    idx = jnp.broadcast_to(parent[:, None, :], ok.shape)
    alive = ok
    for _ in range(n_iter):
        alive = alive & jnp.take_along_axis(alive, idx, axis=-1)
    anc = jnp.where(is_root[:, None, :], True,
                    jnp.take_along_axis(alive, idx, axis=-1))
    return alive, anc
