"""Pure-jnp oracle for the dominance kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def dominance_mask_ref(queries: jnp.ndarray, boxes: jnp.ndarray,
                       eps: float = 1e-5) -> jnp.ndarray:
    """queries [Q, D], boxes [N, D] -> int8 [Q, N]."""
    ok = jnp.all(queries[:, None, :] <= boxes[None, :, :] + eps, axis=-1)
    return ok.astype(jnp.int8)


@jax.jit
def dominance_mask_3d_ref(queries: jnp.ndarray, boxes: jnp.ndarray,
                          eps: float = 1e-5) -> jnp.ndarray:
    """queries [Q, D], boxes [S, L, D] -> int8 [S, Q, L]."""
    ok = jnp.all(queries[None, :, None, :] <= boxes[:, None, :, :] + eps,
                 axis=-1)
    return ok.astype(jnp.int8)


def survivor_propagation_ref(ok: jnp.ndarray, parent: jnp.ndarray,
                             is_root: jnp.ndarray, n_iter: int
                             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Level-order survivor propagation over packed-parent pointers.

    ok [S, Q, R] bool node tests, parent [S, R] int32 (roots and pad rows
    point at themselves), is_root [S, R] bool.  One iteration ANDs every
    row with its parent, so after `n_iter` >= max tree depth iterations
    ``alive[s, q, r]`` is the AND of ok over the row and ALL its
    ancestors; extra iterations are idempotent (callers round n_iter up
    to a bucket to bound jit retraces).  ``anc`` is the same AND over
    *strict* ancestors only (True at roots) — the "candidate before its
    own box test" mask the host traversal's counters are defined on.
    """
    idx = jnp.broadcast_to(parent[:, None, :], ok.shape)
    alive = ok
    for _ in range(n_iter):
        alive = alive & jnp.take_along_axis(alive, idx, axis=-1)
    anc = jnp.where(is_root[:, None, :], True,
                    jnp.take_along_axis(alive, idx, axis=-1))
    return alive, anc
