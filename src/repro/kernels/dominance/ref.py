"""Pure-jnp oracle for the dominance kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def dominance_mask_ref(queries: jnp.ndarray, boxes: jnp.ndarray,
                       eps: float = 1e-5) -> jnp.ndarray:
    """queries [Q, D], boxes [N, D] -> int8 [Q, N]."""
    ok = jnp.all(queries[:, None, :] <= boxes[None, :, :] + eps, axis=-1)
    return ok.astype(jnp.int8)


@jax.jit
def dominance_mask_3d_ref(queries: jnp.ndarray, boxes: jnp.ndarray,
                          eps: float = 1e-5) -> jnp.ndarray:
    """queries [Q, D], boxes [S, L, D] -> int8 [S, Q, L]."""
    ok = jnp.all(queries[None, :, None, :] <= boxes[:, None, :, :] + eps,
                 axis=-1)
    return ok.astype(jnp.int8)
