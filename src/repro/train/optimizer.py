"""Optimizers: Adam / AdamW (pytree-native) + 8-bit blockwise state variant.

No optax in this environment, so the framework carries its own optimizers.
The 8-bit blockwise quantized Adam (Dettmers-style dynamic blockwise
quantization, block=256) is the distributed-optimization trick that makes
deepseek-v3-scale optimizer state fit the per-device HBM budget (see
DESIGN.md §6): m and v are stored int8 + one fp32 scale per 256-block,
dequantized on the fly inside the update.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "adam_init", "adam_update",
    "adamw_init", "adamw_update",
    "Adam8bitState", "adam8bit_init", "adam8bit_update",
    "global_norm", "clip_by_global_norm",
]

Pytree = Any


# --------------------------------------------------------------------------- #
# fp32 Adam / AdamW
# --------------------------------------------------------------------------- #
class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    step: jnp.ndarray


def adam_init(params: Pytree) -> AdamState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamState(mu=z, nu=jax.tree.map(jnp.zeros_like, params),
                     step=jnp.zeros((), jnp.int32))


def adam_update(params: Pytree, grads: Pytree, state: AdamState, *,
                lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8, weight_decay: float = 0.0
                ) -> tuple[Pytree, AdamState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return new_p, m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(new_mu, new_nu, step)


def adamw_init(params: Pytree) -> AdamState:
    return adam_init(params)


def adamw_update(params, grads, state, *, lr=1e-3, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    return adam_update(params, grads, state, lr=lr, b1=b1, b2=b2, eps=eps,
                       weight_decay=weight_decay)


# --------------------------------------------------------------------------- #
# 8-bit blockwise Adam
# --------------------------------------------------------------------------- #
_BLOCK = 256


class Adam8bitState(NamedTuple):
    """m/v stored as parallel trees of int8 codes + per-block fp32 scales.

    Four trees, each mirroring the param tree exactly (array leaves only),
    so every jax.tree.map over (params, grads, state...) is structure-safe.
    """
    mu_codes: Pytree        # int8  [ceil(n/256)*256]
    mu_scales: Pytree       # f32   [ceil(n/256)]
    nu_codes: Pytree
    nu_scales: Pytree
    step: jnp.ndarray


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    n_pad = ((n + _BLOCK - 1) // _BLOCK) * _BLOCK
    flat = jnp.pad(flat, (0, n_pad - n))
    blocks = flat.reshape(-1, _BLOCK)
    scales = jnp.max(jnp.abs(blocks), axis=1) + 1e-12
    codes = jnp.clip(jnp.round(blocks / scales[:, None] * 127.0),
                     -127, 127).astype(jnp.int8)
    return codes.reshape(-1), scales


def _dequantize(codes: jnp.ndarray, scales: jnp.ndarray,
                shape: tuple) -> jnp.ndarray:
    blocks = codes.reshape(-1, _BLOCK).astype(jnp.float32)
    flat = (blocks * (scales[:, None] / 127.0)).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def adam8bit_init(params: Pytree) -> Adam8bitState:
    qz = jax.tree.map(lambda p: _quantize(jnp.zeros(p.shape, jnp.float32)),
                      params)
    codes = jax.tree.map(lambda q: q[0], qz,
                         is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda q: q[1], qz,
                          is_leaf=lambda x: isinstance(x, tuple))
    return Adam8bitState(mu_codes=codes, mu_scales=scales,
                         nu_codes=jax.tree.map(jnp.copy, codes),
                         nu_scales=jax.tree.map(jnp.copy, scales),
                         step=jnp.zeros((), jnp.int32))


def adam8bit_update(params: Pytree, grads: Pytree, state: Adam8bitState, *,
                    lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
                    eps: float = 1e-8, weight_decay: float = 0.0
                    ) -> tuple[Pytree, Adam8bitState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, mc, ms, vc, vs):
        g = g.astype(jnp.float32)
        m = b1 * _dequantize(mc, ms, p.shape) + (1 - b1) * g
        v = b2 * _dequantize(vc, vs, p.shape) + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        new_p = (p.astype(jnp.float32)
                 - lr * (mh / (jnp.sqrt(vh) + eps)
                         + weight_decay * p.astype(jnp.float32))
                 ).astype(p.dtype)
        nmc, nms = _quantize(m)
        nvc, nvs = _quantize(v)
        return new_p, nmc, nms, nvc, nvs

    out = jax.tree.map(upd, params, grads, state.mu_codes, state.mu_scales,
                       state.nu_codes, state.nu_scales)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), Adam8bitState(pick(1), pick(2), pick(3), pick(4), step)


# --------------------------------------------------------------------------- #
# gradient utilities
# --------------------------------------------------------------------------- #
def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: Pytree, max_norm: float) -> tuple[Pytree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm
