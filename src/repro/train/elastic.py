"""Elastic-scaling + failure/straggler handling for the cluster runtime.

Two layers of fault tolerance (DESIGN.md §6):

1. **Training jobs** (TPU mesh): step-atomic checkpoints (checkpoint.py)
   + `simulate_failure_and_restore` which kills a run mid-flight and
   proves bit-exact resume; elastic re-shard = re-lower the same step on a
   smaller mesh (the dry-run proves each mesh compiles — see tests).

2. **Query cluster** (the paper's n<=50 machines): `WorkerFailover`
   re-routes a dead machine's shards to survivors via Algorithm-1
   migration from replicas, and `StragglerMitigator` re-issues shard
   probes whose virtual latency exceeds a deadline multiplier — the
   standard speculative-execution trick.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = ["WorkerFailover", "StragglerMitigator",
           "simulate_failure_and_restore"]


@dataclasses.dataclass
class WorkerFailover:
    """Shard-level failover: on machine death, reassign its shards.

    Thin compatibility facade over the engine's crash-consistent
    `handle_machine_failure` transaction (the engine owns placement,
    quorum checks, cache purges and replica promotion — see
    repro.dist.cluster and docs/robustness.md).  Raises the typed
    ClusterUnavailableError (a RuntimeError) on genuine quorum loss.
    """

    engine: Any                       # DistributedGNNPE
    dead: set = dataclasses.field(default_factory=set)

    def fail_machine(self, machine_id: int) -> list[int]:
        """Kill one machine; return the re-homed shard ids."""
        self.dead.add(machine_id)
        try:
            return self.engine.handle_machine_failure(machine_id)
        finally:
            self.dead |= self.engine.dead_machines

    def verify_exactness(self, queries, oracle_fn) -> bool:
        """Post-failover results must still be exact."""
        for q in queries:
            matches, _ = self.engine.query(q)
            if set(matches) != oracle_fn(q):
                return False
        return True


@dataclasses.dataclass
class StragglerMitigator:
    """Deadline-based re-issue of slow shard probes (speculation).

    In the simulator a straggler is a machine whose virtual service time is
    inflated by `slow_factor`; probes slower than `deadline_x` x median are
    re-issued against the replica on the fastest machine and the first
    result wins.  Telemetry records how much tail latency was recovered.
    """

    deadline_x: float = 3.0
    reissued: int = 0
    recovered_ms: float = 0.0

    def probe_with_speculation(self, latencies_ms: dict[int, float]
                               ) -> dict[int, float]:
        """latencies per machine -> effective latencies after speculation."""
        if not latencies_ms:
            return {}
        med = float(np.median(list(latencies_ms.values())))
        fastest = min(latencies_ms.values())
        out = {}
        for k, v in latencies_ms.items():
            if v > self.deadline_x * med:
                # re-issue on fastest survivor: pay deadline + fast retry
                eff = self.deadline_x * med + fastest
                if eff < v:
                    self.reissued += 1
                    self.recovered_ms += v - eff
                    out[k] = eff
                    continue
            out[k] = v
        return out


def simulate_failure_and_restore(trainer_factory, batches, fail_at: int,
                                 total_steps: int, ckpt_dir: str):
    """Train to fail_at, 'crash', rebuild from scratch, finish; returns
    (history_before, history_after) — the resumed run continues from the
    last checkpoint (bit-exact params thanks to CRC-verified restore)."""
    t1 = trainer_factory(ckpt_dir)
    h1 = t1.fit(batches, n_steps=fail_at)
    del t1                                    # crash
    t2 = trainer_factory(ckpt_dir)            # restore_latest inside
    assert t2.step > 0, "restore failed to pick up checkpoint"
    h2 = t2.fit(batches, n_steps=total_steps)
    return h1, h2
