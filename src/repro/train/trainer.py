"""Generic training loop: microbatch accumulation, clip, checkpoint, logs.

Works for any (params, opt, batch)->(params, opt, metrics) step — the LM,
GNN, and recsys families all build their steps through this module when
trained for real (examples/, launch/train.py); the dry-run lowers the same
step functions without executing them.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.train.checkpoint import restore_latest, save_checkpoint
from repro.train.optimizer import (adamw_init, adamw_update,
                                   clip_by_global_norm)

__all__ = ["TrainConfig", "Trainer", "make_accum_step"]


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 200
    lr: float = 3e-4
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    accum_steps: int = 1
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10


def make_accum_step(loss_fn: Callable, opt_update: Callable,
                    clip_norm: float = 1.0, accum_steps: int = 1):
    """(params, opt, batch) step with gradient accumulation over microbatches.

    batch leaves must have a leading dim divisible by accum_steps.
    """

    def step(params, opt, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(i, carry):
                gsum, lsum = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum_steps),
                        x.shape[0] // accum_steps), batch)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l)

            zeros = jax.tree.map(jnp.zeros_like, params)
            grads, loss = jax.lax.fori_loop(
                0, accum_steps, micro, (zeros, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt = opt_update(params, grads, opt)
        return params, opt, {"loss": loss, "grad_norm": gnorm}

    return step


class Trainer:
    """Checkpointed training loop with restart-from-latest-valid."""

    def __init__(self, loss_fn: Callable, params: Any,
                 cfg: TrainConfig = TrainConfig(),
                 opt_init=adamw_init, opt_update=None) -> None:
        self.cfg = cfg
        ou = opt_update or (lambda p, g, o: adamw_update(
            p, g, o, lr=cfg.lr, weight_decay=cfg.weight_decay))
        self.step_fn = jax.jit(make_accum_step(
            loss_fn, ou, cfg.clip_norm, cfg.accum_steps))
        self.params = params
        self.opt = opt_init(params)
        self.step = 0
        self.history: list[dict[str, float]] = []
        if cfg.ckpt_dir:
            restored = restore_latest(cfg.ckpt_dir,
                                      (self.params, self.opt))
            if restored is not None:
                (self.params, self.opt), manifest = restored
                self.step = int(manifest["step"])

    def fit(self, batches: Iterator[Any], n_steps: int | None = None
            ) -> list[dict[str, float]]:
        n = n_steps or self.cfg.n_steps
        t0 = time.time()
        while self.step < n:
            batch = next(batches)
            batch = jax.tree.map(jnp.asarray, batch)
            self.params, self.opt, metrics = self.step_fn(
                self.params, self.opt, batch)
            self.step += 1
            if self.step % self.cfg.log_every == 0 or self.step == n:
                rec = {k: float(v) for k, v in metrics.items()}
                rec["step"] = self.step
                rec["wall_s"] = time.time() - t0
                self.history.append(rec)
            if (self.cfg.ckpt_dir and
                    (self.step % self.cfg.ckpt_every == 0
                     or self.step == n)):
                save_checkpoint(self.cfg.ckpt_dir, self.step,
                                (self.params, self.opt))
        return self.history
