"""Step-atomic checkpointing with CRC32 manifests + restore-latest-valid.

The paper's CRC32 integrity mechanism (Algorithm 1) is reused for training
checkpoints: every array is serialized with a CRC32 in a manifest; restore
verifies each array and falls back to the newest fully-valid checkpoint —
this is the node-failure recovery path (a restarted worker re-joins from
the last durable step; the data cursor and RNG state ride along, so the
token stream resumes exactly).

Layout:  <dir>/step_000123/{manifest.json, arrays.npz}   (tmp+rename —
the directory is atomic: a crash mid-write never corrupts older steps).
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_latest", "list_checkpoints",
           "CheckpointError"]


class CheckpointError(RuntimeError):
    pass


def _flatten(tree: Any) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: dict | None = None) -> str:
    """Atomically persist `state` (any pytree) at `step`."""
    leaves, treedef = _flatten(state)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:09d}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    arrays = {f"a{i}": leaf for i, leaf in enumerate(leaves)}
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "crc": {f"a{i}": zlib.crc32(leaf.tobytes()) & 0xFFFFFFFF
                for i, leaf in enumerate(leaves)},
        "shapes": {f"a{i}": list(leaf.shape) for i, leaf in enumerate(leaves)},
        "dtypes": {f"a{i}": str(leaf.dtype) for i, leaf in enumerate(leaves)},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_checkpoints(ckpt_dir: str) -> list[tuple[int, str]]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            out.append((int(name[5:]), os.path.join(ckpt_dir, name)))
    return sorted(out)


def _load_and_verify(path: str, template: Any) -> tuple[Any, dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    leaves_t, treedef = jax.tree.flatten(template)
    if manifest["n_leaves"] != len(leaves_t):
        raise CheckpointError("leaf-count mismatch vs template")
    leaves = []
    for i in range(manifest["n_leaves"]):
        arr = z[f"a{i}"]
        crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
        if crc != manifest["crc"][f"a{i}"]:
            raise CheckpointError(f"CRC mismatch on leaf {i}")
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves), manifest


def restore_latest(ckpt_dir: str, template: Any
                   ) -> tuple[Any, dict] | None:
    """Newest checkpoint that passes full CRC verification (or None).

    Corrupt checkpoints are skipped (the failure-recovery path), not
    deleted — operators can inspect them.
    """
    for step, path in reversed(list_checkpoints(ckpt_dir)):
        try:
            state, manifest = _load_and_verify(path, template)
            return state, manifest
        except Exception:  # noqa: BLE001 — any unreadable ckpt is skipped
            continue
    return None
