"""Logical-axis sharding rules for the JAX model zoo.

Models annotate arrays with *logical* axis names ("batch", "heads",
"edges", ...) via `constrain`; a process-wide rule table maps logical
names to mesh axes ("data", "model", ("pod", "data"), or None for
replicated).  The launch layer installs rules + mesh per run
(`set_rules`/`set_mesh`, or scoped with `rules_ctx`), so the same model
code lowers correctly on a laptop (no mesh: every constrain is a no-op)
and on a multi-pod production mesh.

Rule values are mesh-axis names (str), tuples of names for axes sharded
over several mesh dims (e.g. ("pod", "data") data-parallel batch), or
None for replication.  Unknown logical names map to None.
"""

from __future__ import annotations

import contextlib
from typing import Any, Iterator, Mapping

import jax
from jax.sharding import NamedSharding, PartitionSpec

__all__ = ["set_rules", "set_mesh", "clear_rules", "current_mesh",
           "current_rules", "rules_ctx", "spec_for", "constrain",
           "shard_map", "GNN_RULES", "LM_RULES", "RECSYS_RULES"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              **kwargs):
    """Version-portable `shard_map` for the model zoo.

    Newer JAX exposes `jax.shard_map(..., check_vma=...)`; older releases
    ship `jax.experimental.shard_map.shard_map(..., check_rep=...)`.
    """
    impl = getattr(jax, "shard_map", None)
    if impl is not None:
        return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=check_vma, **kwargs)
    from jax.experimental.shard_map import shard_map as impl
    return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma, **kwargs)

# ------------------------------------------------------------------ #
# rule presets per model family (the launch layer rewrites the
# ("pod", "data") placeholders to the ambient data-parallel axes)
# ------------------------------------------------------------------ #
LM_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "kv_len": None,
    "experts": "model",
    "rows": "model",
}

GNN_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    "embed": None,
    "d_ff": None,
    "heads": None,
}

RECSYS_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "d_ff": "model",
    "rows": "model",
    "vocab": "model",
    "cands": None,
}

_rules: dict[str, Any] = {}
_mesh: Any = None


def set_rules(rules: Mapping[str, Any]) -> None:
    """Install the process-wide logical->mesh axis mapping."""
    global _rules
    _rules = dict(rules)


def set_mesh(mesh: Any) -> None:
    """Install the ambient mesh consulted by `constrain`."""
    global _mesh
    _mesh = mesh


def clear_rules() -> None:
    """Drop both the rule table and the ambient mesh."""
    global _rules, _mesh
    _rules = {}
    _mesh = None


def current_mesh() -> Any:
    return _mesh


def current_rules() -> dict[str, Any]:
    return dict(_rules)


@contextlib.contextmanager
def rules_ctx(rules: Mapping[str, Any]) -> Iterator[None]:
    """Scoped rule table (restores the previous table on exit)."""
    global _rules
    prev = _rules
    _rules = dict(rules)
    try:
        yield
    finally:
        _rules = prev


def spec_for(*names: str | None) -> PartitionSpec:
    """PartitionSpec for a sequence of logical axis names."""
    return PartitionSpec(
        *[_rules.get(n) if n is not None else None for n in names])


def _axis_size(mesh: Any, entry: Any) -> int:
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    return size


def constrain(x: jax.Array, *names: str | None) -> jax.Array:
    """Apply a sharding constraint by logical axis names.

    No-op when no real mesh is ambient, when every named axis maps to
    None, or when a mapped mesh axis is absent / does not divide the
    corresponding array dimension (the constraint is a layout *hint* —
    dropping it is always semantically safe).
    """
    mesh = _mesh
    if mesh is None or not isinstance(mesh, jax.sharding.Mesh):
        return x
    entries = [_rules.get(n) if n is not None else None for n in names]
    if all(e is None for e in entries):
        return x
    cleaned = []
    for dim, entry in zip(x.shape, entries):
        if entry is None:
            cleaned.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if any(a not in mesh.shape for a in axes):
            cleaned.append(None)
            continue
        if int(dim) % _axis_size(mesh, entry) != 0:
            cleaned.append(None)
            continue
        cleaned.append(entry)
    if all(e is None for e in cleaned):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*cleaned)))
