"""Deterministic chaos harness: seeded fault schedules + typed failures.

The paper's headline distributed guarantee — minimum edge cut + load
balancing + **non-interruptible queries** — is only worth anything if it
survives *arbitrary* interleavings of machine crashes, corrupted
transfers, link timeouts and torn delta images.  This module is the
injection half of that proof obligation:

  * a :class:`FaultPlan` is a fully deterministic, seeded schedule of
    :class:`FaultSpec` events, each anchored to a *named hook point*
    (``HOOK_*`` below) and a hook-visit index.  The engine and the
    migration link consult the plan at every hook; with no plan attached
    every hook is a no-op, so the fault-free path pays nothing.
  * the ONLY randomness a fault may consume is ``FaultPlan.rng`` — never
    the engine rng threaded through ``crc_transfer`` — so a chaos run
    and its fault-free twin draw *identical* engine rng streams and stay
    bit-comparable (reprolint rule RPR007 enforces this statically).
  * typed failures: :class:`TransferTimeoutError` (a transfer exhausted
    its retry/backoff budget; the surrounding transaction must abort
    fully-old) and :class:`ClusterUnavailableError` (quorum genuinely
    lost: no live machine, or a shard's last copy died).  A wrong or
    partial answer is never an acceptable outcome — the chaos oracle
    (`run_script` + tests/test_chaos.py) asserts every query is
    bit-identical to the fault-free run OR one of these errors is
    raised.

Hook-point map (where the engine/link fires each hook):

  ==========================  =============================================
  hook                        fired at
  ==========================  =============================================
  HOOK_QUERY                  start of every ``DistributedGNNPE.query``
  HOOK_BATCH                  between megabatch dispatch and consume
  HOOK_UPDATE_STAGE           before each staged shard's delta transfer
  HOOK_UPDATE_COMMIT          just before ``apply_updates`` commits
  HOOK_REBALANCE              before a rebalance migration batch executes
  HOOK_MIGRATE_PREPARE        before each shard's prepare-phase transfer
  HOOK_TRANSFER               every simulated link transfer attempt
  HOOK_READ                   every routed shard read (``ShardRouter.read``)
  ==========================  =============================================

Engine hooks (``cluster.*``) accept CRASH events — the engine reacts by
running crash-consistent failover.  Link hooks (``migration.*`` and
``router.read``) accept CORRUPT / TIMEOUT / SLOW / TORN events, applied
to the in-flight bytes (for routed reads: the probe RPC — SLOW/TIMEOUT
stall an attempt and trigger the hedge/retry budget in
``repro.dist.router``; CORRUPT/TORN are caught by the same CRC-retry
discipline and simply cost a retransmission).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CRASH", "CORRUPT", "TIMEOUT", "SLOW", "TORN", "FAULT_KINDS",
           "HOOK_QUERY", "HOOK_BATCH", "HOOK_UPDATE_STAGE",
           "HOOK_UPDATE_COMMIT", "HOOK_REBALANCE", "HOOK_MIGRATE_PREPARE",
           "HOOK_TRANSFER", "HOOK_READ", "ENGINE_HOOKS", "LINK_HOOKS",
           "ALL_HOOKS", "ClusterUnavailableError", "TransferTimeoutError",
           "FaultSpec", "FaultPlan", "random_fault_plan", "Unavailable",
           "default_script", "run_script", "script_queries"]

# ---------------------------------------------------------------------- #
# fault taxonomy
# ---------------------------------------------------------------------- #
CRASH = "crash"        # a machine dies (engine hooks only)
CORRUPT = "corrupt"    # one in-flight byte flipped (CRC catches it)
TIMEOUT = "timeout"    # the transfer attempt is lost entirely
SLOW = "slow"          # the link runs `factor` x slower for the attempt
TORN = "torn"          # the image arrives truncated (CRC catches it)
FAULT_KINDS = (CRASH, CORRUPT, TIMEOUT, SLOW, TORN)

# named hook points (see the module docstring's map)
HOOK_QUERY = "cluster.query"
HOOK_BATCH = "cluster.megabatch"
HOOK_UPDATE_STAGE = "cluster.updates.stage"
HOOK_UPDATE_COMMIT = "cluster.updates.commit"
HOOK_REBALANCE = "cluster.rebalance"
HOOK_MIGRATE_PREPARE = "migration.prepare"
HOOK_TRANSFER = "migration.transfer"
HOOK_READ = "router.read"

ENGINE_HOOKS = (HOOK_QUERY, HOOK_BATCH, HOOK_UPDATE_STAGE,
                HOOK_UPDATE_COMMIT, HOOK_REBALANCE)
LINK_HOOKS = (HOOK_MIGRATE_PREPARE, HOOK_TRANSFER, HOOK_READ)
ALL_HOOKS = ENGINE_HOOKS + LINK_HOOKS


class ClusterUnavailableError(RuntimeError):
    """Quorum genuinely lost: no live machine remains, or some shard's
    last copy (primary + every replica) is on dead machines.  The ONLY
    acceptable alternative to a bit-identical answer — never a wrong or
    partial result.  ``reason`` is machine-checkable for the oracle;
    ``sids``/``machines`` name the shards whose every copy is dead and
    the dead machines involved, so callers can assert *which* quorum was
    lost (and the router can prove a live copy really did not exist)."""

    def __init__(self, message: str, reason: str = "",
                 sids: "tuple | list" = (),
                 machines: "tuple | list" = ()) -> None:
        super().__init__(message)
        self.reason = reason
        self.sids = tuple(sids)
        self.machines = tuple(machines)


class TransferTimeoutError(RuntimeError):
    """A link transfer exhausted its retry/backoff budget.  The
    transaction that issued the transfer must abort fully-old (nothing
    installed, no routing/epoch/cache mutation); callers may retry the
    whole operation."""

    def __init__(self, message: str, virtual_ms: float = 0.0,
                 attempts: int = 0) -> None:
        super().__init__(message)
        self.virtual_ms = virtual_ms
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class Unavailable:
    """Per-query answer slot for a typed failure in a degraded-mode
    script run (``run_script(on_unavailable="continue")``).  Records the
    structured fields of the :class:`ClusterUnavailableError` (or
    admission rejection) the query raised, so the availability oracle
    can assert the loss was genuine for exactly those shards while the
    rest of the script keeps serving bit-identical answers."""

    reason: str = ""
    sids: tuple = ()
    machines: tuple = ()


# ---------------------------------------------------------------------- #
# fault schedule
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    Fires on hook visits ``at .. at+times-1`` (1-based, counted per hook
    name over the plan's lifetime).  ``machine`` targets a CRASH (None =
    the plan rng picks a live machine at fire time); ``factor`` scales a
    SLOW attempt's virtual transfer time.
    """

    kind: str
    hook: str
    at: int = 1
    times: int = 1
    machine: int | None = None
    factor: float = 4.0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.hook not in ALL_HOOKS:
            raise ValueError(f"unknown hook {self.hook!r}")
        if self.kind == CRASH and self.hook not in ENGINE_HOOKS:
            raise ValueError("CRASH faults fire at engine hooks only")
        if self.kind != CRASH and self.hook not in LINK_HOOKS:
            raise ValueError(f"{self.kind} faults fire at link hooks only")
        if self.at < 1 or self.times < 1:
            raise ValueError("at/times are 1-based positive counts")


class FaultPlan:
    """A deterministic seeded fault schedule.

    ``rng`` is the one and only randomness source chaos handling may
    draw from (RPR007): corruption byte positions, torn-image cut
    points, and unpinned crash targets all come from here, so the
    engine rng stream stays identical to the fault-free run's.
    """

    def __init__(self, faults: "tuple | list" = (), seed: int = 0) -> None:
        self.faults = tuple(faults)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._visits: dict[str, int] = {}
        self.fired: list[tuple[str, int, FaultSpec]] = []

    def visits(self, hook: str) -> int:
        return self._visits.get(hook, 0)

    def fire(self, hook: str) -> list[FaultSpec]:
        """Advance the hook's visit counter and return the faults due."""
        n = self._visits.get(hook, 0) + 1
        self._visits[hook] = n
        due = [f for f in self.faults
               if f.hook == hook and f.at <= n < f.at + f.times]
        self.fired.extend((hook, n, f) for f in due)
        return due

    def replay(self) -> "FaultPlan":
        """A fresh plan with the same schedule and rng seed (visit
        counters and the rng stream rewound) — for re-running the same
        chaos schedule against another engine."""
        return FaultPlan(self.faults, seed=self.seed)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FaultPlan(seed={self.seed}, faults={list(self.faults)})"


def random_fault_plan(seed: int, n_faults: int = 4, n_machines: int = 3,
                      max_crashes: int | None = None,
                      horizon: int = 12) -> FaultPlan:
    """A seeded random schedule over every fault kind and hook.

    ``max_crashes`` bounds CRASH events (default ``n_machines - 1`` so
    the cluster stays available; pass ``n_machines`` or more to exercise
    the genuine-quorum-loss path).  ``horizon`` bounds the hook-visit
    indices faults anchor to.  Same seed -> same schedule, always.
    """
    rng = np.random.default_rng(seed)
    if max_crashes is None:
        max_crashes = max(n_machines - 1, 0)
    link_kinds = (CORRUPT, TIMEOUT, SLOW, TORN)
    faults: list[FaultSpec] = []
    crashes = 0
    for _ in range(n_faults):
        roll = float(rng.random())
        if roll < 0.4 and crashes < max_crashes:
            crashes += 1
            faults.append(FaultSpec(
                kind=CRASH,
                hook=ENGINE_HOOKS[int(rng.integers(len(ENGINE_HOOKS)))],
                at=int(rng.integers(1, horizon + 1)),
                machine=int(rng.integers(n_machines))))
        else:
            kind = link_kinds[int(rng.integers(len(link_kinds)))]
            hook = (HOOK_TRANSFER if kind != CRASH else HOOK_TRANSFER)
            faults.append(FaultSpec(
                kind=kind, hook=hook,
                at=int(rng.integers(1, 4 * horizon + 1)),
                times=int(rng.integers(1, 3)),
                factor=float(2.0 + 6.0 * rng.random())))
    return FaultPlan(faults, seed=seed)


# ---------------------------------------------------------------------- #
# chaos oracle runner: deterministic op scripts
# ---------------------------------------------------------------------- #
def default_script(graph, seed: int, n_queries: int = 6,
                   modes: tuple = ("host", "device", "plane"),
                   with_batch: bool = True, with_update: bool = True,
                   with_epoch: bool = True) -> list:
    """A deterministic workload script for `run_script`.

    Interleaves gauntlet-flavoured queries (shape queries where minable,
    random-walk queries otherwise) with a streaming update, a megabatch
    op and a rebalance epoch — the surfaces the fault schedule attacks.
    Same (graph, seed) -> same script, so the fault-free reference and
    every chaos run execute identical operations.
    """
    from repro.core.graph import GraphDelta
    from repro.data.synthetic import make_workload, shape_query
    rng = np.random.default_rng(seed * 977 + 11)
    queries = list(make_workload(graph, n_queries, seed=seed * 31 + 7))
    for shape in ("triangle_tail", "star"):
        try:
            queries.append(shape_query(graph, shape, "dense",
                                       seed=seed % 5 + 1))
        except ValueError:
            pass  # shape absent from this topology: covered elsewhere
    ops: list = []
    qi = 0
    for q in queries[:max(n_queries // 2, 2)]:
        ops.append(("query", q, modes[qi % len(modes)]))
        qi += 1
    if with_update:
        n = graph.n_vertices
        adds = []
        while len(adds) < 2:
            u, v = (int(x) for x in rng.integers(0, n, size=2))
            if u != v and not graph.has_edge(u, v):
                adds.append((u, v))
        del_e = graph.edge_list[int(rng.integers(graph.n_edges))]
        ops.append(("update", GraphDelta.make(add_edges=adds,
                                              del_edges=[del_e])))
    for q in queries[max(n_queries // 2, 2):]:
        ops.append(("query", q, modes[qi % len(modes)]))
        qi += 1
    if with_batch:
        ops.append(("batch", queries[:3]))
    if with_epoch:
        ops.append(("epoch", queries[:4], "plane", 2))
    return ops


def script_queries(ops: list) -> int:
    """Number of per-query answers `run_script` emits for a script."""
    n = 0
    for op in ops:
        if op[0] == "query":
            n += 1
        elif op[0] in ("batch", "epoch"):
            n += len(op[1])
    return n


def _one_query(engine, q, probe_mode: str, as_count: bool):
    """One routed query in degraded-continue mode: the bit-identical
    answer, or an :class:`Unavailable` slot carrying the typed loss."""
    try:
        m, tel = engine.query(q, probe_mode=probe_mode)
    except ClusterUnavailableError as exc:
        return Unavailable(exc.reason, exc.sids, exc.machines)
    return int(tel.n_matches) if as_count else list(m)


def run_script(engine, ops: list, plan: "FaultPlan | None" = None,
               max_op_retries: int = 4, audit: bool = True,
               on_unavailable: str = "stop") -> tuple[list, str]:
    """Execute a deterministic op script, optionally under a FaultPlan.

    Returns ``(answers, outcome)``:

      * ``answers`` — one entry per query: the full match list for
        ``query``/``batch`` ops, the deterministic ``n_matches`` counter
        for ``epoch`` ops (``run_workload`` returns telemetry only).
      * ``outcome`` — ``"completed"``, or ``"unavailable@<i>"`` when op
        ``i`` raised :class:`ClusterUnavailableError` (the oracle then
        checks the loss was genuine and the answer prefix bit-identical).

    ``on_unavailable`` selects the failure discipline:

      * ``"stop"`` (PR-8 behaviour) — the first typed unavailability ends
        the script; the oracle checks the answer *prefix*.
      * ``"continue"`` (degraded-mode serving) — a query that raises the
        typed error contributes an :class:`Unavailable` slot and the
        script keeps going; a failed ``batch``/``epoch`` op falls back to
        per-query serial execution (bit-identical by the cross-mode
        contract), so only the queries whose own shards lost every copy
        degrade to typed slots.  A failed ``update`` op still stops the
        script: the baseline applied it, so later answers could not be
        compared.

    Transactions aborted by :class:`TransferTimeoutError` are retried up
    to ``max_op_retries`` times — the abort left the engine fully-old,
    so a retry is safe; one-shot faults won't re-fire.  With ``audit``
    the engine's ``consistency_audit`` must be clean after every op
    (zero torn state).
    """
    if on_unavailable not in ("stop", "continue"):
        raise ValueError(f"unknown on_unavailable {on_unavailable!r}")
    if plan is not None:
        engine.set_fault_plan(plan)
    answers: list = []
    outcome = "completed"
    try:
        for i, op in enumerate(ops):
            kind = op[0]
            try:
                if kind == "query":
                    if on_unavailable == "continue":
                        answers.append(_one_query(engine, op[1], op[2],
                                                  as_count=False))
                    else:
                        m, _ = engine.query(op[1], probe_mode=op[2])
                        answers.append(list(m))
                elif kind == "batch":
                    try:
                        for m, _ in engine.query_batch(list(op[1])):
                            answers.append(list(m))
                    except ClusterUnavailableError:
                        if on_unavailable == "stop":
                            raise
                        # per-shard degradation: re-issue each batch
                        # member serially so only the queries whose own
                        # shards lost quorum degrade to typed slots
                        answers.extend(_one_query(engine, q, "plane",
                                                  as_count=False)
                                       for q in op[1])
                elif kind == "update":
                    for _ in range(max_op_retries):
                        try:
                            engine.apply_updates(op[1], refit_pe=False)
                            break
                        except TransferTimeoutError:
                            continue  # aborted fully-old: retry is safe
                    else:
                        raise TransferTimeoutError(
                            f"op {i}: update kept timing out after "
                            f"{max_op_retries} attempts")
                elif kind == "epoch":
                    try:
                        tels = engine.run_workload(list(op[1]),
                                                   rebalance=True,
                                                   probe_mode=op[2],
                                                   batch_size=op[3])
                        answers.extend(int(t.n_matches) for t in tels)
                    except ClusterUnavailableError:
                        if on_unavailable == "stop":
                            raise
                        answers.extend(_one_query(engine, q, op[2],
                                                  as_count=True)
                                       for q in op[1])
                else:
                    raise ValueError(f"unknown op kind {kind!r}")
            except ClusterUnavailableError:
                outcome = f"unavailable@{i}"
                break
            if audit and getattr(engine, "_unavailable", None) is None:
                bad = engine.consistency_audit()
                assert not bad, f"torn state after op {i}: {bad}"
    finally:
        if plan is not None:
            engine.set_fault_plan(None)
    return answers, outcome
