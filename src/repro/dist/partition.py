"""Graph partitioning for ultra-fine shards (paper §4.2.1).

The paper partitions the data graph into n_machines x shards_per_machine
ultra-fine shards with a METIS objective: minimum edge cut under a size
balance constraint.  METIS itself is not available offline, so
`metis_like_partition` reimplements the two ingredients that carry the
claim (30-40% fewer cross-shard edges than random, balance <= 15%):

  1. greedy graph growing — BFS regions of target size seeded in
     unassigned territory (the classic GGGP coarse phase);
  2. boundary refinement — Fiduccia-Mattheyses-style single-vertex moves
     that reduce the cut while staying inside the balance envelope.

`random_partition` and `hash_partition` are the benchmark baselines.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.graph import LabeledGraph

__all__ = ["Partition", "metis_like_partition", "random_partition",
           "hash_partition", "edge_cut", "size_balance"]

# balance envelope: no part may exceed (1 + BALANCE_EPS) x average size
BALANCE_EPS = 0.12


@dataclasses.dataclass(frozen=True)
class Partition:
    """assignment[v] = part id of vertex v."""

    assignment: np.ndarray      # int32 [n]
    n_parts: int

    def sizes(self) -> np.ndarray:
        return np.bincount(self.assignment, minlength=self.n_parts)


def _assignment_of(p) -> np.ndarray:
    return p.assignment if isinstance(p, Partition) else np.asarray(p)


def edge_cut(graph: LabeledGraph, p) -> int:
    """Number of undirected edges whose endpoints live in different parts."""
    a = _assignment_of(p)
    e = graph.edge_list
    if e.size == 0:
        return 0
    return int((a[e[:, 0]] != a[e[:, 1]]).sum())


def size_balance(p) -> float:
    """max part size / mean part size - 1 (paper reports <= 15%)."""
    if isinstance(p, Partition):
        sizes = p.sizes()
    else:
        a = np.asarray(p)
        sizes = np.bincount(a, minlength=int(a.max()) + 1 if a.size else 1)
    mean = sizes.mean() if sizes.size else 1.0
    return float(sizes.max() / max(mean, 1e-9) - 1.0)


def random_partition(graph: LabeledGraph, n_parts: int,
                     seed: int = 0) -> Partition:
    rng = np.random.default_rng(seed)
    a = rng.integers(0, n_parts, size=graph.n_vertices).astype(np.int32)
    return Partition(assignment=a, n_parts=n_parts)


def hash_partition(graph: LabeledGraph, n_parts: int) -> Partition:
    """Deterministic multiplicative-hash assignment (stateless baseline)."""
    v = np.arange(graph.n_vertices, dtype=np.uint64)
    h = (v * np.uint64(2654435761)) % np.uint64(2 ** 32)
    return Partition(assignment=(h % np.uint64(n_parts)).astype(np.int32),
                     n_parts=n_parts)


def _grow_regions(graph: LabeledGraph, n_parts: int,
                  rng: np.random.Generator) -> np.ndarray:
    """Greedy BFS region growing: contiguous parts of near-equal size."""
    n = graph.n_vertices
    assignment = np.full(n, -1, dtype=np.int32)
    unassigned = n
    order = rng.permutation(n)
    cursor = 0
    for part in range(n_parts):
        target = unassigned // (n_parts - part)
        # seed: first unassigned vertex in the shuffled order
        while cursor < n and assignment[order[cursor]] >= 0:
            cursor += 1
        if cursor >= n:
            break
        seed_v = int(order[cursor])
        taken = 0
        queue = deque([seed_v])
        while taken < target:
            if not queue:
                # region exhausted its component; restart from fresh seed
                while cursor < n and assignment[order[cursor]] >= 0:
                    cursor += 1
                if cursor >= n:
                    break
                queue.append(int(order[cursor]))
            v = queue.popleft()
            if assignment[v] >= 0:
                continue
            assignment[v] = part
            taken += 1
            for u in graph.neighbors(v):
                if assignment[u] < 0:
                    queue.append(int(u))
        unassigned -= taken
    # stragglers (disconnected leftovers): smallest part wins each
    leftovers = np.flatnonzero(assignment < 0)
    if leftovers.size:
        sizes = np.bincount(assignment[assignment >= 0], minlength=n_parts)
        for v in leftovers:
            part = int(np.argmin(sizes))
            assignment[v] = part
            sizes[part] += 1
    return assignment


def _refine(graph: LabeledGraph, assignment: np.ndarray, n_parts: int,
            rng: np.random.Generator, n_passes: int = 4) -> np.ndarray:
    """FM-style boundary refinement under the balance envelope."""
    n = graph.n_vertices
    avg = n / n_parts
    cap = int(np.floor(avg * (1.0 + BALANCE_EPS)))
    floor_sz = max(1, int(np.ceil(avg * (1.0 - BALANCE_EPS))))
    sizes = np.bincount(assignment, minlength=n_parts)
    indptr, indices = graph.indptr, graph.indices
    for _ in range(n_passes):
        moved = 0
        e = graph.edge_list
        boundary = np.unique(
            e[assignment[e[:, 0]] != assignment[e[:, 1]]].ravel())
        for v in rng.permutation(boundary):
            a = assignment[v]
            nbrs = indices[indptr[v]:indptr[v + 1]]
            if nbrs.size == 0:
                continue
            conn = np.bincount(assignment[nbrs], minlength=n_parts)
            # candidate: the neighbor part with the strongest connection
            conn_masked = conn.copy()
            conn_masked[a] = -1
            b = int(np.argmax(conn_masked))
            gain = int(conn[b] - conn[a])
            if gain > 0 and sizes[b] < cap and sizes[a] > floor_sz:
                assignment[v] = b
                sizes[a] -= 1
                sizes[b] += 1
                moved += 1
        if moved == 0:
            break
    return assignment


def metis_like_partition(graph: LabeledGraph, n_parts: int,
                         seed: int = 0) -> Partition:
    """Minimum-edge-cut partition with size balance <= ~12% (§4.2.1).

    Greedy BFS growing + FM boundary refinement.  Deterministic for a
    given seed.  Guarantees every part non-empty for n >= n_parts.
    """
    if n_parts < 1:
        raise ValueError("n_parts must be >= 1")
    n = graph.n_vertices
    if n_parts >= n:
        return Partition(np.arange(n, dtype=np.int32) % n_parts, n_parts)
    rng = np.random.default_rng(seed)
    assignment = _grow_regions(graph, n_parts, rng)
    assignment = _refine(graph, assignment, n_parts, rng)
    # safety: refinement floors keep parts populated, but re-seed any
    # part emptied by pathological inputs
    sizes = np.bincount(assignment, minlength=n_parts)
    for part in np.flatnonzero(sizes == 0):
        donor = int(np.argmax(sizes))
        v = int(np.flatnonzero(assignment == donor)[0])
        assignment[v] = part
        sizes[donor] -= 1
        sizes[part] += 1
    return Partition(assignment=assignment.astype(np.int32), n_parts=n_parts)
