"""Mesh runner: real-process rank bootstrap + cross-backend scenarios.

``python -m repro.dist.meshrun --launch N --scenario S`` starts N real
OS processes (rank 0 drives, ranks 1..N-1 serve as the remote ends of
every transport link), bootstraps them into one ``jax.distributed``
cluster over a loopback coordinator, runs scenario S on the driver and
prints its JSON verdict.  The scenarios are the cross-backend
acceptance property made executable:

  * ``identity``  — the same workload, same seeds, on a SimTransport
    engine and a MeshTransport engine; matches, per-query counters and
    the per-channel wire ledger must agree bit-for-bit in host and
    plane probe modes.
  * ``megabatch`` — the same property through ``query_batch`` (fused
    multi-query launches, operand broadcast + candidate readback).
  * ``chaos``     — one seeded FaultPlan crash schedule replayed on
    both backends; every answer (including typed Unavailable slots)
    must be identical.
  * ``census``    — the 300-vertex bench: dryrun's collective-byte
    census prediction (:func:`repro.dist.transport.predicted_wire`
    over the sim ledger) vs the mesh transport's *measured*
    bytes-on-wire, gated at <=10% relative error per channel.

Every scenario builds the sim engine first and injects its partition
assignment + GNN params into the mesh engine, so both executions are
bit-comparable index for index (the ``rebuild_reference`` trick).  The
scenarios also run in-process on a ``world=1`` loopback MeshTransport
(tests, ``dryrun.py --validate-census``) — same code path, no
coordinator needed.

A child that cannot bootstrap ``jax.distributed`` (sandboxed CI, no
loopback sockets) exits with :data:`INIT_FAILED_EXIT`; the launcher
reports ``ok=False, init_failed=True`` so callers can skip rather than
fail.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import numpy as np

from repro.dist.shard import shard_crc32
from repro.dist.transport import (CHANNELS, MeshTransport, SimTransport,
                                  predicted_wire)

__all__ = ["SCENARIOS", "INIT_FAILED_EXIT", "bench_graph", "bench_queries",
           "build_engine", "build_pair", "run_scenario", "launch",
           "census_diff"]

SCENARIOS = ("identity", "megabatch", "chaos", "census")
INIT_FAILED_EXIT = 77        # child could not bootstrap jax.distributed
_RESULT_MARK = "MESHRUN_RESULT "
_BASE_PORT = 29400           # + pid spread, so parallel CI runs don't clash

# the shared scenario cluster shape: 4 machines x 2 shards, replication 1
N_MACHINES = 4
SEED = 7


def bench_graph(n_vertices: int = 120, seed: int = SEED):
    """The deterministic scenario data graph (300v for the census)."""
    from repro.data.synthetic import community_graph
    return community_graph(n_vertices, max(n_vertices // 50, 2), 0.3, 0.02,
                           4, seed=seed)


def bench_queries(graph, n: int = 4, seed: int = SEED):
    from repro.data.synthetic import make_workload
    return make_workload(graph, n_queries=n, seed=seed + 1)


def build_engine(graph, *, transport=None, probe_mode: str = "host",
                 twin=None, replication: int = 1,
                 failover_mode: str = "promote"):
    """One scenario engine; `transport=None` -> sim backend.  `twin`
    injects a prior engine's assignment/params so both backends build
    bit-comparable indexes without re-running partitioner + trainer."""
    from repro.dist.cluster import DistributedGNNPE
    kw = {}
    if twin is not None:
        kw = dict(assignment=twin.assignment, params=twin.params)
    return DistributedGNNPE.build(
        graph, n_machines=N_MACHINES, shards_per_machine=2,
        gnn_train_steps=4, seed=SEED, probe_mode=probe_mode,
        replication=replication, failover_mode=failover_mode,
        transport=transport, **kw)


def build_pair(graph, mesh_transport, probe_mode: str = "host",
               replication: int = 1, failover_mode: str = "promote"):
    """(sim engine, mesh engine) over identical indexes and seeds."""
    sim = build_engine(graph, probe_mode=probe_mode,
                       replication=replication,
                       failover_mode=failover_mode)
    mesh = build_engine(graph, transport=mesh_transport,
                        probe_mode=probe_mode, twin=sim,
                        replication=replication,
                        failover_mode=failover_mode)
    return sim, mesh


def _match_digest(matches: list) -> list:
    """[n_matches, crc32 of the canonically-serialized match list] —
    compact but collision-safe enough to assert bit-identity across
    process boundaries."""
    blob = json.dumps(sorted([list(map(int, m)) for m in matches]),
                      separators=(",", ":")).encode()
    return [len(matches), shard_crc32(blob)]


def _wire(t) -> dict:
    return {ch: int(t.wire[ch]) for ch in CHANNELS}


def _run_queries(engine, queries, probe_mode: str) -> dict:
    digests, comm = [], []
    for q in queries:
        m, tel = engine.query(q, probe_mode=probe_mode)
        digests.append(_match_digest(m))
        comm.append(int(tel.comm_bytes))
    return {"matches": digests, "comm_bytes": comm}


def _scenario_identity(mesh_t) -> dict:
    g = bench_graph()
    qs = bench_queries(g)
    sim, mesh = build_pair(g, mesh_t)
    out: dict = {"modes": {}}
    for mode in ("host", "plane"):
        a = _run_queries(sim, qs, mode)
        b = _run_queries(mesh, qs, mode)
        out["modes"][mode] = {"sim": a, "mesh": b,
                              "identical": a == b}
    out["sim_wire"] = _wire(sim.transport)
    out["mesh_wire"] = _wire(mesh.transport)
    out["identical"] = (all(v["identical"] for v in out["modes"].values())
                        and out["sim_wire"] == out["mesh_wire"])
    return out


def _scenario_megabatch(mesh_t) -> dict:
    g = bench_graph()
    qs = bench_queries(g, n=4)
    sim, mesh = build_pair(g, mesh_t, probe_mode="plane")
    a = [(_match_digest(m), int(t.n_matches)) for m, t in
         sim.query_batch(qs)]
    b = [(_match_digest(m), int(t.n_matches)) for m, t in
         mesh.query_batch(qs)]
    out = {"sim": a, "mesh": b,
           "sim_wire": _wire(sim.transport),
           "mesh_wire": _wire(mesh.transport)}
    out["identical"] = a == b and out["sim_wire"] == out["mesh_wire"]
    return out


def _answers_digest(answers: list) -> list:
    """Typed serialization of run_script answers: match lists digest,
    counters pass through, Unavailable slots keep their typed fields."""
    from repro.dist.chaos import Unavailable
    out = []
    for a in answers:
        if isinstance(a, Unavailable):
            out.append(["unavailable", a.reason, list(a.sids),
                        list(a.machines)])
        elif isinstance(a, list):
            out.append(["matches"] + _match_digest(a))
        else:
            out.append(["count", int(a)])
    return out


def _scenario_chaos(mesh_t) -> dict:
    from repro.dist.chaos import (CRASH, HOOK_QUERY, HOOK_READ, TIMEOUT,
                                  FaultPlan, FaultSpec, default_script,
                                  run_script)
    g = bench_graph()
    plan = FaultPlan([
        FaultSpec(CRASH, HOOK_QUERY, at=2, machine=2),
        FaultSpec(TIMEOUT, HOOK_READ, at=1, times=2),
        FaultSpec(CRASH, HOOK_QUERY, at=6, machine=1),
    ], seed=5)
    ops = default_script(g, seed=3, n_queries=4, modes=("host", "plane"),
                         with_update=False)
    sim, mesh = build_pair(g, mesh_t, replication=1,
                           failover_mode="route")
    plan_a, plan_b = plan.replay(), plan.replay()
    a_ans, a_out = run_script(sim, ops, plan=plan_a,
                              on_unavailable="continue")
    b_ans, b_out = run_script(mesh, ops, plan=plan_b,
                              on_unavailable="continue")
    a = {"answers": _answers_digest(a_ans), "outcome": a_out,
         "fired": len(plan_a.fired)}
    b = {"answers": _answers_digest(b_ans), "outcome": b_out,
         "fired": len(plan_b.fired)}
    return {"sim": a, "mesh": b, "identical": a == b}


def census_diff(sim_transport, mesh_transport, world: int) -> dict:
    """Predicted (census) vs measured mesh wire bytes, per channel.

    Relative error is |measured - predicted| / predicted per nonzero
    predicted channel plus the total; channels the census predicts as
    silent must measure below 10% of total traffic (headers/control)."""
    pred = predicted_wire(sim_transport, world)
    meas = mesh_transport.measured()
    per: dict = {}
    total_p = sum(pred.values())
    total_m = sum(meas.values())
    worst = 0.0
    for ch in CHANNELS:
        p, m = pred[ch], meas.get(ch, 0)
        if p:
            err = abs(m - p) / p
            per[ch] = {"predicted": int(p), "measured": int(m),
                       "rel_err": err}
            worst = max(worst, err)
        elif m:
            err = m / max(total_m, 1)
            per[ch] = {"predicted": 0, "measured": int(m),
                       "share_of_total": err}
            worst = max(worst, err)
    total_err = (abs(total_m - total_p) / total_p) if total_p else 0.0
    worst = max(worst, total_err)
    return {"channels": per,
            "total": {"predicted": int(total_p), "measured": int(total_m),
                      "rel_err": total_err},
            "worst_rel_err": worst,
            "within_10pct": worst <= 0.10}


def _scenario_census(mesh_t) -> dict:
    g = bench_graph(n_vertices=300)
    qs = bench_queries(g, n=6)
    sim, mesh = build_pair(g, mesh_t, probe_mode="plane")
    for e in (sim, mesh):
        for q in qs[:3]:
            e.query(q, probe_mode="plane")
        e.query_batch(qs[3:])
    out = census_diff(sim.transport, mesh.transport, mesh_t.world)
    out["sim_wire"] = _wire(sim.transport)
    out["mesh_wire"] = _wire(mesh.transport)
    out["ledger_identical"] = out["sim_wire"] == out["mesh_wire"]
    out["identical"] = out["ledger_identical"] and out["within_10pct"]
    return out


_SCENARIO_FNS = {"identity": _scenario_identity,
                 "megabatch": _scenario_megabatch,
                 "chaos": _scenario_chaos,
                 "census": _scenario_census}


def run_scenario(scenario: str, mesh_transport=None) -> dict:
    """Run one scenario against `mesh_transport` (default: a fresh
    world=1 loopback MeshTransport) and return its JSON-able verdict."""
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}")
    t = mesh_transport if mesh_transport is not None else MeshTransport()
    out = _SCENARIO_FNS[scenario](t)
    out["scenario"] = scenario
    out["world"] = t.world
    return out


# -------------------------------------------------------------------- #
# multi-process launch
# -------------------------------------------------------------------- #
def _child_env() -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def launch(world: int, scenario: str, timeout_s: float = 600.0) -> dict:
    """Start `world` real processes, run `scenario` on rank 0, return
    its parsed verdict.  ``ok=False, init_failed=True`` means the ranks
    could not bootstrap (callers should skip, not fail)."""
    port = _BASE_PORT + (os.getpid() % 2000)
    coord = f"127.0.0.1:{port}"
    env = _child_env()
    procs = []
    for rank in range(world):
        cmd = [sys.executable, "-m", "repro.dist.meshrun",
               "--world", str(world), "--rank", str(rank),
               "--coord", coord, "--scenario", scenario]
        procs.append(subprocess.Popen(
            cmd, env=env,
            stdout=subprocess.PIPE if rank == 0 else subprocess.DEVNULL,
            stderr=subprocess.PIPE if rank == 0 else subprocess.DEVNULL,
            text=True))
    try:
        stdout, stderr = procs[0].communicate(timeout=timeout_s)
        for p in procs[1:]:
            p.wait(timeout=60.0)
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        return {"ok": False, "init_failed": False,
                "detail": f"timeout after {timeout_s}s"}
    codes = [p.returncode for p in procs]
    if any(c == INIT_FAILED_EXIT for c in codes):
        return {"ok": False, "init_failed": True, "exit_codes": codes}
    result = None
    for line in (stdout or "").splitlines():
        if line.startswith(_RESULT_MARK):
            result = json.loads(line[len(_RESULT_MARK):])
    if result is None or any(codes):
        return {"ok": False, "init_failed": False, "exit_codes": codes,
                "detail": (stderr or "")[-2000:]}
    return {"ok": True, "init_failed": False, "exit_codes": codes,
            "result": result}


def _child_main(world: int, rank: int, coord: str, scenario: str) -> int:
    import faulthandler
    faulthandler.enable()
    t = MeshTransport(world=world, rank=rank, coordinator=coord,
                      timeout_ms=300_000)
    try:
        t.connect()
    except Exception as exc:                      # noqa: BLE001
        print(f"meshrun rank {rank}: init failed: {exc}", file=sys.stderr)
        return INIT_FAILED_EXIT
    if rank != 0:
        t.serve()
        return 0
    out = run_scenario(scenario, t)
    t.close()
    print(_RESULT_MARK + json.dumps(out))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="mesh transport rank runner / launcher")
    ap.add_argument("--launch", type=int, default=0, metavar="N",
                    help="launch N real ranks and run the scenario")
    ap.add_argument("--scenario", choices=SCENARIOS, default="identity")
    ap.add_argument("--world", type=int, default=1)
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--coord", default="")
    args = ap.parse_args(argv)
    if args.launch:
        out = launch(args.launch, args.scenario)
        print(json.dumps(out, indent=2))
        ok = out.get("ok") and out.get("result", {}).get("identical",
                                                         True)
        if out.get("init_failed"):
            print("meshrun: ranks could not bootstrap jax.distributed "
                  "(skipping)", file=sys.stderr)
            return 0
        return 0 if ok else 1
    return _child_main(args.world, args.rank, args.coord, args.scenario)


if __name__ == "__main__":
    sys.exit(main())
