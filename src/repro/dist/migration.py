"""Hot shard migration with CRC verification (paper §4, Algorithm 1).

Execution half of the balancer: ship each shard's canonical byte image to
its target machine, verify integrity with CRC32, retransmit with
exponential backoff on mismatch, and atomically flip the routing table
once every replica in the batch is confirmed.

Queries are **non-interruptible** during migration because

  * the shard byte image is a read-only replica — the aR-tree travels
    verbatim and is byte-identical after the move (no index rebuild, so
    no window where probes could miss candidates), and
  * migration is a two-phase transaction: the PREPARE phase does all
    fallible work (serialize, transfer, CRC verify, decode) without
    touching `shards` or `routing`; the COMMIT phase is pure assignment.
    A fault at any point during prepare aborts the whole batch fully-old
    — a query always sees either the complete pre-batch or complete
    post-batch placement, never a torn mix.

The byte movement itself lives in :mod:`repro.dist.transport` — every
transfer here flows through a :class:`~repro.dist.transport.Transport`
(the engine threads its own; standalone callers get the process-wide
default SimTransport), which owns the link model, chaos injection at the
``migration.transfer`` hook, and the per-channel wire ledger.
`crc_transfer` remains as a compatibility shim for out-of-engine callers
(tests, the gauntlet); in-engine code must call
``engine.transport.transfer`` directly (reprolint RPR009).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.dist.chaos import (HOOK_MIGRATE_PREPARE, SLOW, TIMEOUT, TORN,
                              TransferTimeoutError)
from repro.dist.shard import Shard
from repro.dist.transport import (BACKOFF_BASE_MS, BACKOFF_CAP_MS, CH_IMAGE,
                                  HANDSHAKE_MS, LINK_BYTES_PER_MS,
                                  MAX_RETRIES, TransferResult, Transport,
                                  _link_faults, default_transport)

__all__ = ["MigrationResult", "TransferResult", "crc_transfer",
           "hot_migrate", "migrate_with_retry", "LINK_BYTES_PER_MS",
           "HANDSHAKE_MS", "MAX_RETRIES", "BACKOFF_BASE_MS",
           "BACKOFF_CAP_MS"]

# re-exported for callers that patch/inspect the fault model directly
_link_faults = _link_faults


def crc_transfer(blob: bytes, rng: np.random.Generator,
                 corrupt_prob: float = 0.0,
                 max_retries: int = MAX_RETRIES,
                 chaos=None, timeout_ms: float | None = None
                 ) -> TransferResult:
    """Ship one byte image over the default SimTransport link.

    Compatibility shim over :meth:`repro.dist.transport.Transport.transfer`
    for out-of-engine callers (tests, the gauntlet's standalone replica
    drills).  Semantics are unchanged: CRC32 + retry + exponential
    backoff, ``corrupt_prob`` in-flight flips from the engine rng with a
    clean final attempt, chaos faults from the plan's own rng, and a
    typed :class:`TransferTimeoutError` when the bounded budget is
    exhausted.  In-engine code goes through ``engine.transport`` instead
    so the bytes land in the right backend and ledger (RPR009).
    """
    return default_transport().transfer(
        blob, rng=rng, corrupt_prob=corrupt_prob, max_retries=max_retries,
        chaos=chaos, timeout_ms=timeout_ms)


@dataclasses.dataclass
class MigrationResult:
    """Telemetry of one migration batch.

    crc_ok means every applied routing flip was preceded by a
    CRC-confirmed delivery — structurally guaranteed now that an
    unconfirmed transfer raises instead of returning.

    ``skipped`` lists (sid, reason) moves the batch dropped instead of
    executing: a sid absent from `shards` (removed by failover between
    plan and execute), one whose routing no longer matches the planned
    source (stale plan / the same sid listed twice), or — under
    :func:`migrate_with_retry` — a move whose transfer kept timing out
    after its per-step retry budget.  Skipping keeps `routing`
    consistent — a crash mid-batch used to leave earlier moves applied
    and later ones not, with no record of either.  ``timeouts`` counts
    aborted per-step transactions (each was a clean fully-old abort).
    """

    migrated: list
    crc_ok: bool
    retransmissions: int
    bytes_moved: int
    virtual_ms: float
    skipped: list = dataclasses.field(default_factory=list)
    timeouts: int = 0


def hot_migrate(shards: dict, moves: list, routing: dict,
                rng: np.random.Generator,
                corrupt_prob: float = 0.0,
                max_retries: int = MAX_RETRIES,
                chaos=None, transport: Transport | None = None
                ) -> MigrationResult:
    """Migrate shards per `moves` = [(sid, src_machine, tgt_machine), ...]
    as one prepare/commit transaction.

    PREPARE serializes, transfers (CRC + backoff) and decodes every
    non-skipped move without mutating anything; COMMIT then installs all
    decoded replicas and flips `routing` in one pure-assignment pass.  A
    :class:`TransferTimeoutError` (or an injected TIMEOUT/TORN fault at
    the ``migration.prepare`` hook) during prepare propagates with
    `shards` and `routing` untouched — the batch aborts fully-old.

    Stale moves are skipped, never raised: a planner emitting the same
    shard twice, or a shard removed/re-homed by failover between plan
    and execute, must not abort the batch.  Each skip is recorded in
    ``MigrationResult.skipped`` with its reason.

    `transport` carries the bytes (and its ledger bills them to the
    ``image`` channel per target machine); the engine passes its own,
    standalone callers fall back to the process default.
    """
    t = transport if transport is not None else default_transport()
    staged: list = []            # (sid, tgt, decoded replica, n bytes)
    pending: set = set()         # sids staged but not yet committed
    skipped: list = []
    retrans = 0
    virtual_ms = 0.0

    for sid, src, tgt in moves:
        shard = shards.get(sid)
        if shard is None:
            skipped.append((sid, "unknown shard"))
            continue
        if sid in pending or routing.get(sid, src) != src:
            # the plan's source is stale: a duplicate move in this very
            # batch already staged it, or failover re-homed the shard
            skipped.append((sid, "stale source machine"))
            continue
        if chaos is not None:
            for f in chaos.fire(HOOK_MIGRATE_PREPARE):
                if f.kind in (TIMEOUT, TORN):
                    raise TransferTimeoutError(
                        f"prepare aborted by injected {f.kind} fault "
                        f"(shard {sid})", virtual_ms=virtual_ms)
                if f.kind == SLOW:
                    virtual_ms += f.factor * HANDSHAKE_MS
        blob = shard.serialize()
        tr = t.transfer(blob, rng=rng, src=src, dst=tgt, channel=CH_IMAGE,
                        corrupt_prob=corrupt_prob,
                        max_retries=max_retries, chaos=chaos)
        retrans += tr.retransmissions
        virtual_ms += tr.virtual_ms
        staged.append((sid, tgt, Shard.deserialize(tr.received), len(blob)))
        pending.add(sid)

    migrated: list = []
    bytes_moved = 0
    for sid, tgt, replica, nbytes in staged:   # COMMIT: pure assignment
        shards[sid] = replica
        routing[sid] = tgt
        bytes_moved += nbytes
        migrated.append(sid)

    return MigrationResult(migrated=migrated, crc_ok=True,
                           retransmissions=retrans,
                           bytes_moved=bytes_moved, virtual_ms=virtual_ms,
                           skipped=skipped)


def migrate_with_retry(shards: dict, moves: list, routing: dict,
                       rng: np.random.Generator,
                       corrupt_prob: float = 0.0,
                       max_retries: int = MAX_RETRIES,
                       chaos=None, step_retries: int = 2,
                       transport: Transport | None = None
                       ) -> MigrationResult:
    """`hot_migrate` per move, with per-step retry then skip-and-report.

    A single :class:`TransferTimeoutError` used to abort the *whole*
    rebalance epoch — one stubborn link dropped every remaining planned
    move on the floor.  Here each move runs as its own one-move
    prepare/commit transaction (still fully-old on abort); a step that
    times out is retried up to ``step_retries`` times with
    ``crc_transfer``-style exponential backoff charged in virtual ms,
    and only then recorded in ``MigrationResult.skipped`` (reason
    ``"transfer timeout"``) while the rest of the epoch proceeds.
    ``timeouts`` counts every aborted step transaction so the engine's
    ``aborted_transactions`` ledger stays exact.
    """
    out = MigrationResult(migrated=[], crc_ok=True, retransmissions=0,
                          bytes_moved=0, virtual_ms=0.0)
    for move in moves:
        res = None
        for attempt in range(1, step_retries + 2):
            try:
                res = hot_migrate(shards, [move], routing, rng,
                                  corrupt_prob=corrupt_prob,
                                  max_retries=max_retries, chaos=chaos,
                                  transport=transport)
                break
            except TransferTimeoutError:
                out.timeouts += 1       # clean fully-old abort; retryable
                out.virtual_ms += min(BACKOFF_BASE_MS * 2.0 ** (attempt - 1),
                                      BACKOFF_CAP_MS)
        if res is None:
            out.skipped.append(
                (move[0], f"transfer timeout after {step_retries + 1} "
                          f"attempts"))
            continue
        out.migrated.extend(res.migrated)
        out.retransmissions += res.retransmissions
        out.bytes_moved += res.bytes_moved
        out.virtual_ms += res.virtual_ms
        out.skipped.extend(res.skipped)
    return out
