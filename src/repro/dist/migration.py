"""Hot shard migration with CRC verification (paper §4, Algorithm 1).

Execution half of the balancer: ship each shard's canonical byte image to
its target machine, verify integrity with CRC32, retransmit on mismatch,
and atomically flip the routing table once the replica is confirmed.

Queries are **non-interruptible** during migration because

  * the shard byte image is a read-only replica — the aR-tree travels
    verbatim and is byte-identical after the move (no index rebuild, so
    no window where probes could miss candidates), and
  * the routing-table flip happens only after the CRC check passes, so a
    query always finds the shard either at the source (pre-flip) or the
    target (post-flip), never in between.

The network is simulated: transfer time is charged in *virtual ms* from a
1 Gbps link model plus a fixed per-transfer handshake, and `corrupt_prob`
injects in-flight byte flips to exercise the retransmission path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.dist.shard import Shard, shard_crc32

__all__ = ["MigrationResult", "TransferResult", "crc_transfer",
           "hot_migrate", "LINK_BYTES_PER_MS", "HANDSHAKE_MS"]

LINK_BYTES_PER_MS = 125_000.0    # 1 Gbps simulated inter-machine link
HANDSHAKE_MS = 5.0               # per-transfer setup + CRC check
MAX_RETRIES = 16


@dataclasses.dataclass
class TransferResult:
    """One CRC-verified blob delivery over the simulated link."""

    received: bytes
    ok: bool                     # delivered bytes match the source CRC
    retransmissions: int
    virtual_ms: float


def crc_transfer(blob: bytes, rng: np.random.Generator | None = None,
                 corrupt_prob: float = 0.0,
                 max_retries: int = MAX_RETRIES) -> TransferResult:
    """Ship one byte image over the simulated link with CRC32 + retry.

    The shared transfer half of Algorithm 1, reused by both hot shard
    migration and the streaming-update delta protocol: attempts
    1..max_retries may be corrupted in flight (`corrupt_prob` injects
    byte flips); attempt max_retries+1 is clean by construction,
    bounding the loop.  (A real deployment would abort instead; in the
    simulator only injected corruption exists, so delivery of the
    source-identical image is guaranteed.)
    """
    rng = rng if rng is not None else np.random.default_rng(0)
    crc = shard_crc32(blob)
    retrans = 0
    virtual_ms = 0.0
    received = blob
    for attempt in range(1, max_retries + 2):
        virtual_ms += len(blob) / LINK_BYTES_PER_MS + HANDSHAKE_MS
        received = blob
        if (corrupt_prob > 0.0 and attempt <= max_retries
                and rng.random() < corrupt_prob):
            bad = bytearray(blob)
            bad[int(rng.integers(len(bad)))] ^= 0xFF
            received = bytes(bad)
        if shard_crc32(received) == crc:
            break
        retrans += 1
    return TransferResult(received=received,
                          ok=shard_crc32(received) == crc,
                          retransmissions=retrans, virtual_ms=virtual_ms)


@dataclasses.dataclass
class MigrationResult:
    """Telemetry of one migration batch.

    crc_ok means every applied routing flip was preceded by a
    CRC-confirmed delivery; the bounded retransmission loop guarantees
    this in the simulator (only injected corruption exists), so a False
    here would indicate a bug, not a lossy network.

    ``skipped`` lists (sid, reason) moves the batch dropped instead of
    executing: a sid absent from `shards` (removed by failover between
    plan and execute) or whose routing no longer matches the planned
    source (stale plan / the same sid listed twice).  Skipping keeps
    `routing` consistent — a crash mid-batch used to leave earlier moves
    applied and later ones not, with no record of either.
    """

    migrated: list
    crc_ok: bool
    retransmissions: int
    bytes_moved: int
    virtual_ms: float
    skipped: list = dataclasses.field(default_factory=list)


def hot_migrate(shards: dict, moves: list, routing: dict,
                rng: np.random.Generator | None = None,
                corrupt_prob: float = 0.0,
                max_retries: int = MAX_RETRIES) -> MigrationResult:
    """Migrate shards per `moves` = [(sid, src_machine, tgt_machine), ...].

    Mutates `shards` (replacing each moved shard with the replica decoded
    at the target — provably identical to the source image) and `routing`
    (flipped to the target only after CRC verification).  Returns batch
    telemetry including the simulated retransmission count.

    Stale moves are skipped, never raised: a planner emitting the same
    shard twice, or a shard removed/re-homed by failover between plan
    and execute, must not crash the batch halfway (leaving `routing`
    half-applied).  Each skip is recorded in ``MigrationResult.skipped``
    with its reason.
    """
    rng = rng if rng is not None else np.random.default_rng(0)
    migrated: list = []
    skipped: list = []
    retrans = 0
    bytes_moved = 0
    virtual_ms = 0.0
    crc_ok = True

    for sid, src, tgt in moves:
        shard = shards.get(sid)
        if shard is None:
            skipped.append((sid, "unknown shard"))
            continue
        if routing.get(sid, src) != src:
            # the plan's source is stale: a duplicate move in this very
            # batch already flipped it, or failover re-homed the shard
            skipped.append((sid, "stale source machine"))
            continue
        blob = shard.serialize()
        tr = crc_transfer(blob, rng=rng, corrupt_prob=corrupt_prob,
                          max_retries=max_retries)
        retrans += tr.retransmissions
        virtual_ms += tr.virtual_ms
        crc_ok = crc_ok and tr.ok
        if not tr.ok:           # defensive: shard stays at the source
            continue
        shards[sid] = Shard.deserialize(tr.received)
        routing[sid] = tgt
        bytes_moved += len(blob)
        migrated.append(sid)

    return MigrationResult(migrated=migrated, crc_ok=crc_ok,
                           retransmissions=retrans,
                           bytes_moved=bytes_moved, virtual_ms=virtual_ms,
                           skipped=skipped)
