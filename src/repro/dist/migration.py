"""Hot shard migration with CRC verification (paper §4, Algorithm 1).

Execution half of the balancer: ship each shard's canonical byte image to
its target machine, verify integrity with CRC32, retransmit with
exponential backoff on mismatch, and atomically flip the routing table
once every replica in the batch is confirmed.

Queries are **non-interruptible** during migration because

  * the shard byte image is a read-only replica — the aR-tree travels
    verbatim and is byte-identical after the move (no index rebuild, so
    no window where probes could miss candidates), and
  * migration is a two-phase transaction: the PREPARE phase does all
    fallible work (serialize, transfer, CRC verify, decode) without
    touching `shards` or `routing`; the COMMIT phase is pure assignment.
    A fault at any point during prepare aborts the whole batch fully-old
    — a query always sees either the complete pre-batch or complete
    post-batch placement, never a torn mix.

The network is simulated: transfer time is charged in *virtual ms* from a
1 Gbps link model plus a fixed per-transfer handshake and per-retry
exponential backoff.  `corrupt_prob` injects in-flight byte flips from
the *engine* rng to exercise retransmission; a chaos `FaultPlan`
(repro.dist.chaos) injects corruption / timeouts / slowdowns / torn
images from its own rng at the ``migration.transfer`` hook, and —
unlike `corrupt_prob`, whose final attempt is clean by construction —
chaos faults may exhaust the retry budget, raising a typed
:class:`~repro.dist.chaos.TransferTimeoutError` that the surrounding
transaction turns into a clean abort.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.dist.chaos import (CORRUPT, HOOK_MIGRATE_PREPARE, HOOK_TRANSFER,
                              SLOW, TIMEOUT, TORN, TransferTimeoutError)
from repro.dist.shard import Shard, shard_crc32

__all__ = ["MigrationResult", "TransferResult", "crc_transfer",
           "hot_migrate", "migrate_with_retry", "LINK_BYTES_PER_MS",
           "HANDSHAKE_MS", "MAX_RETRIES", "BACKOFF_BASE_MS",
           "BACKOFF_CAP_MS"]

LINK_BYTES_PER_MS = 125_000.0    # 1 Gbps simulated inter-machine link
HANDSHAKE_MS = 5.0               # per-transfer setup + CRC check
MAX_RETRIES = 16
BACKOFF_BASE_MS = 2.0            # retry k backs off BASE * 2**(k-1) ...
BACKOFF_CAP_MS = 64.0            # ... capped here (virtual ms)


@dataclasses.dataclass
class TransferResult:
    """One CRC-verified blob delivery over the simulated link."""

    received: bytes
    ok: bool                     # delivered bytes match the source CRC
    retransmissions: int
    virtual_ms: float


def _link_faults(chaos, blob: bytes) -> tuple:
    """Apply the chaos faults due at ``migration.transfer`` to one
    in-flight attempt.

    Returns ``(received, slow_factor)`` where ``received`` is None for a
    lost (TIMEOUT) attempt, possibly torn/corrupted bytes otherwise.
    Draws ONLY from ``chaos.rng`` — never the engine rng — so chaos and
    fault-free runs consume identical engine rng streams (RPR007).
    """
    if chaos is None:
        return blob, 1.0
    received: bytes | None = blob
    factor = 1.0
    for f in chaos.fire(HOOK_TRANSFER):
        if f.kind == TIMEOUT:
            received = None
        elif f.kind == SLOW:
            factor *= f.factor
        elif f.kind == TORN and received is not None and len(received) > 1:
            cut = 1 + int(chaos.rng.integers(len(received) - 1))
            received = received[:cut]
        elif f.kind == CORRUPT and received is not None and received:
            bad = bytearray(received)
            bad[int(chaos.rng.integers(len(bad)))] ^= 0xFF
            received = bytes(bad)
    return received, factor


def crc_transfer(blob: bytes, rng: np.random.Generator,
                 corrupt_prob: float = 0.0,
                 max_retries: int = MAX_RETRIES,
                 chaos=None, timeout_ms: float | None = None
                 ) -> TransferResult:
    """Ship one byte image over the simulated link with CRC32 + retry +
    exponential backoff.

    The shared transfer half of Algorithm 1, reused by hot shard
    migration, the streaming-update delta protocol and replica sync.
    ``rng`` is the *engine* rng (required — every call site threads its
    own generator so corruption simulation is reproducible per run) and
    is consulted only when ``corrupt_prob > 0``: attempts
    1..max_retries may then be corrupted in flight, while attempt
    max_retries+1 is clean by construction, so absent chaos delivery of
    the source-identical image is guaranteed.

    A chaos FaultPlan may corrupt/tear/lose/slow any attempt (final one
    included) from its own rng; if every attempt fails, or accumulated
    virtual time passes ``timeout_ms``, the bounded budget is exhausted
    and :class:`TransferTimeoutError` is raised — reachable only under
    chaos, and handled by the caller as a clean transactional abort.
    """
    crc = shard_crc32(blob)
    retrans = 0
    virtual_ms = 0.0
    for attempt in range(1, max_retries + 2):
        received, slow = _link_faults(chaos, blob)
        if (received is not None and corrupt_prob > 0.0
                and attempt <= max_retries and rng.random() < corrupt_prob):
            bad = bytearray(received)
            bad[int(rng.integers(len(bad)))] ^= 0xFF
            received = bytes(bad)
        virtual_ms += slow * (len(blob) / LINK_BYTES_PER_MS) + HANDSHAKE_MS
        if received is not None and shard_crc32(received) == crc:
            return TransferResult(received=received, ok=True,
                                  retransmissions=retrans,
                                  virtual_ms=virtual_ms)
        retrans += 1
        virtual_ms += min(BACKOFF_BASE_MS * 2.0 ** (attempt - 1),
                          BACKOFF_CAP_MS)
        if timeout_ms is not None and virtual_ms > timeout_ms:
            raise TransferTimeoutError(
                f"transfer exceeded {timeout_ms:.1f} virtual ms "
                f"after {attempt} attempts",
                virtual_ms=virtual_ms, attempts=attempt)
    raise TransferTimeoutError(
        f"transfer failed all {max_retries + 1} attempts",
        virtual_ms=virtual_ms, attempts=max_retries + 1)


@dataclasses.dataclass
class MigrationResult:
    """Telemetry of one migration batch.

    crc_ok means every applied routing flip was preceded by a
    CRC-confirmed delivery — structurally guaranteed now that an
    unconfirmed transfer raises instead of returning.

    ``skipped`` lists (sid, reason) moves the batch dropped instead of
    executing: a sid absent from `shards` (removed by failover between
    plan and execute), one whose routing no longer matches the planned
    source (stale plan / the same sid listed twice), or — under
    :func:`migrate_with_retry` — a move whose transfer kept timing out
    after its per-step retry budget.  Skipping keeps `routing`
    consistent — a crash mid-batch used to leave earlier moves applied
    and later ones not, with no record of either.  ``timeouts`` counts
    aborted per-step transactions (each was a clean fully-old abort).
    """

    migrated: list
    crc_ok: bool
    retransmissions: int
    bytes_moved: int
    virtual_ms: float
    skipped: list = dataclasses.field(default_factory=list)
    timeouts: int = 0


def hot_migrate(shards: dict, moves: list, routing: dict,
                rng: np.random.Generator,
                corrupt_prob: float = 0.0,
                max_retries: int = MAX_RETRIES,
                chaos=None) -> MigrationResult:
    """Migrate shards per `moves` = [(sid, src_machine, tgt_machine), ...]
    as one prepare/commit transaction.

    PREPARE serializes, transfers (CRC + backoff) and decodes every
    non-skipped move without mutating anything; COMMIT then installs all
    decoded replicas and flips `routing` in one pure-assignment pass.  A
    :class:`TransferTimeoutError` (or an injected TIMEOUT/TORN fault at
    the ``migration.prepare`` hook) during prepare propagates with
    `shards` and `routing` untouched — the batch aborts fully-old.

    Stale moves are skipped, never raised: a planner emitting the same
    shard twice, or a shard removed/re-homed by failover between plan
    and execute, must not abort the batch.  Each skip is recorded in
    ``MigrationResult.skipped`` with its reason.
    """
    staged: list = []            # (sid, tgt, decoded replica, n bytes)
    pending: set = set()         # sids staged but not yet committed
    skipped: list = []
    retrans = 0
    virtual_ms = 0.0

    for sid, src, tgt in moves:
        shard = shards.get(sid)
        if shard is None:
            skipped.append((sid, "unknown shard"))
            continue
        if sid in pending or routing.get(sid, src) != src:
            # the plan's source is stale: a duplicate move in this very
            # batch already staged it, or failover re-homed the shard
            skipped.append((sid, "stale source machine"))
            continue
        if chaos is not None:
            for f in chaos.fire(HOOK_MIGRATE_PREPARE):
                if f.kind in (TIMEOUT, TORN):
                    raise TransferTimeoutError(
                        f"prepare aborted by injected {f.kind} fault "
                        f"(shard {sid})", virtual_ms=virtual_ms)
                if f.kind == SLOW:
                    virtual_ms += f.factor * HANDSHAKE_MS
        blob = shard.serialize()
        tr = crc_transfer(blob, rng=rng, corrupt_prob=corrupt_prob,
                          max_retries=max_retries, chaos=chaos)
        retrans += tr.retransmissions
        virtual_ms += tr.virtual_ms
        staged.append((sid, tgt, Shard.deserialize(tr.received), len(blob)))
        pending.add(sid)

    migrated: list = []
    bytes_moved = 0
    for sid, tgt, replica, nbytes in staged:   # COMMIT: pure assignment
        shards[sid] = replica
        routing[sid] = tgt
        bytes_moved += nbytes
        migrated.append(sid)

    return MigrationResult(migrated=migrated, crc_ok=True,
                           retransmissions=retrans,
                           bytes_moved=bytes_moved, virtual_ms=virtual_ms,
                           skipped=skipped)


def migrate_with_retry(shards: dict, moves: list, routing: dict,
                       rng: np.random.Generator,
                       corrupt_prob: float = 0.0,
                       max_retries: int = MAX_RETRIES,
                       chaos=None, step_retries: int = 2) -> MigrationResult:
    """`hot_migrate` per move, with per-step retry then skip-and-report.

    A single :class:`TransferTimeoutError` used to abort the *whole*
    rebalance epoch — one stubborn link dropped every remaining planned
    move on the floor.  Here each move runs as its own one-move
    prepare/commit transaction (still fully-old on abort); a step that
    times out is retried up to ``step_retries`` times with
    ``crc_transfer``-style exponential backoff charged in virtual ms,
    and only then recorded in ``MigrationResult.skipped`` (reason
    ``"transfer timeout"``) while the rest of the epoch proceeds.
    ``timeouts`` counts every aborted step transaction so the engine's
    ``aborted_transactions`` ledger stays exact.
    """
    out = MigrationResult(migrated=[], crc_ok=True, retransmissions=0,
                          bytes_moved=0, virtual_ms=0.0)
    for move in moves:
        res = None
        for attempt in range(1, step_retries + 2):
            try:
                res = hot_migrate(shards, [move], routing, rng,
                                  corrupt_prob=corrupt_prob,
                                  max_retries=max_retries, chaos=chaos)
                break
            except TransferTimeoutError:
                out.timeouts += 1       # clean fully-old abort; retryable
                out.virtual_ms += min(BACKOFF_BASE_MS * 2.0 ** (attempt - 1),
                                      BACKOFF_CAP_MS)
        if res is None:
            out.skipped.append(
                (move[0], f"transfer timeout after {step_retries + 1} "
                          f"attempts"))
            continue
        out.migrated.extend(res.migrated)
        out.retransmissions += res.retransmissions
        out.bytes_moved += res.bytes_moved
        out.virtual_ms += res.virtual_ms
        out.skipped.extend(res.skipped)
    return out
