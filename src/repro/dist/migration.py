"""Hot shard migration with CRC verification (paper §4, Algorithm 1).

Execution half of the balancer: ship each shard's canonical byte image to
its target machine, verify integrity with CRC32, retransmit on mismatch,
and atomically flip the routing table once the replica is confirmed.

Queries are **non-interruptible** during migration because

  * the shard byte image is a read-only replica — the aR-tree travels
    verbatim and is byte-identical after the move (no index rebuild, so
    no window where probes could miss candidates), and
  * the routing-table flip happens only after the CRC check passes, so a
    query always finds the shard either at the source (pre-flip) or the
    target (post-flip), never in between.

The network is simulated: transfer time is charged in *virtual ms* from a
1 Gbps link model plus a fixed per-transfer handshake, and `corrupt_prob`
injects in-flight byte flips to exercise the retransmission path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.dist.shard import Shard, shard_crc32

__all__ = ["MigrationResult", "hot_migrate", "LINK_BYTES_PER_MS",
           "HANDSHAKE_MS"]

LINK_BYTES_PER_MS = 125_000.0    # 1 Gbps simulated inter-machine link
HANDSHAKE_MS = 5.0               # per-transfer setup + CRC check
MAX_RETRIES = 16


@dataclasses.dataclass
class MigrationResult:
    """Telemetry of one migration batch.

    crc_ok means every applied routing flip was preceded by a
    CRC-confirmed delivery; the bounded retransmission loop guarantees
    this in the simulator (only injected corruption exists), so a False
    here would indicate a bug, not a lossy network.
    """

    migrated: list
    crc_ok: bool
    retransmissions: int
    bytes_moved: int
    virtual_ms: float


def hot_migrate(shards: dict, moves: list, routing: dict,
                rng: np.random.Generator | None = None,
                corrupt_prob: float = 0.0,
                max_retries: int = MAX_RETRIES) -> MigrationResult:
    """Migrate shards per `moves` = [(sid, src_machine, tgt_machine), ...].

    Mutates `shards` (replacing each moved shard with the replica decoded
    at the target — provably identical to the source image) and `routing`
    (flipped to the target only after CRC verification).  Returns batch
    telemetry including the simulated retransmission count.
    """
    rng = rng if rng is not None else np.random.default_rng(0)
    migrated: list = []
    retrans = 0
    bytes_moved = 0
    virtual_ms = 0.0
    crc_ok = True

    for sid, src, tgt in moves:
        shard = shards[sid]
        blob = shard.serialize()
        crc = shard_crc32(blob)
        # attempts 1..max_retries may be corrupted in flight; attempt
        # max_retries+1 is clean by construction, bounding the loop.
        # (A real deployment would abort the move instead; in the
        # simulator only injected corruption exists, so delivery of the
        # source-identical image is guaranteed.)
        for attempt in range(1, max_retries + 2):
            virtual_ms += len(blob) / LINK_BYTES_PER_MS + HANDSHAKE_MS
            received = blob
            if (corrupt_prob > 0.0 and attempt <= max_retries
                    and rng.random() < corrupt_prob):
                bad = bytearray(blob)
                bad[int(rng.integers(len(bad)))] ^= 0xFF
                received = bytes(bad)
            if shard_crc32(received) == crc:
                break
            retrans += 1
        delivered = shard_crc32(received) == crc
        crc_ok = crc_ok and delivered
        if not delivered:       # defensive: shard stays at the source
            continue
        shards[sid] = Shard.deserialize(received)
        routing[sid] = tgt
        bytes_moved += len(blob)
        migrated.append(sid)

    return MigrationResult(migrated=migrated, crc_ok=crc_ok,
                           retransmissions=retrans,
                           bytes_moved=bytes_moved, virtual_ms=virtual_ms)
