"""DistributedGNNPE: the paper's full distributed engine on one process.

Offline (build):  partition -> shards(+halo) -> dominance-GNN training ->
global vertex embeddings -> per-shard path tables + aR-trees (canonical-
owner rule: every data path indexed by exactly one shard) -> hardware-
aware job/shard allocation -> PE-score model fit on sampled probes.

Online (query):   plan (Algorithm 6 / degree / natural order) -> per-path
aR-tree probes on every non-skipped shard (root-MBR skip, both
orientations) -> candidate-row filtering against the running per-vertex
masks (what the paper transmits to the master) -> exact backtracking join.
Exactness: per-shard candidates are a dominance-certified superset, the
canonical-owner rule guarantees cluster-wide coverage, and the join
verifies every match — so results equal the VF2 oracle.

Workload loop:    run_workload collects per-shard telemetry, fuses it
into machine loads (§4.1), and when the sigma trigger fires plans and
executes CRC-verified hot migrations (Algorithm 1).

Caching:          a TwoLevelCache (master Top-V + per-machine slaves,
Algorithms 3 & 4) keyed by query signature, valued by AW-ResNet fused
path features (Algorithms 2 & 5).  `use_cache` toggles the whole layer.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict

import numpy as np

from repro.cache.awresnet import AWResNet
from repro.cache.features import FeatureTracker
from repro.cache.policy import TwoLevelCache, protected_degree_threshold
from repro.core import gnn as gnn_lib
from repro.core.artree import build_artree
from repro.core.embedding import (EmbeddedPaths, embed_query_paths,
                                  train_dominance_gnn)
from repro.core.graph import LabeledGraph
from repro.core.matching import (MatchStats, ShardIndex, backtrack_join,
                                 batched_path_candidates, path_candidates,
                                 _reverse_embedding, _scatter_hits)
from repro.core.paths import PathTable, enumerate_paths, paths_of_query
from repro.core.probeplane import ClusterPlanes
from repro.core.pescore import (PEScoreModel, aggregate_global_features,
                                path_feature_vector, shard_features)
from repro.core.plan import degree_based_plan, rank_query_plan
from repro.dist import loadbalance as lb
from repro.dist.migration import LINK_BYTES_PER_MS, hot_migrate
from repro.dist.partition import edge_cut, metis_like_partition, size_balance
from repro.dist.shard import Shard, make_shards

__all__ = ["MachineSpec", "QueryTelemetry", "DistributedGNNPE",
           "EPOCH_VIRTUAL_S"]

ROW_BYTES_PER_VERTEX = 4          # int32 candidate vertex ids on the wire

# Rebalance clock: the engine runs on VIRTUAL time (queries carry virtual
# latencies, not wall time), so the anti-thrash decay in
# `loadbalance.alpha_decay` — specified in seconds over ALPHA_WINDOW_S —
# needs one documented conversion: each `run_workload` epoch advances the
# virtual rebalance clock by EPOCH_VIRTUAL_S seconds.  With the defaults
# (60 s window / 20 s per epoch) the post-migration boost decays to zero
# after exactly 3 epochs.  All migration bookkeeping uses this one clock;
# the per-query counter `_qclock` is only a query id / feature timestamp
# and must never be fed to the balancer as seconds.
EPOCH_VIRTUAL_S = 20.0

# Deterministic PE-score labeling: the virtual cost of testing one aR-tree
# leaf during an offline probe.  Labels built from (leaves_tested x this)
# are machine- and load-independent, unlike wall-clock timings.
VIRTUAL_MS_PER_LEAF = 1e-4


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Static description of one (simulated) cluster machine."""

    machine_id: int
    cpu_weight: float             # relative speed (1.0 = reference core)
    mem_gb: float = 16.0
    net_gbps: float = 1.0


@dataclasses.dataclass
class QueryTelemetry:
    """Per-query execution telemetry (feeds balancing + benchmarks)."""

    latency_ms: float = 0.0       # virtual ms (simulated cluster clock)
    comm_bytes: int = 0           # candidate rows shipped shard -> master
    cross_shard_rows: int = 0
    cache_hits: int = 0
    shards_skipped: int = 0       # root-MBR skips
    paths_executed: int = 0
    paths_skipped: int = 0        # early-terminated after empty candidates
    probe_launches: int = 0       # probe dispatches: host = one per
                                  # (path, shard); device = one per path;
                                  # plane = ONE per query plan
    probe_h2d_bytes: int = 0      # host->device probe traffic (slab +
                                  # queries; 0 on the pure-host path)
    probe_d2h_bytes: int = 0      # device->host readback (dense mask on
                                  # the device path; candidate ids +
                                  # counters only on the plane path)
    n_matches: int = 0
    plan_mode: str = "pescore"
    probe_mode: str = "host"      # host | device | plane
    device_probe: bool = False


def _root_skip(tree, q_fwd: np.ndarray, q_rev: np.ndarray,
               eps: float = 1e-5) -> bool:
    """True iff the shard's root MBR proves zero candidates (both
    orientations) — the <1KB metadata check the central node runs."""
    if tree.uppers:
        up = tree.uppers[0].max(axis=0)
    else:
        up = tree.points.max(axis=0)
    return bool((q_fwd > up + eps).any() and (q_rev > up + eps).any())


class DistributedGNNPE:
    """Distributed exact subgraph matching engine (paper §3-§6)."""

    def __init__(self) -> None:
        raise TypeError("use DistributedGNNPE.build(...)")

    # ------------------------------------------------------------------ #
    # offline phase
    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, graph: LabeledGraph, n_machines: int,
              shards_per_machine: int = 4, gnn_train_steps: int = 60,
              seed: int = 0, halo_hops: int = 2,
              max_path_length: int = 2,
              device_probe: bool = False,
              probe_mode: str | None = None) -> "DistributedGNNPE":
        self = object.__new__(cls)
        t_build = time.perf_counter()
        rng = np.random.default_rng(seed)
        self.graph = graph
        self.max_path_length = max_path_length
        # default probe path: "host" (per-(path, shard) traversal),
        # "device" (PR-2 per-path slab launch), or "plane" (device-
        # resident planes, one fused launch per query plan).  The legacy
        # device_probe bool maps onto probe_mode for compatibility.
        if probe_mode is None:
            probe_mode = "device" if device_probe else "host"
        if probe_mode not in ("host", "device", "plane"):
            raise ValueError(f"unknown probe_mode {probe_mode!r}")
        self.probe_mode = probe_mode
        self.device_probe = probe_mode != "host"
        self.cfg = gnn_lib.GNNConfig(n_labels=graph.n_labels)

        # 1. partition into ultra-fine shards with halo context
        n_shards = n_machines * shards_per_machine
        part = metis_like_partition(graph, n_shards, seed=seed)
        self.assignment = part.assignment
        # the halo must cover both the GNN receptive field and the
        # longest indexed path, or the canonical owner of a path could
        # be unable to enumerate it (silent false dismissals)
        shard_list = make_shards(graph, part.assignment, n_shards,
                                 halo_hops=max(halo_hops, self.cfg.n_hops,
                                               max_path_length))

        # 2. dominance GNN (shared across shards so cross-shard paths
        #    embed consistently) + full-context vertex embeddings
        self.params = train_dominance_gnn(graph, self.cfg,
                                          path_length=max_path_length,
                                          n_steps=gnn_train_steps,
                                          seed=seed)
        vemb = self._encode_data_graph()

        # 3. per-shard path tables + aR-trees (canonical-owner rule);
        #    each index is also packed onto device as a resident probe
        #    plane at build time (lifecycle: build -> resident ->
        #    invalidate on migration/failure)
        self.planes = ClusterPlanes()
        self.shards: dict[int, Shard] = {}
        build_weight: dict[int, float] = {}
        for shard in shard_list:
            self._build_shard_index(shard, vemb)
            self.shards[shard.sid] = shard
            build_weight[shard.sid] = 1.0 + sum(
                ep.n_paths for ep in shard.index.embedded.values())
        self._shard_bytes = {sid: float(s.nbytes())
                             for sid, s in self.shards.items()}
        self._label_hist = {sid: s.label_histogram(self.cfg.n_labels)
                            for sid, s in self.shards.items()}

        # 4. heterogeneous machines + hardware-aware allocation: both the
        #    offline index-build jobs (train_alloc) and the initial shard
        #    placement (routing) are LPT-balanced by weight/speed
        self.cpu_w = rng.uniform(0.7, 1.3, size=n_machines)
        self.specs = [MachineSpec(k, float(self.cpu_w[k]))
                      for k in range(n_machines)]
        train_alloc, alloc_imbalance = self._lpt_alloc(build_weight)
        # initial placement doubles as the index-build job allocation:
        # both balance estimated shard work over heterogeneous machines
        self.routing: dict[int, int] = dict(train_alloc)

        # 5. PE-score model: shard features -> global features; labels
        #    from sampled offline probes
        self.pe_model = PEScoreModel()
        self.pe_model.label_freq = (
            np.bincount(graph.labels, minlength=self.cfg.n_labels)
            / max(graph.n_vertices, 1)).astype(np.float32)
        per_shard = [
            shard_features(s.graph,
                           {l: PathTable(ep.vertices, l)
                            for l, ep in s.index.embedded.items()})
            for s in self.shards.values()]
        self.pe_model.global_features = aggregate_global_features(per_shard)
        self._fit_pe_model(seed)

        # 6. caching layer (Algorithms 2-5)
        theta_d = protected_degree_threshold(graph.degrees)
        self.cache = TwoLevelCache(n_slaves=n_machines, theta_d=theta_d)
        self.tracker = FeatureTracker()
        self.aw = AWResNet(seed=seed)
        self.use_cache = True
        self._slave_store: dict[int, dict] = {k: {}
                                              for k in range(n_machines)}

        # 7. balancing state
        self.dead_machines: set[int] = set()
        self.migrations: list = []
        self.history: list[dict] = []
        self._rng = rng
        self._qclock = 0.0            # query counter (ids/features only)
        self._epoch = 0               # run_workload epochs (rebalance clock)
        self._last_migration_epoch = (self._epoch
                                      - lb.ALPHA_WINDOW_S / EPOCH_VIRTUAL_S)
        self._cpu: dict[int, float] = defaultdict(float)
        self._comm: dict[int, float] = defaultdict(float)
        self._touch: dict[int, set] = defaultdict(set)
        self._last_loads = np.zeros(n_machines)

        self.offline_report = {
            "n_shards": n_shards,
            "n_machines": n_machines,
            "edge_cut": edge_cut(graph, part),
            "size_balance": size_balance(part),
            "alloc_imbalance": alloc_imbalance,
            "train_alloc": np.bincount(
                list(train_alloc.values()),
                minlength=n_machines).tolist(),
            "build_s": round(time.perf_counter() - t_build, 2),
        }
        return self

    # -------------------------------------------------------------- #
    def _encode_data_graph(self) -> np.ndarray:
        import jax.numpy as jnp
        g = self.graph
        src = jnp.asarray(np.repeat(np.arange(g.n_vertices),
                                    np.diff(g.indptr)))
        dst = jnp.asarray(g.indices.astype(np.int64))
        vemb = gnn_lib.encode_graph(self.params, self.cfg,
                                    jnp.asarray(g.labels),
                                    jnp.asarray(g.degrees), src, dst)
        return np.asarray(vemb)

    def _build_shard_index(self, shard: Shard, vemb: np.ndarray) -> None:
        """Index the shard's *owned* paths with full-context embeddings.

        A path is owned by the shard owning its min-global-id endpoint
        (canonical-owner rule) — exactly one shard indexes each data
        path, and the halo guarantees the owner can enumerate it.
        Structural embeddings are taken from the full-graph vertex
        embeddings, so shard-local indexing never weakens the dominance
        certificate (halo vertices keep their exact global context).
        """
        import jax.numpy as jnp
        gi = shard.global_ids
        labels = jnp.asarray(shard.graph.labels)
        embedded: dict[int, EmbeddedPaths] = {}
        trees = {}
        for l in range(1, self.max_path_length + 1):
            table = enumerate_paths(shard.graph, l, max_paths=None)
            verts = table.vertices
            if verts.shape[0]:
                g_first = gi[verts[:, 0]]
                g_last = gi[verts[:, -1]]
                canon = np.where(g_first <= g_last, verts[:, 0],
                                 verts[:, -1])
                verts = verts[shard.owned_mask[canon]]
            if verts.shape[0]:
                struct = vemb[gi[verts]].reshape(verts.shape[0], -1)
                lab = gnn_lib.label_embeddings(labels, jnp.asarray(verts),
                                               self.cfg.n_labels,
                                               self.cfg.d_label)
                emb = np.asarray(gnn_lib.interleave_path_embedding(
                    jnp.asarray(struct), lab, l + 1), dtype=np.float32)
            else:
                verts = np.zeros((0, l + 1), np.int32)
                emb = np.zeros((0, (l + 1) * self.cfg.d_vertex), np.float32)
            embedded[l] = EmbeddedPaths(vertices=verts, embeddings=emb,
                                        length=l)
            trees[l] = build_artree(emb)
        shard.index = ShardIndex(embedded=embedded, trees=trees)
        self.planes.build_shard(shard.sid, shard.index)

    def _lpt_alloc(self, weights: dict[int, float]
                   ) -> tuple[dict[int, int], float]:
        """Longest-processing-time job allocation over heterogeneous
        machines; returns (job -> machine, speed-normalized imbalance)."""
        loads = np.zeros(len(self.cpu_w))
        alloc: dict[int, int] = {}
        for sid in sorted(weights, key=lambda s: -weights[s]):
            k = int(np.argmin((loads + weights[sid]) / self.cpu_w))
            alloc[sid] = k
            loads[k] += weights[sid]
        norm = loads / self.cpu_w
        imbalance = float(norm.max() / max(norm.mean(), 1e-9) - 1.0)
        return alloc, imbalance

    def _fit_pe_model(self, seed: int, n_queries: int = 6) -> None:
        """Offline PE-score labels from sampled probes (§6.2.1).

        Labels use DETERMINISTIC probe statistics: the filter-cost term
        is `leaves_tested * VIRTUAL_MS_PER_LEAF` (the work the probe
        actually did), not wall time, so the fitted model is identical
        across machines and load conditions.  Wall time is still
        measured, but only into the `pe_fit_report` diagnostic.
        """
        from repro.data.synthetic import random_walk_query
        rng = np.random.default_rng(seed + 0x9E)
        xs, ys, wall_ms = [], [], []
        totals = {l: sum(s.index.embedded[l].n_paths
                         for s in self.shards.values())
                  for l in range(1, self.max_path_length + 1)}
        for i in range(n_queries):
            q = random_walk_query(self.graph, int(rng.integers(3, 6)),
                                  seed=seed * 131 + i)
            tables = paths_of_query(q, self.max_path_length)
            for table in tables:
                q_emb = embed_query_paths(q, self.params, self.cfg, table)
                for r in range(table.n_paths):
                    t0 = time.perf_counter()
                    rows, leaves = self._probe_all_shards(q_emb[r],
                                                          table.length)
                    wall_ms.append((time.perf_counter() - t0) * 1e3)
                    y = PEScoreModel.label_pe_score(
                        n_valid=float(rows),
                        n_total=float(max(totals[table.length], 1)),
                        filter_time_ms=leaves * VIRTUAL_MS_PER_LEAF)
                    xs.append(path_feature_vector(
                        q, table.vertices[r], False,
                        self.pe_model.global_features,
                        self.pe_model.label_freq))
                    ys.append(y)
        self.pe_fit_report = {
            "n_probes": len(wall_ms),
            "wall_ms_total": float(sum(wall_ms)),   # diagnostic only
        }
        if len(xs) >= 8:
            from repro.core.pescore import fit_gbdt
            self.pe_model.gbdt = fit_gbdt(np.stack(xs), np.asarray(ys),
                                          n_trees=24, depth=3, n_bins=8)

    def _probe_all_shards(self, q_emb: np.ndarray, length: int
                          ) -> tuple[int, int]:
        """(surviving rows, leaves tested) over all shards — both counts
        are deterministic functions of the index and the query."""
        rows = 0
        stats = MatchStats()
        q_rev = _reverse_embedding(q_emb[None, :], length + 1)[0]
        for shard in self.shards.values():
            tree = shard.index.trees.get(length)
            if tree is None or tree.n_points == 0 \
                    or _root_skip(tree, q_emb, q_rev):
                continue
            verts, _ = path_candidates(shard.index, q_emb, length, stats)
            rows += verts.shape[0]
        return rows, stats.leaves_tested

    # ------------------------------------------------------------------ #
    # online phase
    # ------------------------------------------------------------------ #
    def query(self, query: LabeledGraph, plan_mode: str = "pescore",
              device_probe: bool | None = None,
              probe_mode: str | None = None
              ) -> tuple[list[tuple], QueryTelemetry]:
        """Exact matches of `query` in the data graph + telemetry.

        probe_mode picks the probe path — all three are bit-identical in
        candidates, matches and comm accounting:

          * "host":   one aR-tree traversal per (path, shard);
          * "device": ONE batched launch per query path (PR-2 slab,
            padded [S, max_leaves, D], both orientations fused — the
            slab is re-packed on the host per path);
          * "plane":  ONE fused launch per query PLAN over the
            device-resident shard planes (zero slab bytes when warm;
            readback is candidate row ids + counters only).

        The legacy device_probe bool maps True -> "device", False ->
        "host"; None falls back to the engine default set at build time.
        """
        if probe_mode is None:
            if device_probe is None:
                probe_mode = self.probe_mode
            else:
                probe_mode = "device" if device_probe else "host"
        if probe_mode not in ("host", "device", "plane"):
            raise ValueError(f"unknown probe_mode {probe_mode!r}")
        tel = QueryTelemetry(plan_mode=plan_mode, probe_mode=probe_mode,
                             device_probe=probe_mode != "host")
        self._qclock += 1.0
        key = (query.n_vertices, query.labels.tobytes(),
               query.edge_list.tobytes())

        if self.use_cache:
            res = self.cache.access(key, self._slave_store)
            tel.latency_ms += res.latency_ms
            if res.data is not None:
                tel.cache_hits = 1
                tel.n_matches = len(res.data)
                self._observe_cache(key, hit=True, matched=bool(res.data),
                                    latency_ms=tel.latency_ms)
                return list(res.data), tel

        t_plan = time.perf_counter()
        tables = paths_of_query(query, self.max_path_length)
        if plan_mode == "pescore":
            order = rank_query_plan(query, self.pe_model,
                                    max_path_length=self.max_path_length,
                                    tables=tables).order
        elif plan_mode == "degree":
            order = degree_based_plan(query, tables=tables).order
        else:
            order = [(ti, r) for ti, t in enumerate(tables)
                     for r in range(t.n_paths)]
        q_embs = [embed_query_paths(query, self.params, self.cfg, t)
                  for t in tables]
        plan_ms = (time.perf_counter() - t_plan) * 1e3

        n_d = self.graph.n_vertices
        deg_d, deg_q = self.graph.degrees, query.degrees
        masks = [(self.graph.labels == query.labels[v])
                 & (deg_d >= deg_q[v]) for v in range(query.n_vertices)]
        alive = all(m.any() for m in masks)

        machine_ms: dict[int, float] = defaultdict(float)
        qid = int(self._qclock)
        rows_by_machine: dict[int, int] = defaultdict(int)

        # plane mode: ONE fused launch for the whole plan, up front.
        # Early-exited paths simply never read their precomputed rows
        # (their comm/latency accounting stays untouched, exactly like a
        # skipped host probe), so bit-identity with the host loop holds.
        plan_hits = None
        if probe_mode == "plane" and alive and order:
            plan_hits = self._plan_probe(tables, order, q_embs, tel)

        for ti, r in order:
            if not alive:
                tel.paths_skipped += 1
                continue
            table = tables[ti]
            l = table.length
            qv = table.vertices[r]
            qe = q_embs[ti][r]
            q_rev = _reverse_embedding(qe[None, :], l + 1)[0]
            pos_mask = np.zeros((l + 1, n_d), dtype=bool)
            # central node: root-MBR skip from the <1KB metadata, then
            # gather the surviving shards for this path's probe
            probes: list[tuple[int, Shard]] = []
            for sid, shard in self.shards.items():
                tree = shard.index.trees.get(l)
                if tree is None or tree.n_points == 0:
                    continue
                if _root_skip(tree, qe, q_rev):
                    tel.shards_skipped += 1
                    continue
                probes.append((sid, shard))
            if probes and plan_hits is not None:
                # read this path's survivors from the plan-wide launch;
                # same deterministic service-time attribution as the
                # per-path device branch below
                base, res = plan_hits["row_of"][(ti, r)], plan_hits["res"]
                probe_ms, verts_of = {}, {}
                for sid, shard in probes:
                    idx_f = res.hits(sid, l, base)
                    idx_r = res.hits(sid, l, base + 1)
                    verts_of[sid], _ = _scatter_hits(
                        shard.index.embedded[l], idx_f, idx_r)
                    probe_ms[sid] = (shard.index.trees[l].n_points
                                     * VIRTUAL_MS_PER_LEAF)
            elif probes and probe_mode == "device":
                # pad all probed shards into one [S, max_leaves, D] slab
                # and launch once; survivor rows scatter back per shard.
                # Service time is attributed per shard as a DETERMINISTIC
                # virtual cost (leaves x VIRTUAL_MS_PER_LEAF): the wall
                # time of a batched launch includes one-off jit compiles
                # per slab-shape bucket and cannot be attributed to a
                # machine without poisoning the load telemetry.
                bs: dict[str, int] = {}
                results = batched_path_candidates(
                    [shard.index for _, shard in probes], qe, l,
                    byte_stats=bs)
                tel.probe_launches += 1
                tel.probe_h2d_bytes += bs.get("h2d_bytes", 0)
                tel.probe_d2h_bytes += bs.get("d2h_bytes", 0)
                probe_ms = {sid: s.index.trees[l].n_points
                            * VIRTUAL_MS_PER_LEAF for sid, s in probes}
                verts_of = {sid: verts
                            for (sid, _), (verts, _) in zip(probes, results)}
            else:
                probe_ms, verts_of = {}, {}
                for sid, shard in probes:
                    t0 = time.perf_counter()
                    verts_of[sid], _ = path_candidates(shard.index, qe, l)
                    probe_ms[sid] = (time.perf_counter() - t0) * 1e3
                    tel.probe_launches += 1
            for sid, shard in probes:
                mk = self.routing[sid]
                service_ms = probe_ms[sid] / self.cpu_w[mk]
                gverts = shard.global_ids[verts_of[sid]]
                # shard-side filter against the candidate masks the
                # master shipped with the probe: only surviving rows
                # cross the network (what PE-score ordering optimizes)
                if gverts.shape[0]:
                    ok = np.ones(gverts.shape[0], dtype=bool)
                    for i in range(l + 1):
                        ok &= masks[qv[i]][gverts[:, i]]
                    gverts = gverts[ok]
                n_rows = int(gverts.shape[0])
                tx_bytes = n_rows * ROW_BYTES_PER_VERTEX * (l + 1)
                machine_ms[mk] += service_ms
                self._cpu[sid] += service_ms
                self._comm[sid] += tx_bytes
                if n_rows:
                    self._touch[sid].add(qid)
                    rows_by_machine[mk] += n_rows
                tel.comm_bytes += tx_bytes
                tel.cross_shard_rows += n_rows
                for i in range(l + 1):
                    pos_mask[i, gverts[:, i]] = True
            for i, qvi in enumerate(qv):
                masks[qvi] &= pos_mask[i]
                if not masks[qvi].any():
                    alive = False
            tel.paths_executed += 1

        t_join = time.perf_counter()
        matches = backtrack_join(query, self.graph, masks) if alive else []
        join_ms = (time.perf_counter() - t_join) * 1e3

        tel.n_matches = len(matches)
        comm_ms = tel.comm_bytes / LINK_BYTES_PER_MS
        tel.latency_ms += (max(machine_ms.values(), default=0.0)
                           + comm_ms + plan_ms + join_ms + 0.05)

        # home the cached result on the LIVE machine that produced the
        # most candidate rows; never onto a dead machine (a query that
        # probed nothing must not default to machine 0 if 0 is dead).
        # With no live machine at all there is nowhere to cache: home is
        # None and admission is skipped.
        live_rows = {k: v for k, v in rows_by_machine.items()
                     if k not in self.dead_machines}
        if live_rows:
            home = max(live_rows, key=live_rows.get)
        else:
            home = next((spec.machine_id for spec in self.specs
                         if spec.machine_id not in self.dead_machines),
                        None)
        self._observe_cache(key, hit=False, matched=bool(matches),
                            latency_ms=tel.latency_ms,
                            result=matches, slave_id=home)
        return matches, tel

    # -------------------------------------------------------------- #
    def _plan_probe(self, tables, order, q_embs, tel: QueryTelemetry):
        """ONE fused device launch for every path of the query plan.

        Assembles the resident shard planes of every length the plan
        touches (warm planes and a warm assembly ship ZERO slab bytes),
        stacks all (path, orientation) embeddings on the query axis —
        rows are -inf-padded past their own length's width so different
        lengths share the launch — and reads back only candidate row ids
        + counters.  Returns {"res": PlanProbeResult, "row_of":
        {(ti, r): fwd query-row}}, or None when there is nothing to
        probe.  Stale planes (index replaced by migration/failover) are
        repacked before use by the identity check in ClusterPlanes.
        """
        lengths = sorted({tables[ti].length for ti, _ in order})
        entries = []
        for sid in sorted(self.shards):
            index = self.shards[sid].index
            for l in lengths:
                tree = index.trees.get(l)
                if tree is not None and tree.n_points:
                    entries.append((sid, l, tree))
        if not entries:
            return None
        qrows: list[tuple[np.ndarray, int]] = []
        row_of: dict[tuple[int, int], int] = {}
        for ti, r in order:
            l = tables[ti].length
            qe = q_embs[ti][r]
            row_of[(ti, r)] = len(qrows)
            qrows.append((qe, l))
            qrows.append((_reverse_embedding(qe[None, :], l + 1)[0], l))
        h2d0 = self.planes.stats["h2d_bytes"]
        d2h0 = self.planes.stats["d2h_bytes"]
        res = self.planes.probe(entries, qrows)
        tel.probe_launches += 1
        # stats deltas, not res.h2d_bytes: a cold probe (first after
        # build or invalidation) also pays plane repacking + assembly
        # metadata, and the telemetry must show that amortization
        tel.probe_h2d_bytes += self.planes.stats["h2d_bytes"] - h2d0
        tel.probe_d2h_bytes += self.planes.stats["d2h_bytes"] - d2h0
        return {"res": res, "row_of": row_of}

    def _observe_cache(self, key, hit: bool, matched: bool,
                       latency_ms: float, result=None,
                       slave_id: int | None = 0) -> None:
        """slave_id=None means no live machine can hold the result:
        feature tracking still runs, admission is skipped."""
        self.tracker.record_query(self._qclock, [key], {key: matched})
        feats = np.asarray(self.tracker.features(key), np.float32)
        self.aw.observe(feats, 1.0 if hit else 0.0)
        if not self.use_cache:
            return
        if result is not None and slave_id is not None:
            w = self.aw.weights(feats[None])[0]
            value = float((w * feats).sum())
            self._slave_store[slave_id][key] = result
            self.cache.register(key, slave_id)
            self.cache.admit(key, result, value=value,
                             avg_deg=float(self.graph.avg_degree()),
                             slave_id=slave_id,
                             hit_rate=self.cache.hit_rate,
                             latency_ms=latency_ms)
        if self.aw.should_train(self.cache.hit_rate):
            self.aw.train_once(self.cache.hit_rate, latency_ms)

    # ------------------------------------------------------------------ #
    # workload loop + balancing
    # ------------------------------------------------------------------ #
    def run_workload(self, queries: list[LabeledGraph],
                     rebalance: bool = False,
                     corrupt_prob: float = 0.0,
                     plan_mode: str = "pescore") -> list[QueryTelemetry]:
        """Execute a query stream (one epoch); optionally rebalance.

        The rebalance clock advances EPOCH_VIRTUAL_S virtual seconds per
        epoch — see the constant's docstring; the anti-thrash boost in
        `lb.alpha_decay` therefore decays over ALPHA_WINDOW_S /
        EPOCH_VIRTUAL_S epochs, never over a number of *queries*.
        """
        self._cpu.clear()
        self._comm.clear()
        self._touch.clear()
        tels = [self.query(q, plan_mode=plan_mode)[1] for q in queries]
        self._epoch += 1

        tele = self._refresh_loads()
        rebalanced = False
        if rebalance:
            plan = lb.plan_migrations(
                tele, corr_fn=self._corr, wlabel_fn=self._wlabel,
                shard_sizes=self._shard_bytes,
                seconds_since_migration=(self._epoch
                                         - self._last_migration_epoch)
                * EPOCH_VIRTUAL_S)
            if plan.trigger and plan.moves:
                res = hot_migrate(self.shards, plan.moves, self.routing,
                                  rng=self._rng,
                                  corrupt_prob=corrupt_prob)
                self.migrations.append(res)
                self._last_migration_epoch = self._epoch
                rebalanced = bool(res.migrated)
                # migrated shards carry freshly deserialized indexes:
                # drop their resident probe planes (lazily repacked on
                # the next plane-mode probe)
                for sid in res.migrated:
                    self.planes.invalidate(sid)
                self._refresh_loads()
        self.history.append({
            "sigma": self.load_sigma(),
            "n_queries": len(queries),
            "rebalanced": rebalanced,
            "cache_hit_rate": self.cache.hit_rate,
        })
        return tels

    def handle_machine_failure(self, machine_id: int) -> list[int]:
        """Kill a machine and re-home its shards onto the survivors
        (Algorithm-1 migration from replicas, via WorkerFailover); the
        victims' resident probe planes are invalidated so a plane-mode
        probe can never read a pre-failover slab."""
        from repro.train.elastic import WorkerFailover
        fo = WorkerFailover(self, dead=set(self.dead_machines))
        victims = fo.fail_machine(machine_id)
        for sid in victims:
            self.planes.invalidate(sid)
        return victims

    def load_sigma(self) -> float:
        """Std of machine loads from the most recent workload epoch."""
        return lb.cluster_sigma(self._last_loads)

    def _refresh_loads(self) -> list[lb.MachineTelemetry]:
        """Recompute machine loads from the epoch's per-shard stats."""
        tele = self._machine_telemetry()
        comm_max = max((sum(t.comm.values()) for t in tele), default=1.0)
        self._last_loads = np.array(
            [lb.machine_load(t, max(comm_max, 1e-9)) for t in tele])
        return tele

    def _machine_telemetry(self) -> list[lb.MachineTelemetry]:
        """Per-machine telemetry; dead machines emit no row, so the
        balancer can never pick them as migration receivers."""
        total_cpu = sum(self._cpu.values()) or 1.0
        total_mem = sum(self._shard_bytes.values()) or 1.0
        tele = []
        for spec in self.specs:
            k = spec.machine_id
            if k in self.dead_machines:
                continue
            sids = [sid for sid, mk in self.routing.items() if mk == k]
            tele.append(lb.MachineTelemetry(
                machine_id=k, shard_ids=sids,
                cpu={s: self._cpu.get(s, 0.0) / total_cpu for s in sids},
                comm={s: float(self._comm.get(s, 0.0)) for s in sids},
                mem={s: self._shard_bytes[s] / total_mem for s in sids},
                corr={s: self._corr(s, k) for s in sids}))
        return tele

    def _corr(self, sid: int, machine_id: int) -> float:
        """Workload correlation: fraction of this epoch's queries that
        touched both `sid` and the target machine's resident shards."""
        mine = self._touch.get(sid, set())
        if not mine:
            return 0.0
        theirs: set = set()
        for other, mk in self.routing.items():
            if mk == machine_id and other != sid:
                theirs |= self._touch.get(other, set())
        return len(mine & theirs) / len(mine)

    def _wlabel(self, sid: int, machine_id: int) -> float:
        """Label affinity between a shard and a machine's working set."""
        hists = [self._label_hist[o] for o, mk in self.routing.items()
                 if mk == machine_id and o != sid]
        if not hists:
            return 0.5
        h_m = np.mean(hists, axis=0)
        h_s = self._label_hist[sid]
        denom = np.linalg.norm(h_m) * np.linalg.norm(h_s)
        return float(h_m @ h_s / denom) if denom > 0 else 0.5
