"""DistributedGNNPE: the paper's full distributed engine on one process.

Offline (build):  partition -> shards(+halo) -> dominance-GNN training ->
global vertex embeddings -> per-shard path tables + aR-trees (canonical-
owner rule: every data path indexed by exactly one shard) -> hardware-
aware job/shard allocation -> PE-score model fit on sampled probes.

Online (query):   plan (Algorithm 6 / degree / natural order) -> per-path
aR-tree probes on every non-skipped shard (root-MBR skip, both
orientations) -> candidate-row filtering against the running per-vertex
masks (what the paper transmits to the master) -> exact backtracking join.
Exactness: per-shard candidates are a dominance-certified superset, the
canonical-owner rule guarantees cluster-wide coverage, and the join
verifies every match — so results equal the VF2 oracle.

Workload loop:    run_workload collects per-shard telemetry, fuses it
into machine loads (§4.1), and when the sigma trigger fires plans and
executes CRC-verified hot migrations (Algorithm 1).

Megabatch mode:   run_workload(batch_size=B) (or query_batch directly)
packs the plans of B consecutive queries into ONE multi-query fused
leaf-dominance launch over the device-resident planes, with each
query's label/degree candidate masks shipped as a packed bit operand so
the readback is pre-filtered in-kernel; the stream is pipelined (batch
k+1's launch is dispatched asynchronously while the host joins batch
k).  Results, per-query counters, and comm-byte accounting are
bit-identical to the serial plane path; the launch itself and its
host<->device bytes are attributed to the FIRST query of each batch
(QueryTelemetry.batch_size marks the batch).

Caching:          a TwoLevelCache (master Top-V + per-machine slaves,
Algorithms 3 & 4) keyed by query signature, valued by AW-ResNet fused
path features (Algorithms 2 & 5).  `use_cache` toggles the whole layer.
"""

from __future__ import annotations

import dataclasses
import time
import zlib
from collections import OrderedDict, defaultdict

import numpy as np

from repro.cache.awresnet import AWResNet
from repro.cache.features import FeatureTracker
from repro.cache.policy import TwoLevelCache, protected_degree_threshold
from repro.core import gnn as gnn_lib
from repro.core.artree import reload_artree
from repro.core.embedding import (EmbeddedPaths, embed_query_paths,
                                  splice_embedding_rows,
                                  train_dominance_gnn)
from repro.core.graph import GraphDelta, LabeledGraph, apply_graph_delta
from repro.core.matching import (MatchStats, ShardIndex, backtrack_join,
                                 batched_path_candidates, path_candidates,
                                 _reverse_embedding, _scatter_hits)
from repro.core.paths import (PathTable, enumerate_paths, path_row_keys,
                              paths_of_query)
from repro.core.probeplane import ClusterPlanes, pack_mask_bits
from repro.core.pescore import (PEScoreModel, aggregate_global_features,
                                path_feature_vector, shard_features)
from repro.core.plan import (degree_based_plan, random_plan,
                             rank_query_plan)
from repro.dist import loadbalance as lb
from repro.dist.chaos import (CRASH, HOOK_BATCH, HOOK_QUERY, HOOK_REBALANCE,
                              HOOK_UPDATE_COMMIT, HOOK_UPDATE_STAGE,
                              ClusterUnavailableError, TransferTimeoutError)
from repro.dist.migration import hot_migrate, migrate_with_retry
from repro.dist.replica import ReplicaSet
from repro.dist.transport import (CH_DELTA, CH_OPERANDS, CH_READBACK,
                                  CH_ROWS, LINK_BYTES_PER_MS,
                                  make_transport)
from repro.dist.router import QueryBudget, QueryOutcome, Route, ShardRouter
from repro.dist.partition import (Partition, edge_cut, metis_like_partition,
                                  size_balance)
from repro.dist.shard import (Shard, apply_shard_delta, halo_region,
                              make_shard, make_shards, shard_delta)

__all__ = ["MachineSpec", "QueryTelemetry", "UpdateReport",
           "DistributedGNNPE", "EPOCH_VIRTUAL_S"]

ROW_BYTES_PER_VERTEX = 4          # int32 candidate vertex ids on the wire

PLAN_LRU_SIZE = 128               # memoized (tables, embeddings, orders)
                                  # entries keyed by the query cache key

# Rebalance clock: the engine runs on VIRTUAL time (queries carry virtual
# latencies, not wall time), so the anti-thrash decay in
# `loadbalance.alpha_decay` — specified in seconds over ALPHA_WINDOW_S —
# needs one documented conversion: each `run_workload` epoch advances the
# virtual rebalance clock by EPOCH_VIRTUAL_S seconds.  With the defaults
# (60 s window / 20 s per epoch) the post-migration boost decays to zero
# after exactly 3 epochs.  All migration bookkeeping uses this one clock;
# the per-query counter `_qclock` is only a query id / feature timestamp
# and must never be fed to the balancer as seconds.
EPOCH_VIRTUAL_S = 20.0

# Deterministic PE-score labeling: the virtual cost of testing one aR-tree
# leaf during an offline probe.  Labels built from (leaves_tested x this)
# are machine- and load-independent, unlike wall-clock timings.
VIRTUAL_MS_PER_LEAF = 1e-4


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Static description of one (simulated) cluster machine."""

    machine_id: int
    cpu_weight: float             # relative speed (1.0 = reference core)
    mem_gb: float = 16.0
    net_gbps: float = 1.0


@dataclasses.dataclass
class QueryTelemetry:
    """Per-query execution telemetry (feeds balancing + benchmarks)."""

    latency_ms: float = 0.0       # virtual ms (simulated cluster clock)
    comm_bytes: int = 0           # candidate rows shipped shard -> master
    cross_shard_rows: int = 0
    cache_hits: int = 0
    shards_skipped: int = 0       # root-MBR skips
    paths_executed: int = 0
    paths_skipped: int = 0        # early-terminated after empty candidates
    probe_launches: int = 0       # probe dispatches: host = one per
                                  # (path, shard); device = one per path;
                                  # plane = ONE per query plan
    probe_h2d_bytes: int = 0      # host->device probe traffic (slab +
                                  # queries; 0 on the pure-host path)
    probe_d2h_bytes: int = 0      # device->host readback (dense mask on
                                  # the device path; candidate ids +
                                  # counters only on the plane path)
    n_matches: int = 0
    plan_mode: str = "pescore"
    probe_mode: str = "host"      # host | device | plane
    device_probe: bool = False
    batch_size: int = 1           # queries sharing this query's launch
    plan_cache_hits: int = 0      # plan-artifact LRU hits (tables+embeds
                                  # reused from an earlier identical query)
    outcome: QueryOutcome = dataclasses.field(default_factory=QueryOutcome)
                                  # typed serving outcome (degraded-read /
                                  # retry / hedge / deadline / health)


@dataclasses.dataclass
class UpdateReport:
    """Telemetry of one `apply_updates` batch (feeds BENCH_updates)."""

    data_epoch: int               # engine-wide epoch AFTER this batch
    n_added_edges: int = 0
    n_removed_edges: int = 0
    n_added_vertices: int = 0
    n_detached_vertices: int = 0
    touched_shards: list = dataclasses.field(default_factory=list)
    n_shards: int = 0
    paths_total: int = 0          # paths in the touched shards' new tables
    paths_reused: int = 0         # embedding rows spliced from the old epoch
    paths_reembedded: int = 0     # rows actually recomputed (dirty/new)
    delta_bytes: int = 0          # CRC'd delta images shipped
    full_image_bytes: int = 0     # what a full-cluster rebuild would ship
    retransmissions: int = 0
    virtual_ms: float = 0.0
    planes_invalidated: int = 0   # (sid, length) slabs dropped (changed only)
    results_purged: int = 0       # pre-update cached results retired
    noop: bool = False


def _root_skip(tree, q_fwd: np.ndarray, q_rev: np.ndarray,
               eps: float = 1e-5) -> bool:
    """True iff the shard's root MBR proves zero candidates (both
    orientations) — the <1KB metadata check the central node runs."""
    if tree.uppers:
        up = tree.uppers[0].max(axis=0)
    else:
        up = tree.points.max(axis=0)
    return bool((q_fwd > up + eps).any() and (q_rev > up + eps).any())


class DistributedGNNPE:
    """Distributed exact subgraph matching engine (paper §3-§6)."""

    def __init__(self) -> None:
        raise TypeError("use DistributedGNNPE.build(...)")

    # ------------------------------------------------------------------ #
    # offline phase
    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, graph: LabeledGraph, n_machines: int,
              shards_per_machine: int = 4, gnn_train_steps: int = 60,
              seed: int = 0, halo_hops: int = 2,
              max_path_length: int = 2,
              device_probe: bool = False,
              probe_mode: str | None = None,
              assignment: np.ndarray | None = None,
              params: dict | None = None,
              replication: int = 0,
              failover_mode: str = "promote",
              backend: str = "sim",
              transport=None) -> "DistributedGNNPE":
        """Offline build.  `assignment` / `params` inject a fixed
        partition assignment and pretrained GNN params instead of
        running the partitioner / trainer — the rebuild-equivalence
        oracle for streaming updates (`rebuild_reference`) uses them to
        build a from-scratch engine on the live engine's updated graph
        that is bit-comparable index for index.

        `replication=k` keeps k anti-affine standby replicas of every
        shard (repro.dist.replica) — failover then promotes instead of
        rebuilding.  The default 0 preserves the legacy byte-image
        failover path and pays zero replication overhead.

        `failover_mode` picks the crash reaction with replication on:

          * "promote" (PR-8 default) — a crash immediately promotes a
            standby for every victim shard, inline with failover;
          * "route" — degraded-mode serving: the crash only marks the
            machine dead; reads are routed to live standbys *without*
            promotion (bit-identical by the CRC-sync construction) and
            promotion + re-replication are deferred to an explicit
            `recover()` (or the next write/rebalance, which recovers
            first).  No one-way unavailability latch: queries fail
            typed only when a shard they NEED lost every copy.

        `backend` picks the transport every inter-machine byte crosses
        (repro.dist.transport): "sim" (default, in-process link model —
        the deterministic oracle) or "mesh" (jax.distributed process
        ranks; bytes physically ship between ranks / through the local
        device).  `transport` injects a pre-configured Transport
        instance instead (e.g. a MeshTransport with explicit
        world/rank/coordinator); it overrides `backend`.
        """
        self = object.__new__(cls)
        # reprolint: disable=RPR004 -- build_s is a wall diagnostic
        t_build = time.perf_counter()
        rng = np.random.default_rng(seed)
        self.graph = graph
        self.max_path_length = max_path_length
        self._seed = seed
        self._build_cfg = dict(n_machines=n_machines,
                               shards_per_machine=shards_per_machine,
                               gnn_train_steps=gnn_train_steps, seed=seed,
                               halo_hops=halo_hops,
                               max_path_length=max_path_length,
                               replication=replication,
                               failover_mode=failover_mode)
        if failover_mode not in ("promote", "route"):
            raise ValueError(f"unknown failover_mode {failover_mode!r}")
        self.failover_mode = failover_mode
        # the transport seam: every cross-machine byte (shard images,
        # deltas, candidate rows, megabatch operands/readbacks) flows
        # through self.transport, which owns the chaos plan + wire ledger
        self.transport = (transport if transport is not None
                          else make_transport(backend)).bind(self)
        # default probe path: "host" (per-(path, shard) traversal),
        # "device" (PR-2 per-path slab launch), or "plane" (device-
        # resident planes, one fused launch per query plan).  The legacy
        # device_probe bool maps onto probe_mode for compatibility.
        if probe_mode is None:
            probe_mode = "device" if device_probe else "host"
        if probe_mode not in ("host", "device", "plane"):
            raise ValueError(f"unknown probe_mode {probe_mode!r}")
        self.probe_mode = probe_mode
        self.device_probe = probe_mode != "host"
        self.cfg = gnn_lib.GNNConfig(n_labels=graph.n_labels)

        # 1. partition into ultra-fine shards with halo context
        n_shards = n_machines * shards_per_machine
        if assignment is None:
            part = metis_like_partition(graph, n_shards, seed=seed)
            self.assignment = part.assignment
        else:
            self.assignment = np.asarray(assignment)
            part = Partition(assignment=self.assignment, n_parts=n_shards)
        # the halo must cover both the GNN receptive field and the
        # longest indexed path, or the canonical owner of a path could
        # be unable to enumerate it (silent false dismissals)
        self._halo_eff = max(halo_hops, self.cfg.n_hops, max_path_length)
        shard_list = make_shards(graph, self.assignment, n_shards,
                                 halo_hops=self._halo_eff)

        # 2. dominance GNN (shared across shards so cross-shard paths
        #    embed consistently) + full-context vertex embeddings
        self.params = params if params is not None else \
            train_dominance_gnn(graph, self.cfg,
                                path_length=max_path_length,
                                n_steps=gnn_train_steps, seed=seed)
        vemb = self._encode_data_graph()
        # kept for streaming updates: the dirty-vertex rule re-embeds a
        # path iff any of its vertices' rows changed vs this snapshot
        self._vemb = vemb

        # 3. per-shard path tables + aR-trees (canonical-owner rule);
        #    each index is also packed onto device as a resident probe
        #    plane at build time (lifecycle: build -> resident ->
        #    invalidate on migration/failure)
        self.planes = ClusterPlanes()
        self.shards: dict[int, Shard] = {}
        build_weight: dict[int, float] = {}
        for shard in shard_list:
            self._build_shard_index(shard, vemb)
            self.shards[shard.sid] = shard
            build_weight[shard.sid] = 1.0 + sum(
                ep.n_paths for ep in shard.index.embedded.values())
        # streaming-update consistency state: per-shard index epochs
        # (bumped when apply_updates re-indexes a shard) + the global
        # data epoch baked into every result-cache key
        self.index_epoch: dict[int, int] = {sid: 0 for sid in self.shards}
        self._data_epoch = 0
        self.update_reports: list[UpdateReport] = []
        self.retired_ids: set[int] = set()   # detached: never re-attach
        self._shard_bytes = {sid: float(s.nbytes())
                             for sid, s in self.shards.items()}
        # full replica-image sizes for UpdateReport's delta-vs-full
        # comparison; filled lazily by the first apply_updates so a
        # build that never streams updates pays no serialization
        self._image_bytes: dict[int, int] = {}
        self._label_hist = {sid: s.label_histogram(self.cfg.n_labels)
                            for sid, s in self.shards.items()}

        # 4. heterogeneous machines + hardware-aware allocation: both the
        #    offline index-build jobs (train_alloc) and the initial shard
        #    placement (routing) are LPT-balanced by weight/speed
        self.cpu_w = rng.uniform(0.7, 1.3, size=n_machines)
        self.specs = [MachineSpec(k, float(self.cpu_w[k]))
                      for k in range(n_machines)]
        train_alloc, alloc_imbalance = self._lpt_alloc(build_weight)
        # initial placement doubles as the index-build job allocation:
        # both balance estimated shard work over heterogeneous machines
        self.routing: dict[int, int] = dict(train_alloc)
        # topology exists (shards + planes + routing): let the transport
        # home per-machine state (mesh backend pins probe planes to each
        # machine's local device; sim is placement-agnostic)
        self.transport.on_topology(self)

        # 5. PE-score model: shard features -> global features; labels
        #    from sampled offline probes
        self.pe_model = PEScoreModel()
        self._refit_pe_model()

        # 6. caching layer (Algorithms 2-5)
        theta_d = protected_degree_threshold(graph.degrees)
        self.cache = TwoLevelCache(n_slaves=n_machines, theta_d=theta_d)
        self.tracker = FeatureTracker()
        self.aw = AWResNet(seed=seed)
        self.use_cache = True
        self._slave_store: dict[int, dict] = {k: {}
                                              for k in range(n_machines)}

        # 6b. per-query plan artifacts (paths_of_query + embed_query_paths
        #     + ranked orders) are pure functions of (query, engine state
        #     fixed at build), so repeated query shapes reuse them via a
        #     small LRU keyed on the cache key (hits in QueryTelemetry)
        self._plan_lru: OrderedDict = OrderedDict()
        # 6c. AW-ResNet update batching: run_workload defers Algorithm-5
        #     training to one update per epoch (observations still stream
        #     per query); query() outside a workload trains immediately
        self._defer_aw = False
        self._aw_pending: list[tuple[float, float]] = []

        # 7. balancing state
        self.dead_machines: set[int] = set()
        self.migrations: list = []
        self.history: list[dict] = []
        self._rng = rng
        # 7b. robustness state: chaos fault plan (None = every hook is a
        #     no-op), aborted-transaction counter, terminal-unavailability
        #     latch, and the k-replica standby set (k=0 = legacy failover)
        self.chaos = None
        self._unavailable: str | None = None
        self.aborted_transactions = 0
        self.replicas = ReplicaSet(replication, n_machines)
        if replication:
            for sid in sorted(self.shards):
                self.replicas.sync_full(sid, self.shards[sid],
                                        self.routing[sid],
                                        self.dead_machines, rng,
                                        transport=self.transport)
        # 7c. degraded-mode serving: the router is the single resolver
        #     for shard reads (primary-or-standby, RPR008) and owns the
        #     HEALTHY/DEGRADED/BROWNOUT health state machine
        self.router = ShardRouter(self)
        self._qclock = 0.0            # query counter (ids/features only)
        self._epoch = 0               # run_workload epochs (rebalance clock)
        self._last_migration_epoch = (self._epoch
                                      - lb.ALPHA_WINDOW_S / EPOCH_VIRTUAL_S)
        self._cpu: dict[int, float] = defaultdict(float)
        self._comm: dict[int, float] = defaultdict(float)
        self._touch: dict[int, set] = defaultdict(set)
        self._last_loads = np.zeros(n_machines)

        self.offline_report = {
            "n_shards": n_shards,
            "n_machines": n_machines,
            "edge_cut": edge_cut(graph, part),
            "size_balance": size_balance(part),
            "alloc_imbalance": alloc_imbalance,
            "train_alloc": np.bincount(
                list(train_alloc.values()),
                minlength=n_machines).tolist(),
            # reprolint: disable=RPR004 -- build_s is a wall diagnostic
            "build_s": round(time.perf_counter() - t_build, 2),
        }
        return self

    # -------------------------------------------------------------- #
    def _encode_data_graph(self, graph: LabeledGraph | None = None
                           ) -> np.ndarray:
        import jax.numpy as jnp
        g = graph if graph is not None else self.graph
        src = jnp.asarray(np.repeat(np.arange(g.n_vertices),
                                    np.diff(g.indptr)))
        dst = jnp.asarray(g.indices.astype(np.int64))
        vemb = gnn_lib.encode_graph(self.params, self.cfg,
                                    jnp.asarray(g.labels),
                                    jnp.asarray(g.degrees), src, dst)
        return np.asarray(vemb)

    def _build_shard_index(self, shard: Shard, vemb: np.ndarray,
                           reuse_from: Shard | None = None,
                           dirty_gmask: np.ndarray | None = None,
                           stats: dict | None = None,
                           build_trees: bool = True) -> None:
        """Index the shard's *owned* paths with full-context embeddings.

        A path is owned by the shard owning its min-global-id endpoint
        (canonical-owner rule) — exactly one shard indexes each data
        path, and the halo guarantees the owner can enumerate it.
        Structural embeddings are taken from the full-graph vertex
        embeddings, so shard-local indexing never weakens the dominance
        certificate (halo vertices keep their exact global context).

        Incremental mode (``reuse_from`` + ``dirty_gmask``, the
        streaming-update path): the path table is still enumerated in
        CANONICAL order (so tables/trees stay bit-identical to a
        from-scratch build), but embedding rows whose vertices are all
        clean are SPLICED from the previous epoch's table instead of
        recomputed — only paths through dirty vertices (or genuinely
        new paths) re-embed, and each tree is a bulk reload.  ``stats``
        accumulates paths_total/paths_reused/paths_reembedded.

        ``build_trees=False`` (update staging only) skips aR-tree
        construction entirely — the delta protocol never ships trees
        (the receiver bulk-reloads them from the embeddings), so
        sender-side builds would be pure waste for carried lengths and
        a double build for changed ones.  The resulting index's
        ``trees`` is EMPTY; such a shard must never be installed.
        """
        import jax.numpy as jnp
        gi = shard.global_ids
        labels = jnp.asarray(shard.graph.labels)
        old_index = reuse_from.index if reuse_from is not None else None
        old_gi = reuse_from.global_ids if reuse_from is not None else None
        embedded: dict[int, EmbeddedPaths] = {}
        trees = {}
        for l in range(1, self.max_path_length + 1):
            table = enumerate_paths(shard.graph, l, max_paths=None)
            verts = table.vertices
            if verts.shape[0]:
                g_first = gi[verts[:, 0]]
                g_last = gi[verts[:, -1]]
                canon = np.where(g_first <= g_last, verts[:, 0],
                                 verts[:, -1])
                verts = verts[shard.owned_mask[canon]]
            d_emb = (l + 1) * self.cfg.d_vertex
            n_reused = 0
            if verts.shape[0]:
                def fresh(rows: np.ndarray) -> np.ndarray:
                    vv = verts[rows]
                    struct = vemb[gi[vv]].reshape(vv.shape[0], -1)
                    lab = gnn_lib.label_embeddings(
                        labels, jnp.asarray(vv), self.cfg.n_labels,
                        self.cfg.d_label)
                    return np.asarray(gnn_lib.interleave_path_embedding(
                        jnp.asarray(struct), lab, l + 1), dtype=np.float32)
                old_ep = (old_index.embedded.get(l)
                          if old_index is not None else None)
                if old_ep is not None and dirty_gmask is not None:
                    clean = ~dirty_gmask[gi[verts]].any(axis=1)
                    emb, n_reused = splice_embedding_rows(
                        path_row_keys(gi[verts]), clean,
                        path_row_keys(old_gi[old_ep.vertices]),
                        old_ep.embeddings, d_emb, fresh)
                else:
                    emb = fresh(np.arange(verts.shape[0], dtype=np.int64))
            else:
                verts = np.zeros((0, l + 1), np.int32)
                emb = np.zeros((0, d_emb), np.float32)
            if stats is not None:
                stats["paths_total"] += int(verts.shape[0])
                stats["paths_reused"] += n_reused
                stats["paths_reembedded"] += int(verts.shape[0]) - n_reused
            embedded[l] = EmbeddedPaths(vertices=verts, embeddings=emb,
                                        length=l)
            if build_trees:
                old_tree = (old_index.trees.get(l)
                            if old_index is not None else None)
                trees[l] = reload_artree(old_tree, emb)
        shard.index = ShardIndex(embedded=embedded, trees=trees)
        if reuse_from is None:
            # fresh build packs planes eagerly; the update path instead
            # invalidates only the CHANGED (sid, length) slabs after the
            # delta installs (untouched lengths stay warm by identity)
            self.planes.build_shard(shard.sid, shard.index)

    def _lpt_alloc(self, weights: dict[int, float]
                   ) -> tuple[dict[int, int], float]:
        """Longest-processing-time job allocation over heterogeneous
        machines; returns (job -> machine, speed-normalized imbalance)."""
        loads = np.zeros(len(self.cpu_w))
        alloc: dict[int, int] = {}
        for sid in sorted(weights, key=lambda s: -weights[s]):
            k = int(np.argmin((loads + weights[sid]) / self.cpu_w))
            alloc[sid] = k
            loads[k] += weights[sid]
        norm = loads / self.cpu_w
        imbalance = float(norm.max() / max(norm.mean(), 1e-9) - 1.0)
        return alloc, imbalance

    def _refit_pe_model(self) -> None:
        """(Re)fit the whole PE-score pipeline on the CURRENT graph and
        shard indexes: label frequencies -> per-shard/global features ->
        deterministic offline-probe labels.  Build step 5 AND the
        streaming-update refit run exactly this — a single code path is
        what keeps post-update plan ranking bit-identical to a fresh
        build's (the rebuild-equivalence invariant)."""
        self.pe_model.label_freq = (
            np.bincount(self.graph.labels, minlength=self.cfg.n_labels)
            / max(self.graph.n_vertices, 1)).astype(np.float32)
        per_shard = [
            shard_features(s.graph,
                           {l: PathTable(ep.vertices, l)
                            for l, ep in s.index.embedded.items()})
            for s in self.shards.values()]
        self.pe_model.global_features = aggregate_global_features(per_shard)
        self.pe_model.mbr_uppers = self._collect_mbr_uppers()
        self._fit_pe_model(self._seed)

    def _collect_mbr_uppers(self) -> dict[int, np.ndarray]:
        """Per-length [S, D] root-MBR upper summaries over shards sorted
        by id — the same <1KB central-node metadata `_root_skip` reads,
        exported so plan ranking can PREDICT shard skips per path.
        Shards with no tree at a length get a -inf row (always
        predicted-skipped, matching the probe loop's short-circuit)."""
        out: dict[int, np.ndarray] = {}
        sids = sorted(self.shards)
        for length in range(1, self.max_path_length + 1):
            rows, dim = [], 0
            for sid in sids:
                tree = self.shards[sid].index.trees.get(length)
                if tree is None or tree.n_points == 0:
                    rows.append(None)
                    continue
                up = (tree.uppers[0].max(axis=0) if tree.uppers
                      else tree.points.max(axis=0))
                rows.append(np.asarray(up, np.float32))
                dim = up.shape[0]
            if dim == 0:
                continue            # no shard carries this length
            out[length] = np.stack([
                r if r is not None else np.full(dim, -np.inf, np.float32)
                for r in rows])
        return out

    def _fit_pe_model(self, seed: int, n_queries: int = 6) -> None:
        """Offline PE-score labels from sampled probes (§6.2.1).

        Labels use DETERMINISTIC probe statistics: the filter-cost term
        is `leaves_tested * VIRTUAL_MS_PER_LEAF` (the work the probe
        actually did), not wall time, so the fitted model is identical
        across machines and load conditions.  Wall time is still
        measured, but only into the `pe_fit_report` diagnostic.
        """
        from repro.data.synthetic import random_walk_query
        rng = np.random.default_rng(seed + 0x9E)
        xs, ys, wall_ms = [], [], []
        totals = {l: sum(s.index.embedded[l].n_paths
                         for s in self.shards.values())
                  for l in range(1, self.max_path_length + 1)}
        for i in range(n_queries):
            q = random_walk_query(self.graph, int(rng.integers(3, 6)),
                                  seed=seed * 131 + i)
            tables = paths_of_query(q, self.max_path_length)
            for table in tables:
                q_emb = embed_query_paths(q, self.params, self.cfg, table)
                for r in range(table.n_paths):
                    t0 = time.perf_counter()
                    rows, leaves = self._probe_all_shards(q_emb[r],
                                                          table.length)
                    wall_ms.append((time.perf_counter() - t0) * 1e3)
                    y = PEScoreModel.label_pe_score(
                        n_valid=float(rows),
                        n_total=float(max(totals[table.length], 1)),
                        filter_time_ms=leaves * VIRTUAL_MS_PER_LEAF)
                    xs.append(path_feature_vector(
                        q, table.vertices[r], False,
                        self.pe_model.global_features,
                        self.pe_model.label_freq,
                        q_emb=q_emb[r],
                        mbr_uppers=self.pe_model.mbr_uppers))
                    ys.append(y)
        self.pe_fit_report = {
            "n_probes": len(wall_ms),
            "wall_ms_total": float(sum(wall_ms)),   # diagnostic only
        }
        if len(xs) >= 8:
            from repro.core.pescore import fit_gbdt
            self.pe_model.gbdt = fit_gbdt(np.stack(xs), np.asarray(ys),
                                          n_trees=24, depth=3, n_bins=8)

    def _probe_all_shards(self, q_emb: np.ndarray, length: int
                          ) -> tuple[int, int]:
        """(surviving rows, leaves tested) over all shards — both counts
        are deterministic functions of the index and the query."""
        rows = 0
        stats = MatchStats()
        q_rev = _reverse_embedding(q_emb[None, :], length + 1)[0]
        for shard in self.shards.values():
            tree = shard.index.trees.get(length)
            if tree is None or tree.n_points == 0 \
                    or _root_skip(tree, q_emb, q_rev):
                continue
            verts, _ = path_candidates(shard.index, q_emb, length, stats)
            rows += verts.shape[0]
        return rows, stats.leaves_tested

    # ------------------------------------------------------------------ #
    # chaos harness + replication plumbing
    # ------------------------------------------------------------------ #
    @property
    def chaos(self):
        """The attached FaultPlan.  Ownership lives on the transport —
        link faults fire inside Transport.transfer — and this view keeps
        the engine's hook sites (`self.chaos.fire`, RPR007 rng rule)
        reading naturally."""
        return self.transport.chaos

    @chaos.setter
    def chaos(self, plan) -> None:
        self.transport.chaos = plan

    def set_fault_plan(self, plan) -> None:
        """Attach a chaos FaultPlan (None detaches).  Every named hook
        point consults the plan; with none attached hooks are no-ops."""
        self.chaos = plan

    def enable_replication(self, k: int) -> None:
        """(Re)build the standby replica set at factor `k` from the
        current shards — post-build twin of `build(replication=k)`."""
        self.replicas = ReplicaSet(k, len(self.specs))
        if k:
            for sid in sorted(self.shards):
                self.replicas.sync_full(sid, self.shards[sid],
                                        self.routing[sid],
                                        self.dead_machines, self._rng,
                                        transport=self.transport)

    def _check_available(self) -> None:
        if self._unavailable is not None:
            raise ClusterUnavailableError(
                f"cluster is unavailable: {self._unavailable}",
                reason=self._unavailable)

    def _fire_hook(self, hook: str) -> None:
        """Consult the fault plan at a named engine hook point.

        CRASH faults kill their target machine via the full failover
        path (a fault with no pinned machine picks a live one from the
        PLAN's rng — never the engine rng, so fault-free and chaos runs
        draw identical engine rng streams; reprolint RPR007 checks
        this).  Failover may raise ClusterUnavailableError, which
        propagates to the caller mid-operation — transactions must
        therefore only fire hooks before their commit point.
        """
        if self.chaos is None:
            return
        for f in self.chaos.fire(hook):
            if f.kind != CRASH:
                continue
            m = f.machine
            if m is None:
                live = [s.machine_id for s in self.specs
                        if s.machine_id not in self.dead_machines]
                if not live:
                    continue
                m = int(live[int(self.chaos.rng.integers(len(live)))])
            if m < len(self.specs) and m not in self.dead_machines:
                self.handle_machine_failure(m)

    # ------------------------------------------------------------------ #
    # consistency audits (chaos oracle + CI torn-state gates)
    # ------------------------------------------------------------------ #
    def cache_audit(self) -> list:
        """Cache-layer wrongness: nothing may remain homed on a dead
        machine — not a slave ValueCache entry, not a slave-memory
        result, not a master memory-index pointer."""
        bad = []
        for m in sorted(self.dead_machines):
            if self.cache.slaves[m].store:
                bad.append(f"dead machine {m} still holds "
                           f"{len(self.cache.slaves[m].store)} "
                           f"slave-cache entries")
            if self._slave_store[m]:
                bad.append(f"dead machine {m} still holds "
                           f"{len(self._slave_store[m])} slave-memory "
                           f"results")
        for s in self.cache.location.values():
            if s in self.dead_machines:
                bad.append(f"cache key homed on dead machine {s}")
        return bad

    def consistency_audit(self) -> list:
        """Zero-torn-state invariant, checkable after ANY operation
        (chaos oracle runs it after every op): routing, shards, planes
        epochs, caches and replicas are mutually consistent — either
        fully-old or fully-new, never a mix.  Returns violations (empty
        = clean).  A terminally unavailable engine audits empty: its
        state is frozen and every operation raises."""
        if self._unavailable is not None:
            return []
        bad = self.cache_audit()
        for sid, mk in self.routing.items():
            if mk in self.dead_machines and self.failover_mode != "route":
                # route mode defers promotion: a dead-routed shard is
                # DEGRADED (standby-served) or LOST (typed per query),
                # tracked by the router — not torn state
                bad.append(f"shard {sid} routed to dead machine {mk}")
            if sid not in self.shards:
                bad.append(f"routed shard {sid} has no shard object")
        for sid in self.shards:
            if sid not in self.routing:
                bad.append(f"shard {sid} missing from routing")
            if sid not in self.index_epoch:
                bad.append(f"shard {sid} missing from index_epoch")
            idx = self.shards[sid].index
            if idx is None or not idx.trees:
                bad.append(f"shard {sid} installed without aR-trees")
        bad.extend(self.replicas.audit(self.routing, self.dead_machines))
        return bad

    # ------------------------------------------------------------------ #
    # online phase
    # ------------------------------------------------------------------ #
    def query(self, query: LabeledGraph, plan_mode: str = "pescore",
              device_probe: bool | None = None,
              probe_mode: str | None = None,
              budget: QueryBudget | None = None
              ) -> tuple[list[tuple], QueryTelemetry]:
        """Exact matches of `query` in the data graph + telemetry.

        probe_mode picks the probe path — all three are bit-identical in
        candidates, matches and comm accounting:

          * "host":   one aR-tree traversal per (path, shard);
          * "device": ONE batched launch per query path (PR-2 slab,
            padded [S, max_leaves, D], both orientations fused — the
            slab is re-packed on the host per path);
          * "plane":  ONE fused launch per query PLAN over the
            device-resident shard planes (zero slab bytes when warm;
            readback is candidate row ids + counters only).

        The legacy device_probe bool maps True -> "device", False ->
        "host"; None falls back to the engine default set at build time.

        `budget` threads the degraded-mode serving knobs (deadline /
        read retries / hedging / brownout priority — repro.dist.router)
        through the probe and join stages; None uses the defaults (no
        deadline, priority 1 = never shed).
        """
        if probe_mode is None:
            if device_probe is None:
                probe_mode = self.probe_mode
            else:
                probe_mode = "device" if device_probe else "host"
        if probe_mode not in ("host", "device", "plane"):
            raise ValueError(f"unknown probe_mode {probe_mode!r}")
        self._check_available()
        self._fire_hook(HOOK_QUERY)
        tel = QueryTelemetry(plan_mode=plan_mode, probe_mode=probe_mode,
                             device_probe=probe_mode != "host")
        # admission control AFTER the hook: a crash-induced brownout
        # sheds (typed) the very query that observed it
        tel.outcome.health = self.router.admit(budget)
        self._qclock += 1.0
        key = self._query_key(query)

        cached = self._cache_lookup(key, tel)
        if cached is not None:
            return cached, tel
        return self._execute_serial(query, key, tel, plan_mode, probe_mode,
                                    budget)

    def _execute_serial(self, query: LabeledGraph, key,
                        tel: QueryTelemetry, plan_mode: str,
                        probe_mode: str, budget: QueryBudget | None = None
                        ) -> tuple[list[tuple], QueryTelemetry]:
        """`query`'s post-cache-miss body (plan -> probe -> join).

        Also the megabatch eviction-race fallback: a query whose cached
        result vanished between dispatch and consume re-enters here, on
        the already-bumped qclock and already-missed cache access.
        """
        # reprolint: disable=RPR004 -- plan_ms is a wall diagnostic
        t_plan = time.perf_counter()
        tables, q_embs, order = self._plan_artifacts(query, key, plan_mode,
                                                     tel)
        # reprolint: disable=RPR004 -- plan_ms is a wall diagnostic
        plan_ms = (time.perf_counter() - t_plan) * 1e3

        n_d = self.graph.n_vertices
        masks = self._initial_masks(query)
        alive = all(m.any() for m in masks)

        machine_ms: dict[int, float] = defaultdict(float)
        qid = int(self._qclock)
        rows_by_machine: dict[int, int] = defaultdict(int)
        # one routed read per (query, shard): the router resolves the
        # primary-or-standby serving copy under the retry/hedge budget
        routes: dict[int, Route] = {}

        # plane mode: ONE fused launch for the whole plan, up front.
        # Early-exited paths simply never read their precomputed rows
        # (their comm/latency accounting stays untouched, exactly like a
        # skipped host probe), so bit-identity with the host loop holds.
        plan_hits = None
        if probe_mode == "plane" and alive and order:
            plan_hits = self._plan_probe(tables, order, q_embs, tel)

        for ti, r in order:
            if not alive:
                tel.paths_skipped += 1
                continue
            table = tables[ti]
            l = table.length
            qv = table.vertices[r]
            qe = q_embs[ti][r]
            q_rev = _reverse_embedding(qe[None, :], l + 1)[0]
            pos_mask = np.zeros((l + 1, n_d), dtype=bool)
            # central node: root-MBR skip from the <1KB metadata (every
            # copy is CRC-identical, so this reads the master snapshot
            # regardless of liveness), then ROUTE the surviving shards'
            # probes to their live serving copies
            probes: list[tuple[int, Route]] = []
            for sid in sorted(self.shards):
                tree = self.router.metadata(sid).trees.get(l)
                if tree is None or tree.n_points == 0:
                    continue
                if _root_skip(tree, qe, q_rev):
                    tel.shards_skipped += 1
                    continue
                rt = routes.get(sid)
                if rt is None:
                    rt = routes[sid] = self.router.read(sid, budget, tel)
                probes.append((sid, rt))
            if probes and plan_hits is not None:
                # read this path's survivors from the plan-wide launch;
                # same deterministic service-time attribution as the
                # per-path device branch below.  Degraded shards have no
                # lane in the launch (their primary's planes died with
                # it): fall back PER SHARD to a host probe of the
                # standby copy, same deterministic virtual cost.
                base, res = plan_hits["row_of"][(ti, r)], plan_hits["res"]
                probe_ms, verts_of = {}, {}
                for sid, rt in probes:
                    index = rt.shard.index
                    if rt.degraded or sid not in plan_hits["sids"]:
                        verts_of[sid], _ = path_candidates(index, qe, l)
                        tel.probe_launches += 1
                    else:
                        idx_f = res.hits(sid, l, base)
                        idx_r = res.hits(sid, l, base + 1)
                        verts_of[sid], _ = _scatter_hits(
                            index.embedded[l], idx_f, idx_r)
                    probe_ms[sid] = (index.trees[l].n_points
                                     * VIRTUAL_MS_PER_LEAF)
            elif probes and probe_mode == "device":
                # pad all probed shards into one [S, max_leaves, D] slab
                # and launch once; survivor rows scatter back per shard.
                # Service time is attributed per shard as a DETERMINISTIC
                # virtual cost (leaves x VIRTUAL_MS_PER_LEAF): the wall
                # time of a batched launch includes one-off jit compiles
                # per slab-shape bucket and cannot be attributed to a
                # machine without poisoning the load telemetry.
                bs: dict[str, int] = {}
                results = batched_path_candidates(
                    [rt.shard.index for _, rt in probes], qe, l,
                    byte_stats=bs)
                tel.probe_launches += 1
                tel.probe_h2d_bytes += bs.get("h2d_bytes", 0)
                tel.probe_d2h_bytes += bs.get("d2h_bytes", 0)
                probe_ms = {sid: rt.shard.index.trees[l].n_points
                            * VIRTUAL_MS_PER_LEAF for sid, rt in probes}
                verts_of = {sid: verts
                            for (sid, _), (verts, _) in zip(probes, results)}
            else:
                probe_ms, verts_of = {}, {}
                for sid, rt in probes:
                    # reprolint: disable=RPR004 -- probe_ms wall diag
                    t0 = time.perf_counter()
                    verts_of[sid], _ = path_candidates(rt.shard.index,
                                                       qe, l)
                    # reprolint: disable=RPR004 -- probe_ms wall diag
                    probe_ms[sid] = (time.perf_counter() - t0) * 1e3
                    tel.probe_launches += 1
            for sid, rt in probes:
                # shard-side filter against the candidate masks the
                # master shipped with the probe: only surviving rows
                # cross the network (what PE-score ordering optimizes);
                # comm/CPU are attributed to the machine that actually
                # SERVED the read (the standby when the primary is dead)
                self._account_rows(sid, l, qv,
                                   rt.shard.global_ids[verts_of[sid]],
                                   masks, probe_ms[sid], machine_ms,
                                   rows_by_machine, qid, tel, pos_mask,
                                   machine=rt.machine)
            for i, qvi in enumerate(qv):
                masks[qvi] &= pos_mask[i]
                if not masks[qvi].any():
                    alive = False
            tel.paths_executed += 1

        return self._finish_query(query, key, tel, masks, alive,
                                  machine_ms, rows_by_machine, plan_ms,
                                  budget)

    # -------------------------------------------------------------- #
    # shared per-query execution pieces.  The serial probe paths and
    # megabatch consume BOTH run these — the megabatch bit-identity
    # contract depends on them staying single-sourced.
    # -------------------------------------------------------------- #
    def _initial_masks(self, query: LabeledGraph) -> list[np.ndarray]:
        """Per-query-vertex label + degree candidate masks over n_d."""
        deg_d, deg_q = self.graph.degrees, query.degrees
        return [(self.graph.labels == query.labels[v])
                & (deg_d >= deg_q[v]) for v in range(query.n_vertices)]

    def _query_key(self, query: LabeledGraph) -> tuple:
        """Result-cache / plan-LRU key: data epoch + query signature.

        The leading `_data_epoch` component is the exactness-preserving
        consistency stamp for streaming updates: every `apply_updates`
        bumps it, so a post-update query can NEVER be served a
        pre-update answer — the old epoch's keys simply stop matching
        (and are purged).  The scope is deliberately engine-global, not
        per-shard: a cached RESULT depends on the whole data graph
        through the cross-shard join (an edge inserted in shard A can
        create matches for a query whose candidates all live in shard
        B), so per-shard epochs can only scope the index/plane
        invalidation, never result validity.
        """
        return (self._data_epoch, query.n_vertices, query.labels.tobytes(),
                query.edge_list.tobytes())

    def _cache_lookup(self, key, tel: QueryTelemetry):
        """Cache access at query start; returns the hit or None."""
        if not self.use_cache:
            return None
        res = self.cache.access(key, self._slave_store,
                                dead=self.dead_machines)
        tel.latency_ms += res.latency_ms
        if res.data is None:
            return None
        tel.cache_hits = 1
        tel.n_matches = len(res.data)
        self._observe_cache(key, hit=True, matched=bool(res.data),
                            latency_ms=tel.latency_ms)
        return list(res.data)

    def _cache_peek(self, key) -> bool:
        """Read-only: would `cache.access` return data right now?

        No LRU / statistics mutation — megabatch dispatch uses it to
        skip speculative probe packing for queries the consume-time
        (authoritative, mutating) lookup will serve from cache.  Both
        sides thread `dead_machines`, so a key homed on a dead machine
        is unservable to dispatch AND consume alike.
        """
        return self.use_cache and self.cache.peek(key, self._slave_store,
                                                  dead=self.dead_machines)

    def _account_rows(self, sid: int, l: int, qv, gverts, masks,
                      probe_ms: float, machine_ms, rows_by_machine,
                      qid: int, tel: QueryTelemetry, pos_mask,
                      machine: int | None = None) -> None:
        """One probed shard's running-mask filter + comm/CPU accounting.

        ``gverts`` are the shard's raw (or in-kernel pre-filtered)
        candidate rows as GLOBAL vertex ids aligned to query path `qv`;
        only rows surviving the running masks count as network traffic.
        ``machine`` is the machine that actually served the read (the
        router's primary-or-standby resolution) — service time and comm
        bytes are attributed there, never blindly to the routing-table
        primary (which may be dead under degraded-mode serving).
        """
        mk = machine if machine is not None else self.router.primary(sid)
        service_ms = probe_ms / self.cpu_w[mk]
        if gverts.shape[0]:
            ok = np.ones(gverts.shape[0], dtype=bool)
            for i in range(l + 1):
                ok &= masks[qv[i]][gverts[:, i]]
            gverts = gverts[ok]
        n_rows = int(gverts.shape[0])
        tx_bytes = n_rows * ROW_BYTES_PER_VERTEX * (l + 1)
        machine_ms[mk] += service_ms
        self._cpu[sid] += service_ms
        self._comm[sid] += tx_bytes
        if n_rows:
            self._touch[sid].add(qid)
            rows_by_machine[mk] += n_rows
        tel.comm_bytes += tx_bytes
        tel.cross_shard_rows += n_rows
        if tx_bytes:
            # surviving candidate rows travel shard-holder -> master
            self.transport.account(CH_ROWS, tx_bytes, dst=mk)
        for i in range(l + 1):
            pos_mask[i, gverts[:, i]] = True

    def _finish_query(self, query: LabeledGraph, key,
                      tel: QueryTelemetry, masks, alive: bool,
                      machine_ms, rows_by_machine, plan_ms: float,
                      budget: QueryBudget | None = None
                      ) -> tuple[list[tuple], QueryTelemetry]:
        """Join + latency attribution + cache homing/admission.

        Homing rule: the cached result lands on the LIVE machine that
        produced the most candidate rows; never onto a dead machine (a
        query that probed nothing must not default to machine 0 if 0 is
        dead).  With no live machine at all there is nowhere to cache:
        home is None and admission is skipped.
        """
        # reprolint: disable=RPR004 -- join_ms is a wall diagnostic
        t_join = time.perf_counter()
        matches = backtrack_join(query, self.graph, masks) if alive else []
        # reprolint: disable=RPR004 -- join_ms is a wall diagnostic
        join_ms = (time.perf_counter() - t_join) * 1e3

        tel.n_matches = len(matches)
        comm_ms = tel.comm_bytes / LINK_BYTES_PER_MS
        tel.latency_ms += (max(machine_ms.values(), default=0.0)
                           + comm_ms + plan_ms + join_ms + 0.05
                           + tel.outcome.stall_ms)
        if (budget is not None and budget.timeout_ms is not None
                and tel.latency_ms > budget.timeout_ms):
            # soft breach: the answer is already exact and is returned;
            # the typed marker lets SLO accounting see the miss (a HARD
            # breach — stall alone exceeding the budget mid-read —
            # raises QueryDeadlineExceeded from the router instead)
            tel.outcome.deadline_exceeded = True
        live_rows = {k: v for k, v in rows_by_machine.items()
                     if k not in self.dead_machines}
        if live_rows:
            home = max(live_rows, key=live_rows.get)
        else:
            home = next((spec.machine_id for spec in self.specs
                         if spec.machine_id not in self.dead_machines),
                        None)
        self._observe_cache(key, hit=False, matched=bool(matches),
                            latency_ms=tel.latency_ms,
                            result=matches, slave_id=home,
                            degraded=tel.outcome.served_degraded)
        return matches, tel

    # -------------------------------------------------------------- #
    def _plan_artifacts(self, query: LabeledGraph, key, plan_mode: str,
                        tel: QueryTelemetry):
        """(tables, q_embs, order) for a query, memoized on `key`.

        Path decomposition, path embeddings and ranked orders are pure
        in (query, params, pe_model) — all fixed after build — so
        repeated query shapes skip paths_of_query + embed_query_paths
        entirely; `tel.plan_cache_hits` counts the reuse.  Orders are
        cached per plan_mode inside the entry.
        """
        ent = self._plan_lru.get(key)
        if ent is None:
            tables = paths_of_query(query, self.max_path_length)
            q_embs = [embed_query_paths(query, self.params, self.cfg, t)
                      for t in tables]
            ent = {"tables": tables, "q_embs": q_embs, "orders": {}}
            self._plan_lru[key] = ent
            while len(self._plan_lru) > PLAN_LRU_SIZE:
                self._plan_lru.popitem(last=False)
        else:
            self._plan_lru.move_to_end(key)
            tel.plan_cache_hits += 1
        order = ent["orders"].get(plan_mode)
        if order is None:
            if plan_mode == "pescore":
                order = rank_query_plan(
                    query, self.pe_model,
                    max_path_length=self.max_path_length,
                    tables=ent["tables"], q_embs=ent["q_embs"]).order
            elif plan_mode == "degree":
                order = degree_based_plan(query, tables=ent["tables"]).order
            elif plan_mode == "random":
                # deterministic per query signature: hash() is process-
                # randomized, crc32 of the cache key is not
                order = random_plan(query, seed=zlib.crc32(repr(key).encode()),
                                    tables=ent["tables"]).order
            else:
                order = [(ti, r) for ti, t in enumerate(ent["tables"])
                         for r in range(t.n_paths)]
            ent["orders"][plan_mode] = order
        return ent["tables"], ent["q_embs"], order

    # -------------------------------------------------------------- #
    def _plan_probe(self, tables, order, q_embs, tel: QueryTelemetry):
        """ONE fused device launch for every path of the query plan.

        Assembles the resident shard planes of every length the plan
        touches (warm planes and a warm assembly ship ZERO slab bytes),
        stacks all (path, orientation) embeddings on the query axis —
        rows are -inf-padded past their own length's width so different
        lengths share the launch — and reads back only candidate row ids
        + counters.  Returns {"res": PlanProbeResult, "row_of":
        {(ti, r): fwd query-row}}, or None when there is nothing to
        probe.  Stale planes (index replaced by migration/failover) are
        repacked before use by the identity check in ClusterPlanes.
        """
        lengths = sorted({tables[ti].length for ti, _ in order})
        # degraded shards (primary dead, promotion deferred) have no
        # resident planes to assemble — their probes fall back per shard
        # to a host read of the standby copy in the path loop
        degraded = self.router.degraded_sids()
        entries = []
        planned: set[int] = set()
        for sid in sorted(self.shards):
            if sid in degraded:
                continue
            index = self.router.metadata(sid)
            for l in lengths:
                tree = index.trees.get(l)
                if tree is not None and tree.n_points:
                    entries.append((sid, l, tree))
                    planned.add(sid)
        if not entries:
            return None
        qrows: list[tuple[np.ndarray, int]] = []
        row_of: dict[tuple[int, int], int] = {}
        for ti, r in order:
            l = tables[ti].length
            qe = q_embs[ti][r]
            row_of[(ti, r)] = len(qrows)
            qrows.append((qe, l))
            qrows.append((_reverse_embedding(qe[None, :], l + 1)[0], l))
        h2d0 = self.planes.stats["h2d_bytes"]
        d2h0 = self.planes.stats["d2h_bytes"]
        res = self.planes.probe(entries, qrows)
        tel.probe_launches += 1
        # stats deltas, not res.h2d_bytes: a cold probe (first after
        # build or invalidation) also pays plane repacking + assembly
        # metadata, and the telemetry must show that amortization
        tel.probe_h2d_bytes += self.planes.stats["h2d_bytes"] - h2d0
        tel.probe_d2h_bytes += self.planes.stats["d2h_bytes"] - d2h0
        return {"res": res, "row_of": row_of, "sids": planned}

    def _observe_cache(self, key, hit: bool, matched: bool,
                       latency_ms: float, result=None,
                       slave_id: int | None = 0,
                       degraded: bool = False) -> None:
        """slave_id=None means no live machine can hold the result:
        feature tracking still runs, admission is skipped.  ``degraded``
        marks results computed from standby reads — admitted normally
        (they are bit-identical by construction) but counted by the
        cache so the availability bench can report how much of the
        working set was filled while serving degraded."""
        self.tracker.record_query(self._qclock, [key], {key: matched})
        feats = np.asarray(self.tracker.features(key), np.float32)
        self.aw.observe(feats, 1.0 if hit else 0.0)
        if not self.use_cache:
            return
        if result is not None and slave_id is not None:
            w = self.aw.weights(feats[None])[0]
            value = float((w * feats).sum())
            self._slave_store[slave_id][key] = result
            self.cache.register(key, slave_id)
            self.cache.admit(key, result, value=value,
                             avg_deg=float(self.graph.avg_degree()),
                             slave_id=slave_id,
                             hit_rate=self.cache.hit_rate,
                             latency_ms=latency_ms,
                             degraded=degraded)
        if self._defer_aw:
            # epoch-batched Algorithm-5: record the training signal; one
            # update is applied at the end of the run_workload epoch
            self._aw_pending.append((self.cache.hit_rate, latency_ms))
        elif self.aw.should_train(self.cache.hit_rate):
            self.aw.train_once(self.cache.hit_rate, latency_ms)

    # ------------------------------------------------------------------ #
    # megabatch execution (multi-query fused probe launches)
    # ------------------------------------------------------------------ #
    def query_batch(self, queries: list[LabeledGraph],
                    plan_mode: str = "pescore",
                    budget: QueryBudget | None = None
                    ) -> list[tuple[list[tuple], QueryTelemetry]]:
        """Execute B queries with ONE fused multi-query probe launch.

        All (path, orientation) rows of every query plan in the batch are
        packed per length and probed against the device-resident shard
        planes in a single leaf-dominance launch whose readback is
        pre-filtered in-kernel by each query's label/degree candidate
        masks (shipped as a packed bit operand).  Joins then run
        sequentially in stream order, so matches, per-query counters and
        comm-byte accounting are bit-identical to calling `query(q,
        probe_mode="plane")` per query; the launch and its host<->device
        bytes are attributed to the batch's FIRST query.  If a migration
        or failover replaced a shard index between dispatch and consume,
        the whole batch transparently re-runs on the serial plane path.

        `budget` applies batch-wide: one admission decision at dispatch
        (the whole batch is shed together under brownout) and the same
        deadline / read-retry knobs for every member query.
        """
        self._check_available()
        return self._mb_consume(self._mb_dispatch(list(queries), plan_mode,
                                                  budget))

    def _mb_dispatch(self, batch: list[LabeledGraph], plan_mode: str,
                     budget: QueryBudget | None = None) -> dict:
        """Plan every query of a batch and launch the fused probe
        WITHOUT blocking on it (JAX async dispatch): the returned flight
        is consumed later, overlapping device probing with host work."""
        health = self.router.admit(budget)
        items = []
        for query in batch:
            tel = QueryTelemetry(plan_mode=plan_mode, probe_mode="plane",
                                 device_probe=True, batch_size=len(batch))
            tel.outcome.health = health
            key = self._query_key(query)
            if self._cache_peek(key):
                # consume's (authoritative) lookup will serve this from
                # cache: skip planning and probe packing entirely.  If
                # the entry is evicted before consume, _consume_query
                # falls back to the serial plane path.
                items.append(dict(query=query, key=key, tel=tel,
                                  peeked=True, order=[], alive=False,
                                  masks0=[], plan_ms=0.0, qrow_of={}))
                continue
            # reprolint: disable=RPR004 -- plan_ms is a wall diagnostic
            t0 = time.perf_counter()
            tables, q_embs, order = self._plan_artifacts(query, key,
                                                         plan_mode, tel)
            # reprolint: disable=RPR004 -- plan_ms is a wall diagnostic
            plan_ms = (time.perf_counter() - t0) * 1e3
            masks0 = self._initial_masks(query)
            items.append(dict(query=query, key=key, tel=tel, tables=tables,
                              q_embs=q_embs, order=order, masks0=masks0,
                              alive=all(m.any() for m in masks0),
                              plan_ms=plan_ms, qrow_of={}, peeked=False))

        # degraded shards (primary dead, promotion deferred under route
        # failover) have no resident planes — they get no lane in the
        # flight and fall back per shard in _consume_query
        degraded = self.router.degraded_sids()
        entries = []
        for sid in sorted(self.shards):
            if sid in degraded:
                continue
            index = self.router.metadata(sid)
            for l, tree in sorted(index.trees.items()):
                if tree is not None and tree.n_points:
                    entries.append((sid, l, tree))
        flight, h2d = None, 0
        if entries and any(it["alive"] and it["order"] for it in items):
            def gverts_fn(sid, l, tree):
                shard = self.router.resolve(sid).shard
                return shard.global_ids[
                    shard.index.embedded[l].vertices[tree.perm]]
            h2d0 = self.planes.stats["h2d_bytes"]
            assembly = self.planes.mega_assemble(entries, gverts_fn)
            # the shared packed-mask operand: one bit row per (query,
            # query-vertex); reversed-orientation rows index the same
            # bits with their positions reversed.  Rows are padded to
            # MASK_ROW_BUCKET inside pack_mask_bits — the raw total
            # vertex count varies per batch mix and would retrace the
            # fused launch on nearly every call.
            bases, all_masks = [], []
            for it in items:
                bases.append(len(all_masks))
                all_masks.extend(it["masks0"])
            mask_bits = pack_mask_bits(all_masks, self.graph.n_vertices)
            qmat: dict[int, list] = defaultdict(list)
            mask_rows: dict[int, list] = defaultdict(list)
            for qi, it in enumerate(items):
                if not (it["alive"] and it["order"]):
                    continue
                for ti, r in it["order"]:
                    table = it["tables"][ti]
                    l = table.length
                    if l not in assembly.blocks:
                        continue
                    qe = it["q_embs"][ti][r]
                    rows = bases[qi] + table.vertices[r].astype(np.int32)
                    it["qrow_of"][(ti, r)] = len(qmat[l])
                    qmat[l].append(qe)
                    mask_rows[l].append(rows)
                    qmat[l].append(_reverse_embedding(qe[None, :],
                                                      l + 1)[0])
                    mask_rows[l].append(rows[::-1])
            if qmat:
                qstk = {l: np.stack(v) for l, v in qmat.items()}
                mstk = {l: np.stack(v) for l, v in mask_rows.items()}
                # the fused-launch operands (query embeddings, mask-row
                # indirection, packed masks) ship master -> every
                # shard-holder rank before the launch
                self.transport.broadcast(
                    CH_OPERANDS,
                    mask_bits.nbytes + sum(a.nbytes for a in qstk.values())
                    + sum(a.nbytes for a in mstk.values()))
                flight = self.planes.mega_dispatch(assembly, qstk, mstk,
                                                   mask_bits)
            h2d = self.planes.stats["h2d_bytes"] - h2d0
        return {"items": items, "flight": flight, "plan_mode": plan_mode,
                "h2d_bytes": h2d, "data_epoch": self._data_epoch,
                "budget": budget}

    def _mb_consume(self, mb: dict
                    ) -> list[tuple[list[tuple], QueryTelemetry]]:
        """Read back a dispatched megabatch and finish every query in
        stream order (cache access, running-mask filtering, comm
        accounting, join, cache admission — the exact serial sequence)."""
        # mid-megabatch fault point: a crash here replaces shard indexes
        # via failover promotion, which the epoch stamp / assembly
        # identity checks below catch — the batch then re-runs serially
        # on post-failover state, bit-identical by the fallback contract
        self._fire_hook(HOOK_BATCH)
        items, flight = mb["items"], mb["flight"]
        # a streaming update between dispatch and consume invalidates the
        # WHOLE in-flight batch, not just its probe slabs: the packed
        # label/degree mask operand, the planned keys and the join all
        # reference the pre-update graph.  The epoch stamp catches every
        # update (even ones that happen to leave all packed trees
        # intact); the assembly identity check below remains the
        # migration/failover backstop.
        stale = mb.get("data_epoch") != self._data_epoch
        fb_keys: set = set()
        if not stale and flight is not None and flight.launches:
            live = {(sid, l): tree
                    for sid, shard in self.shards.items()
                    for l, tree in shard.index.trees.items()}
            # per-shard staleness: only the (sid, length) slabs whose
            # index moved under the launch (migration / failover) fall
            # back to host probes of the routed copy — the rest of the
            # batch keeps its fused results.  A stale EPOCH (streaming
            # update) still invalidates the whole batch above, because
            # the packed masks and planned keys reference the old graph.
            fb_keys = flight.assembly.stale_keys(live)
        if stale:
            # the graph changed under the dispatched launch: the serial
            # plane path repacks on live state, bit-identical results
            return [self.query(it["query"], plan_mode=mb["plan_mode"],
                               probe_mode="plane", budget=mb.get("budget"))
                    for it in items]
        res = None
        d2h, h2d_sel = 0, 0
        if flight is not None and flight.launches:
            h2d0 = self.planes.stats["h2d_bytes"]
            res = self.planes.mega_readback(flight)
            d2h = res.d2h_bytes
            h2d_sel = self.planes.stats["h2d_bytes"] - h2d0
            if d2h:
                # surviving candidate ids gather back from the ranks
                self.transport.gather(CH_READBACK, d2h)
        out = []
        for i, it in enumerate(items):
            matches, tel = self._consume_query(it, res, fb_keys,
                                               mb.get("budget"))
            if i == 0:
                # batch-attribution rule: the fused launch, the gather
                # launch and all their bytes land on the FIRST query
                tel.probe_launches += res.launches if res else 0
                tel.probe_h2d_bytes += mb["h2d_bytes"] + h2d_sel
                tel.probe_d2h_bytes += d2h
            out.append((matches, tel))
        return out

    def _consume_query(self, it: dict, res, fb_keys: set = frozenset(),
                       budget: QueryBudget | None = None
                       ) -> tuple[list[tuple], QueryTelemetry]:
        """One query's post-probe execution, bit-identical to `query`."""
        query, key, tel = it["query"], it["key"], it["tel"]
        self._qclock += 1.0
        cached = self._cache_lookup(key, tel)
        if cached is not None:
            return cached, tel
        if it["peeked"]:
            # the cached entry vanished between dispatch and consume
            # (eviction race): nothing was packed for this query, so it
            # re-enters the serial plane body on this same cache miss
            return self._execute_serial(query, key, tel, tel.plan_mode,
                                        "plane", budget)
        tables, q_embs = it["tables"], it["q_embs"]
        masks = [m.copy() for m in it["masks0"]]
        alive = it["alive"]
        n_d = self.graph.n_vertices
        machine_ms: dict[int, float] = defaultdict(float)
        qid = int(self._qclock)
        rows_by_machine: dict[int, int] = defaultdict(int)
        routes: dict[int, Route] = {}
        eps = 1e-5
        for ti, r in it["order"]:
            if not alive:
                tel.paths_skipped += 1
                continue
            table = tables[ti]
            l = table.length
            qv = table.vertices[r]
            qe = q_embs[ti][r]
            q_rev = _reverse_embedding(qe[None, :], l + 1)[0]
            pos_mask = np.zeros((l + 1, n_d), dtype=bool)
            blk = res.assembly.blocks.get(l) if res is not None else None
            qrow = it["qrow_of"].get((ti, r))
            served: set[int] = set()
            if blk is not None and qrow is not None:
                # vectorized root-MBR skip: same per-shard predicate the
                # serial loop evaluates one tree at a time
                skip = ((qe[None, :] > blk.up_max + eps).any(axis=1)
                        & (q_rev[None, :] > blk.up_max + eps).any(axis=1))
                for s_i, sid in enumerate(blk.sids):
                    if (sid, l) in fb_keys:
                        # this slab's index moved between dispatch and
                        # consume: its fused rows are orphaned — the
                        # fallback loop below re-probes the live copy
                        continue
                    served.add(sid)
                    if skip[s_i]:
                        tel.shards_skipped += 1
                        continue
                    rt = routes.get(sid)
                    if rt is None:
                        rt = routes[sid] = self.router.read(sid, budget,
                                                            tel)
                    ids_f = res.candidates(l, sid, qrow)
                    ids_r = res.candidates(l, sid, qrow + 1)
                    # rows arrive pre-filtered by the INITIAL label/
                    # degree masks (in-kernel); the running masks are a
                    # subset, so re-filtering the smaller set yields
                    # exactly the serial survivors and comm bytes
                    gv = np.concatenate(
                        [blk.gverts_host[s_i][ids_f],
                         blk.gverts_host[s_i][ids_r][:, ::-1]])
                    self._account_rows(
                        sid, l, qv, gv, masks,
                        float(blk.n_points[s_i]) * VIRTUAL_MS_PER_LEAF,
                        machine_ms, rows_by_machine, qid, tel, pos_mask,
                        machine=rt.machine)
            # per-shard fallback: shards with no lane in the flight
            # (degraded at dispatch, or slab gone stale under it) are
            # re-probed on the host against the ROUTED live copy — the
            # same deterministic virtual cost as a serial host probe
            for sid in sorted(self.shards):
                if sid in served:
                    continue
                tree = self.router.metadata(sid).trees.get(l)
                if tree is None or tree.n_points == 0:
                    continue
                if _root_skip(tree, qe, q_rev):
                    tel.shards_skipped += 1
                    continue
                rt = routes.get(sid)
                if rt is None:
                    rt = routes[sid] = self.router.read(sid, budget, tel)
                verts, _ = path_candidates(rt.shard.index, qe, l)
                tel.probe_launches += 1
                self._account_rows(
                    sid, l, qv, rt.shard.global_ids[verts], masks,
                    tree.n_points * VIRTUAL_MS_PER_LEAF,
                    machine_ms, rows_by_machine, qid, tel, pos_mask,
                    machine=rt.machine)
            for i, qvi in enumerate(qv):
                masks[qvi] &= pos_mask[i]
                if not masks[qvi].any():
                    alive = False
            tel.paths_executed += 1

        return self._finish_query(query, key, tel, masks, alive,
                                  machine_ms, rows_by_machine,
                                  it["plan_ms"], budget)

    # ------------------------------------------------------------------ #
    # streaming graph updates (exactness-preserving incremental re-index)
    # ------------------------------------------------------------------ #
    def apply_updates(self, delta: GraphDelta, corrupt_prob: float = 0.0,
                      refit_pe: bool = True) -> UpdateReport:
        """Apply a streaming update batch without a full rebuild.

        Pipeline (owner routing -> incremental re-index -> CRC'd deltas
        -> epoch bump -> scoped invalidation):

          1. the batch mutates the data graph (ids stable: vertices
             append, deletes detach — see `GraphDelta`);
          2. vertex embeddings are re-encoded once on the updated graph;
             a vertex is DIRTY iff its embedding row (or structure)
             actually changed — the update's blast zone plus any float
             drift, detected by comparison, never modeled;
          3. the canonical-owner rule routes the re-index: a shard is
             TOUCHED iff its owned region intersects the update's
             halo-radius blast zone or its region holds a dirty vertex.
             Touched shards re-enumerate in canonical order, splice
             clean embedding rows from the previous epoch (re-embedding
             ONLY paths through dirty vertices) and bulk-reload their
             aR-trees;
          4. each touched shard's changes ship as a CRC32-verified
             delta image over the migration transfer/retry machinery;
             unchanged path lengths are carried by identity, so their
             resident probe planes stay warm — only changed (sid,
             length) slabs are invalidated.  Untouched shards are never
             repacked (their planes keep their tokens: zero slab h2d);
          5. the global data epoch bumps: every result-cache key embeds
             it, so post-update queries can never be served pre-update
             answers; superseded results are purged, the plan LRU is
             cleared, and an in-flight megabatch spanning the update
             falls back to the serial plane path via its epoch stamp;
          6. the PE-score model refits on the updated index (same
             deterministic labels as an offline build), so plan ranking
             matches a from-scratch engine.

        The whole pipeline is pinned by the rebuild-equivalence
        property: update-then-query is bit-identical (matches, node
        counters, comm bytes) to a fresh `build` on the updated graph
        with the same assignment/params, in all three probe modes.

        Fault semantics: the STAGE phase fires the ``updates.stage``
        chaos hook per touched shard and the ``updates.commit`` hook
        just before the commit point.  A TransferTimeoutError during
        staging (primary or replica delta) propagates with the engine
        fully on the old epoch — the caller may simply retry.  A crash
        at either hook triggers failover inline; the transaction then
        commits on the post-failover placement (promoted replicas are
        content-identical to the primaries they replace).
        """
        self._check_available()
        if self.failover_mode == "route" and self.router.degraded_sids():
            # writes need a live PRIMARY per shard (the delta pipeline
            # installs onto primaries and fans out to standbys): fold
            # the deferred promotions in before staging anything.  A
            # shard with no live copy at all blocks the write with the
            # structured error — reads elsewhere keep being served.
            rec = self.recover()
            if rec["lost"]:
                raise ClusterUnavailableError(
                    f"streaming update blocked: shards {rec['lost']} "
                    f"have no live copy", reason="no-live-copy",
                    sids=tuple(rec["lost"]),
                    machines=tuple(sorted(self.dead_machines)))
        if delta.is_empty:
            return UpdateReport(data_epoch=self._data_epoch, noop=True,
                                n_shards=len(self.shards))
        if delta.add_vertex_labels.size and (
                int(delta.add_vertex_labels.max()) >= self.cfg.n_labels
                or int(delta.add_vertex_labels.min()) < 0):
            raise ValueError(
                f"new vertex label outside [0, {self.cfg.n_labels}); the "
                f"label vocabulary is fixed at build time")
        if self.retired_ids and delta.add_edges.size:
            bad = self.retired_ids.intersection(
                int(v) for v in np.unique(delta.add_edges))
            if bad:
                # `apply_graph_delta` only rejects same-batch
                # re-attachment; retirement across batches is the
                # engine's invariant (a retired id resurfacing is an
                # upstream routing bug, not a no-op)
                raise ValueError(
                    f"edge endpoints {sorted(bad)} were retired by an "
                    f"earlier update batch")
        old_graph = self.graph
        n_old = old_graph.n_vertices
        new_graph, info = apply_graph_delta(old_graph, delta)
        n_new = new_graph.n_vertices
        if info["seeds"].size == 0:
            # effectively empty: every insert/delete was a no-op, the
            # graph content is unchanged — keep the epoch, caches and
            # planes intact (idempotent upserts must not purge anything)
            return UpdateReport(data_epoch=self._data_epoch, noop=True,
                                n_shards=len(self.shards))

        # owner routing for appended vertices: deterministic
        # smallest-assigned-neighbor rule (isolated: round-robin) — the
        # rebuild oracle receives the SAME extended assignment
        asg = self.assignment
        if n_new > n_old:
            asg = np.concatenate([
                asg, np.zeros(n_new - n_old, asg.dtype)])
            n_shards = len(self.shards)
            for v in range(n_old, n_new):
                nbrs = new_graph.neighbors(v)
                nbrs = nbrs[nbrs < v]
                asg[v] = asg[int(nbrs.min())] if nbrs.size \
                    else v % n_shards

        # dirty vertices: re-encode once, diff against the previous
        # epoch's embedding snapshot; update seeds are forced dirty
        new_vemb = self._encode_data_graph(new_graph)
        dirty = np.zeros(n_new, bool)
        dirty[info["seeds"]] = True
        dirty[:n_old] |= (new_vemb[:n_old] != self._vemb).any(axis=1)

        # blast zone: halo-radius ball around the seeds in BOTH graphs
        # (a shard's region can only change if a seed lies within halo
        # range of its owned set in the old or the new topology)
        z_mask = np.zeros(n_new, bool)
        for g in (old_graph, new_graph):
            seeds = info["seeds"][info["seeds"] < g.n_vertices]
            if seeds.size:
                z_mask[halo_region(g, seeds.astype(np.int64),
                                   self._halo_eff)] = True

        touched = []
        for sid, shard in self.shards.items():
            if ((asg == sid) & z_mask).any() \
                    or dirty[shard.global_ids].any():
                touched.append(sid)

        report = UpdateReport(
            data_epoch=self._data_epoch + 1,
            n_added_edges=info["n_added_edges"],
            n_removed_edges=info["n_removed_edges"],
            n_added_vertices=info["n_added_vertices"],
            n_detached_vertices=info["n_detached_vertices"],
            touched_shards=sorted(touched), n_shards=len(self.shards))
        stats = {"paths_total": 0, "paths_reused": 0, "paths_reembedded": 0}

        # STAGE: all fallible work (region cut, re-index, delta build,
        # CRC'd transfer, install decode) runs before any engine state
        # mutates — a failure here leaves the engine fully on the old
        # epoch, never half-updated with still-valid old cache keys
        staged = []
        rep_staged = []
        try:
            for sid in sorted(touched):
                self._fire_hook(HOOK_UPDATE_STAGE)
                old_shard = self.shards[sid]
                new_shard = make_shard(new_graph, asg, sid,
                                       halo_hops=self._halo_eff)
                self._build_shard_index(new_shard, new_vemb,
                                        reuse_from=old_shard,
                                        dirty_gmask=dirty, stats=stats,
                                        build_trees=False)
                # CRC'd delta over the migration transfer machinery; the
                # hosting machine installs the verified image on top of
                # its replica (carried lengths keep identity -> warm
                # planes), and every live standby replica stages the
                # same image so it commits in lockstep with the primary
                blob = shard_delta(old_shard, new_shard)
                tr = self.transport.transfer(
                    blob, rng=self._rng, dst=self.routing.get(sid),
                    channel=CH_DELTA, corrupt_prob=corrupt_prob,
                    chaos=self.chaos)
                report.retransmissions += tr.retransmissions
                report.virtual_ms += tr.virtual_ms
                report.delta_bytes += len(blob)
                if not tr.ok:
                    # unreachable: an unconfirmed transfer raises — but
                    # if that invariant ever breaks, BOTH installing a
                    # corrupt image and silently skipping the shard
                    # would serve wrong answers, so fail loudly —
                    # BEFORE anything installed
                    raise RuntimeError(
                        f"shard {sid} update delta failed CRC after "
                        f"retries")
                staged.append((sid, old_shard,
                               apply_shard_delta(old_shard, tr.received)))
                rep_staged.extend(self.replicas.stage_delta(
                    sid, blob, self.dead_machines, self._rng,
                    chaos=self.chaos, transport=self.transport))
            # final fault point before the commit: a timeout or crash
            # here must still leave the engine fully-old
            self._fire_hook(HOOK_UPDATE_COMMIT)
        except TransferTimeoutError:
            self.aborted_transactions += 1
            raise                     # fully-old: nothing was installed

        # COMMIT: installs, epoch flip, cache scoping (no fallible
        # serialization/compute below — only assignments + invalidation).
        # Replica deltas skip holders that failover promoted to primary
        # or that died between stage and commit; conversely, any copy
        # that did NOT stage this delta (e.g. minted by a mid-stage
        # failover's re-replication from the old epoch) is dropped here
        # — a stale standby must never be promotable.
        rep_commit = [e for e in rep_staged
                      if e[1] != self.routing.get(e[0])
                      and e[1] not in self.dead_machines]
        delta_holders = {(sid, m) for sid, m, _, _ in rep_commit}
        for sid in sorted(touched):
            for m in list(self.replicas.copies.get(sid, {})):
                if (sid, m) not in delta_holders:
                    del self.replicas.copies[sid][m]
        self.replicas.commit_delta(rep_commit)
        self.graph = new_graph
        self.assignment = asg
        self.retired_ids.update(int(v) for v in delta.del_vertices)
        self._vemb = new_vemb
        inval_before = self.planes.stats["invalidations"]
        for sid, old_shard, installed in staged:
            old_trees = (old_shard.index.trees
                         if old_shard.index is not None else {})
            for l, tree in installed.index.trees.items():
                if old_trees.get(l) is not tree:
                    self.planes.invalidate(sid, l)
            self.shards[sid] = installed
            self.index_epoch[sid] += 1
            self._shard_bytes[sid] = float(installed.nbytes())
            # one extra O(shard) npz serialize; bounded by the canonical
            # re-enumeration + tree reload the staging loop already paid
            self._image_bytes[sid] = len(installed.serialize())
            self._label_hist[sid] = installed.label_histogram(
                self.cfg.n_labels)
        report.planes_invalidated = (self.planes.stats["invalidations"]
                                     - inval_before)
        report.paths_total = stats["paths_total"]
        report.paths_reused = stats["paths_reused"]
        report.paths_reembedded = stats["paths_reembedded"]
        # untouched entries fill lazily (first update pays them once);
        # no cluster-wide re-serialization on the steady-state path
        for sid, s in self.shards.items():
            if sid not in self._image_bytes:
                self._image_bytes[sid] = len(s.serialize())
        report.full_image_bytes = sum(self._image_bytes.values())

        # epoch bump: retire every pre-update result key (plan artifacts
        # too — ranked orders reference the superseded PE model/index)
        self._data_epoch += 1
        self._plan_lru.clear()
        report.results_purged = self._purge_stale_results()

        # fresh-build parity for the adaptive layers: eviction degree
        # threshold + PE-score plan ranking track the updated graph
        theta_d = protected_degree_threshold(new_graph.degrees)
        for vc in (self.cache.master, *self.cache.slaves):
            vc.theta_d = theta_d
        if refit_pe:
            self._refit_pe_model()
        # restore the replication factor for touched shards (copies may
        # have been dropped above) — best-effort: a failed sync degrades
        # redundancy, never correctness
        if self.replicas.k:
            try:
                for sid in sorted(touched):
                    self.replicas.sync_full(sid, self.shards[sid],
                                            self.routing[sid],
                                            self.dead_machines, self._rng,
                                            chaos=self.chaos,
                                            transport=self.transport)
            except TransferTimeoutError:
                pass
        self.update_reports.append(report)
        return report

    def _purge_stale_results(self) -> int:
        """Drop every cached result keyed to a superseded data epoch
        from the two-level cache AND the slave memory stores."""
        epoch = self._data_epoch

        def stale(k) -> bool:
            return (isinstance(k, tuple) and len(k) == 4
                    and k[0] != epoch)

        purged = self.cache.purge(stale)
        for store in self._slave_store.values():
            for k in [k for k in store if stale(k)]:
                del store[k]
        return purged

    def rebuild_reference(self) -> "DistributedGNNPE":
        """From-scratch engine on the CURRENT graph with this engine's
        partition assignment and GNN params — the rebuild-equivalence
        oracle: its shard indexes, plan ranking, matches, counters and
        comm accounting must be bit-identical to this engine's
        post-update state (property-tested in tests/test_updates.py)."""
        cfg = self._build_cfg
        return DistributedGNNPE.build(
            self.graph, cfg["n_machines"],
            shards_per_machine=cfg["shards_per_machine"],
            gnn_train_steps=cfg["gnn_train_steps"], seed=cfg["seed"],
            halo_hops=cfg["halo_hops"],
            max_path_length=cfg["max_path_length"],
            probe_mode=self.probe_mode,
            assignment=self.assignment, params=self.params,
            replication=cfg.get("replication", 0),
            failover_mode=cfg.get("failover_mode", "promote"))

    # ------------------------------------------------------------------ #
    # workload loop + balancing
    # ------------------------------------------------------------------ #
    def run_workload(self, queries: list[LabeledGraph],
                     rebalance: bool = False,
                     corrupt_prob: float = 0.0,
                     plan_mode: str = "pescore",
                     batch_size: int | None = None,
                     probe_mode: str | None = None,
                     cache_update_mode: str = "epoch"
                     ) -> list[QueryTelemetry]:
        """Execute a query stream (one epoch); optionally rebalance.

        batch_size=B (with the plane probe path) enables MEGABATCH
        execution: B-query fused probe launches, pipelined so batch
        k+1's launch runs on device while the host joins batch k.
        Results and deterministic per-query counters are bit-identical
        to the serial path; launches/bytes are attributed to each
        batch's first query.

        cache_update_mode="epoch" (default) batches AW-ResNet cache-
        policy training: rewards accumulate during the epoch and at most
        ONE Algorithm-5 update is applied at its end ("per_query"
        restores the legacy train-inside-the-stream schedule).

        The rebalance clock advances EPOCH_VIRTUAL_S virtual seconds per
        epoch — see the constant's docstring; the anti-thrash boost in
        `lb.alpha_decay` therefore decays over ALPHA_WINDOW_S /
        EPOCH_VIRTUAL_S epochs, never over a number of *queries*.
        """
        self._check_available()
        self._cpu.clear()
        self._comm.clear()
        self._touch.clear()
        if cache_update_mode not in ("epoch", "per_query"):
            raise ValueError(f"unknown cache_update_mode "
                             f"{cache_update_mode!r}")
        self._defer_aw = cache_update_mode == "epoch"
        self._aw_pending = []
        resolved = probe_mode if probe_mode is not None else self.probe_mode
        try:
            if batch_size and batch_size > 1 and resolved == "plane":
                tels: list[QueryTelemetry] = []
                chunks = [queries[i:i + batch_size]
                          for i in range(0, len(queries), batch_size)]
                mb = (self._mb_dispatch(chunks[0], plan_mode)
                      if chunks else None)
                for k in range(len(chunks)):
                    # pipeline: launch batch k+1 before joining batch k
                    nxt = (self._mb_dispatch(chunks[k + 1], plan_mode)
                           if k + 1 < len(chunks) else None)
                    tels.extend(t for _, t in self._mb_consume(mb))
                    mb = nxt
            else:
                tels = [self.query(q, plan_mode=plan_mode,
                                   probe_mode=probe_mode)[1]
                        for q in queries]
            if self._aw_pending:
                hit_rate = self._aw_pending[-1][0]
                latency = float(np.mean([l for _, l in self._aw_pending]))
                if self.aw.should_train(hit_rate):
                    self.aw.train_once(hit_rate, latency)
                self._aw_pending = []
        finally:
            self._defer_aw = False
        self._epoch += 1

        if rebalance:
            if self.failover_mode == "route" and self.router.degraded_sids():
                # epoch boundary: fold deferred (route-mode) promotions
                # into the routing table before planning — the balancer
                # only sees live telemetry rows, so a shard still routed
                # at a corpse would be invisible to it
                self.recover()
            # chaos fault point BEFORE telemetry: a crash here removes
            # the machine's telemetry row, so the balancer can never
            # plan a move onto the corpse
            self._fire_hook(HOOK_REBALANCE)
        tele = self._refresh_loads()
        rebalanced = False
        if rebalance:
            plan = lb.plan_migrations(
                tele, corr_fn=self._corr, wlabel_fn=self._wlabel,
                shard_sizes=self._shard_bytes,
                seconds_since_migration=(self._epoch
                                         - self._last_migration_epoch)
                * EPOCH_VIRTUAL_S)
            if plan.trigger and plan.moves:
                # per-step transactions: a stubborn link times out ONE
                # move (clean fully-old abort, retried with backoff,
                # then skipped-and-reported) instead of dropping the
                # whole rebalance epoch on the floor
                res = migrate_with_retry(self.shards, plan.moves,
                                         self.routing, rng=self._rng,
                                         corrupt_prob=corrupt_prob,
                                         chaos=self.chaos,
                                         transport=self.transport)
                self.aborted_transactions += res.timeouts
                if res.migrated:
                    self.migrations.append(res)
                    self._last_migration_epoch = self._epoch
                    rebalanced = True
                    # migrated shards carry freshly deserialized
                    # indexes: drop their resident probe planes (lazily
                    # repacked on the next plane-mode probe), then
                    # re-home their replicas off the new primary
                    # (best-effort: failure degrades redundancy only)
                    for sid in res.migrated:
                        self.planes.invalidate(sid)
                    if self.replicas.k:
                        try:
                            for sid in res.migrated:
                                self.replicas.sync_full(
                                    sid, self.shards[sid],
                                    self.routing[sid],
                                    self.dead_machines, self._rng,
                                    chaos=self.chaos,
                                    transport=self.transport)
                        except TransferTimeoutError:
                            pass
                    self._refresh_loads()
        self.history.append({
            "sigma": self.load_sigma(),
            "n_queries": len(queries),
            "rebalanced": rebalanced,
            "cache_hit_rate": self.cache.hit_rate,
        })
        return tels

    def handle_machine_failure(self, machine_id: int) -> list[int]:
        """Kill a machine and re-home its shards onto the survivors.

        Crash-consistent failover transaction:

          1. mark the machine dead (it factually died — this and the
             cache/replica purge happen even when the cluster ends up
             unavailable) and purge everything homed on it: slave
             ValueCache, slave memory results, master memory-index
             pointers, standby replicas;
          2. quorum check BEFORE any routing/shard mutation — no
             survivors, or a victim shard whose last copy died, raises
             a typed :class:`ClusterUnavailableError` (never a KeyError
             or a silently empty result) and latches the engine
             unavailable: every later operation raises too;
          3. with replication on, every victim PROMOTES a standby
             replica (pure dictionary move — the copy arrived through
             the same CRC pipeline as a migration, so it is
             bit-identical to the lost primary); with k=0 the legacy
             byte-image re-deserialize path re-homes victims onto
             survivors by deterministic LPT over shard bytes;
          4. victims' resident probe planes are invalidated so a
             plane-mode probe can never read a pre-failover slab, and
             the replication factor is restored best-effort.

        With ``failover_mode="route"`` (and k > 0) steps 3-4 DEFER:
        victims stay routed at the corpse and the ShardRouter serves
        their reads from standby replicas immediately — zero transfer,
        zero promotion on the crash path.  Promotion and re-replication
        fold in at the next :meth:`recover` (epoch boundary or write).
        Shards whose last copy died do NOT latch the engine: each query
        that needs one raises its own structured
        :class:`ClusterUnavailableError`, every other query keeps
        getting the exact answer.
        """
        if machine_id in self.dead_machines or machine_id >= len(self.specs):
            return []
        self.dead_machines.add(machine_id)
        self.router.health.record_crash(self._qclock)
        self.replicas.drop_machine(machine_id)
        self.cache.drop_slave(machine_id)
        self._slave_store[machine_id].clear()
        victims = sorted(sid for sid, mk in self.routing.items()
                         if mk == machine_id)
        survivors = [s.machine_id for s in self.specs
                     if s.machine_id not in self.dead_machines]
        if not survivors:
            self._unavailable = "no-survivors"
            raise ClusterUnavailableError(
                f"machine {machine_id} was the last live machine",
                reason="no-survivors",
                machines=tuple(sorted(self.dead_machines)))
        if self.replicas.k and self.failover_mode == "route":
            # deferred failover: reads route to standbys right away;
            # the planes of a dead primary died with it
            for sid in victims:
                self.planes.invalidate(sid)
            return victims
        if self.replicas.k:
            # PREPARE: verify every victim has a live standby before
            # mutating routing — all-or-nothing promotion
            lost = [sid for sid in victims
                    if not self.replicas.holders(sid, self.dead_machines)]
            if lost:
                self._unavailable = "no-live-copy"
                raise ClusterUnavailableError(
                    f"shards {lost} lost their last copy with machine "
                    f"{machine_id}", reason="no-live-copy",
                    sids=tuple(lost),
                    machines=tuple(sorted(self.dead_machines)))
            promos = [(sid, *self.replicas.promote(sid,
                                                   self.dead_machines))
                      for sid in victims]
            for sid, m, shard in promos:      # COMMIT: pure assignment
                self.shards[sid] = shard
                self.routing[sid] = m
        elif victims:
            # legacy simulator path: the dead machine's byte image is
            # still reachable; re-home by LPT over shard bytes (chaos-
            # free — this stand-in is superseded by replication)
            loads = {k: 0.0 for k in survivors}
            for sid, mk in self.routing.items():
                if mk in loads:
                    loads[mk] += self._shard_bytes[sid]
            moves = []
            for sid in sorted(victims,
                              key=lambda s: (-self._shard_bytes[s], s)):
                tgt = min(survivors,
                          key=lambda k: (loads[k] / self.cpu_w[k], k))
                loads[tgt] += self._shard_bytes[sid]
                moves.append((sid, machine_id, tgt))
            hot_migrate(self.shards, moves, self.routing, rng=self._rng,
                        transport=self.transport)
        for sid in victims:
            self.planes.invalidate(sid)
        if self.replicas.k:
            # re-replicate everything that lost a copy (victims and any
            # shard that had a standby on the corpse) — best-effort
            try:
                for sid in sorted(self.shards):
                    self.replicas.sync_full(sid, self.shards[sid],
                                            self.routing[sid],
                                            self.dead_machines, self._rng,
                                            chaos=self.chaos,
                                            transport=self.transport)
            except TransferTimeoutError:
                pass
        return victims

    def recover(self) -> dict:
        """Fold deferred (route-mode) failovers back into the cluster.

        Promotes a live standby for every shard still routed at a dead
        machine (pure dictionary move — same CRC-verified image the
        router was already serving), invalidates the victims' planes,
        restores the replication factor best-effort, and — when nothing
        stayed lost — clears the brownout crash window so the health
        state machine un-latches to HEALTHY.  Shards with NO live copy
        are reported in ``lost`` and stay degraded-routed: queries that
        need them keep raising the structured error, the engine itself
        never latches.

        Idempotent and safe to call any time (promote mode, no dead
        machines: a no-op).  Returns ``{"promoted", "lost", "state"}``.
        """
        promoted: list[int] = []
        lost: list[int] = []
        for sid in sorted(self.router.degraded_sids()):
            if not self.replicas.holders(sid, self.dead_machines):
                lost.append(sid)
                continue
            m, shard = self.replicas.promote(sid, self.dead_machines)
            self.shards[sid] = shard
            self.routing[sid] = m
            self.planes.invalidate(sid)
            promoted.append(sid)
        if self.replicas.k and promoted:
            # restore the replication factor off the new primaries
            # (best-effort: failure degrades redundancy, never answers).
            # Shards still routed at a corpse are genuinely lost — the
            # dead primary's byte image is NOT a legal sync source.
            try:
                for sid in sorted(self.shards):
                    if self.routing[sid] in self.dead_machines:
                        continue
                    self.replicas.sync_full(sid, self.shards[sid],
                                            self.routing[sid],
                                            self.dead_machines, self._rng,
                                            chaos=self.chaos,
                                            transport=self.transport)
            except TransferTimeoutError:
                pass
        if not lost:
            self.router.health.clear_window()
        return {"promoted": promoted, "lost": lost,
                "state": self.router.state()}

    def load_sigma(self) -> float:
        """Std of machine loads from the most recent workload epoch."""
        return lb.cluster_sigma(self._last_loads)

    def _refresh_loads(self) -> list[lb.MachineTelemetry]:
        """Recompute machine loads from the epoch's per-shard stats."""
        tele = self._machine_telemetry()
        comm_max = max((sum(t.comm.values()) for t in tele), default=1.0)
        self._last_loads = np.array(
            [lb.machine_load(t, max(comm_max, 1e-9)) for t in tele])
        return tele

    def _machine_telemetry(self) -> list[lb.MachineTelemetry]:
        """Per-machine telemetry; dead machines emit no row, so the
        balancer can never pick them as migration receivers."""
        total_cpu = sum(self._cpu.values()) or 1.0
        total_mem = sum(self._shard_bytes.values()) or 1.0
        tele = []
        for spec in self.specs:
            k = spec.machine_id
            if k in self.dead_machines:
                continue
            sids = [sid for sid, mk in self.routing.items() if mk == k]
            tele.append(lb.MachineTelemetry(
                machine_id=k, shard_ids=sids,
                cpu={s: self._cpu.get(s, 0.0) / total_cpu for s in sids},
                comm={s: float(self._comm.get(s, 0.0)) for s in sids},
                mem={s: self._shard_bytes[s] / total_mem for s in sids},
                corr={s: self._corr(s, k) for s in sids}))
        return tele

    def _corr(self, sid: int, machine_id: int) -> float:
        """Workload correlation: fraction of this epoch's queries that
        touched both `sid` and the target machine's resident shards."""
        mine = self._touch.get(sid, set())
        if not mine:
            return 0.0
        theirs: set = set()
        for other, mk in self.routing.items():
            if mk == machine_id and other != sid:
                theirs |= self._touch.get(other, set())
        return len(mine & theirs) / len(mine)

    def _wlabel(self, sid: int, machine_id: int) -> float:
        """Label affinity between a shard and a machine's working set."""
        hists = [self._label_hist[o] for o, mk in self.routing.items()
                 if mk == machine_id and o != sid]
        if not hists:
            return 0.5
        h_m = np.mean(hists, axis=0)
        h_s = self._label_hist[sid]
        denom = np.linalg.norm(h_m) * np.linalg.norm(h_s)
        return float(h_m @ h_s / denom) if denom > 0 else 0.5
