"""Transport: the single seam every inter-machine byte crosses.

Before this module, the engine moved bytes between "machines" by passing
Python objects around and incrementing counters in four different places
(`migration.crc_transfer`, `replica.sync_full`/`stage_delta`, the
router's standby reads, the megabatch operand/readback accounting).
Each of those is a *cross-machine interaction* the paper's distributed
claims depend on, and each had its own ad-hoc fault injection and byte
ledger.  This module carves the seam out: **no function outside the
transport may touch another machine's shard bytes directly** (reprolint
RPR009 enforces it, the same move RPR008 made for router reads).

Two backends ship behind the seam:

  * :class:`SimTransport` — today's in-process delivery plus the byte
    ledger.  Bit-identical to the pre-seam engine: the CRC/retry/backoff
    discipline, virtual-ms charges and rng consumption are byte-for-byte
    the old ``crc_transfer``, so every existing test keeps its meaning.
    The sim backend remains the deterministic oracle.
  * :class:`MeshTransport` — real process ranks over
    ``jax.distributed.initialize``.  Machine *k* maps to rank
    ``k % world``; each rank's probe planes are pinned to its local
    device (``ClusterPlanes.device_of``); shard images, update deltas,
    megabatch operands and candidate readbacks physically ship between
    ranks.  On real TPU/GPU meshes the shipments lower to device
    collectives built on the :mod:`repro.dist.sharding` rules; on the
    multi-process **CPU-rank CI fallback** XLA cannot run multiprocess
    collectives, so bytes travel through the ``jax.distributed``
    coordination-service KV store instead (same rank bootstrap, same
    process topology, verified CRC per hop).  With ``world == 1``
    ("loopback") every delivery round-trips through the local JAX device
    so the mesh code path is exercisable in-process.

Design rules the seam must keep:

  * **Ledger identity** — :meth:`Transport.account` maintains the
    *logical* per-channel byte ledger identically on every backend, so
    sim-vs-mesh runs agree bit-for-bit on comm-byte totals (the
    cross-backend acceptance property).  ``MeshTransport`` additionally
    tracks *physical* bytes-on-wire (:meth:`MeshTransport.measured`),
    which ``launch/dryrun.py --validate-census`` checks against the
    census prediction (:func:`predicted_wire`) at a <=10% gate.
  * **Chaos ownership** — the attached :class:`FaultPlan` lives on the
    transport (``DistributedGNNPE.chaos`` is a view of it); link faults
    fire inside :meth:`transfer` from the PLAN's rng only (RPR007), so
    identical fault schedules drive both backends.
  * **Engine-state residency** — the engine (shards dict, replica
    store, caches) is driver-resident on rank 0 in both backends; what
    the mesh backend distributes is the *byte movement* (and plane
    homes), not the Python control plane.  ``fetch_replica`` is the one
    legal accessor for standby copies; on an accelerator mesh it is
    where the remote read would issue.
"""

from __future__ import annotations

import base64
import dataclasses
import json
import os

import numpy as np

from repro.dist.chaos import (CORRUPT, HOOK_TRANSFER, SLOW, TIMEOUT, TORN,
                              TransferTimeoutError)
from repro.dist.shard import shard_crc32

__all__ = ["LINK_BYTES_PER_MS", "HANDSHAKE_MS", "MAX_RETRIES",
           "BACKOFF_BASE_MS", "BACKOFF_CAP_MS", "CH_IMAGE", "CH_DELTA",
           "CH_REPLICA", "CH_ROWS", "CH_OPERANDS", "CH_READBACK",
           "CH_CONTROL", "CHANNELS", "TransferResult", "Transport",
           "SimTransport", "MeshTransport", "make_transport",
           "default_transport", "predicted_wire"]

LINK_BYTES_PER_MS = 125_000.0    # 1 Gbps simulated inter-machine link
HANDSHAKE_MS = 5.0               # per-transfer setup + CRC check
MAX_RETRIES = 16
BACKOFF_BASE_MS = 2.0            # retry k backs off BASE * 2**(k-1) ...
BACKOFF_CAP_MS = 64.0            # ... capped here (virtual ms)

# wire channels: every byte the cluster moves between machines is
# accounted under exactly one of these (the census schema)
CH_IMAGE = "image"          # full shard images (migration, replica sync)
CH_DELTA = "delta"          # streaming-update delta images
CH_REPLICA = "replica"      # standby-read control traffic
CH_ROWS = "rows"            # candidate rows, shard holder -> master
CH_OPERANDS = "operands"    # megabatch query/mask operands, master -> ranks
CH_READBACK = "readback"    # candidate-id readbacks, ranks -> master
CH_CONTROL = "control"      # protocol headers / rank control messages
CHANNELS = (CH_IMAGE, CH_DELTA, CH_REPLICA, CH_ROWS, CH_OPERANDS,
            CH_READBACK, CH_CONTROL)


@dataclasses.dataclass
class TransferResult:
    """One CRC-verified blob delivery over the link."""

    received: bytes
    ok: bool                     # delivered bytes match the source CRC
    retransmissions: int
    virtual_ms: float


def _link_faults(chaos, blob: bytes) -> tuple:
    """Apply the chaos faults due at ``migration.transfer`` to one
    in-flight attempt.

    Returns ``(received, slow_factor)`` where ``received`` is None for a
    lost (TIMEOUT) attempt, possibly torn/corrupted bytes otherwise.
    Draws ONLY from ``chaos.rng`` — never the engine rng — so chaos and
    fault-free runs consume identical engine rng streams (RPR007).
    """
    if chaos is None:
        return blob, 1.0
    received: bytes | None = blob
    factor = 1.0
    for f in chaos.fire(HOOK_TRANSFER):
        if f.kind == TIMEOUT:
            received = None
        elif f.kind == SLOW:
            factor *= f.factor
        elif f.kind == TORN and received is not None and len(received) > 1:
            cut = 1 + int(chaos.rng.integers(len(received) - 1))
            received = received[:cut]
        elif f.kind == CORRUPT and received is not None and received:
            bad = bytearray(received)
            bad[int(chaos.rng.integers(len(bad)))] ^= 0xFF
            received = bytes(bad)
    return received, factor


class Transport:
    """The seam.  Subclasses implement :meth:`_deliver` (move one
    attempt's bytes to the destination, return the CRC the destination
    computed); everything else — retry/backoff/virtual-ms discipline,
    fault injection, the logical byte ledger — is shared, which is what
    keeps the backends bit-comparable."""

    backend = "sim"

    def __init__(self) -> None:
        self.chaos = None            # the attached FaultPlan (or None)
        self.wire: dict[str, int] = {ch: 0 for ch in CHANNELS}
        self.ops: dict[str, int] = {ch: 0 for ch in CHANNELS}
        self.by_dst: dict[tuple, int] = {}   # (channel, dst machine) -> B
        self.transfers = 0
        self._e = None

    # ---------------------------------------------------------------- #
    # engine attachment
    # ---------------------------------------------------------------- #
    def bind(self, engine) -> "Transport":
        self._e = engine
        return self

    def on_topology(self, engine) -> None:
        """Called once routing + probe planes exist (and again after
        topology-changing rebuilds).  Backends that home state per
        machine (plane pinning) hook in here; the sim backend is
        placement-agnostic."""

    # ---------------------------------------------------------------- #
    # chaos + accounting
    # ---------------------------------------------------------------- #
    def fire(self, hook: str) -> list:
        """Consult the attached fault plan at a named hook point."""
        plan = self.chaos
        if plan is None:
            return []
        return plan.fire(hook)

    def account(self, channel: str, nbytes: int, dst=None) -> None:
        """Record `nbytes` of logical cross-machine traffic.  Identical
        on every backend — this ledger is the bit-identity surface."""
        n = int(nbytes)
        self.wire[channel] += n
        self.ops[channel] += 1
        key = (channel, dst)
        self.by_dst[key] = self.by_dst.get(key, 0) + n

    # ---------------------------------------------------------------- #
    # verified point-to-point transfer (the old crc_transfer, per-seam)
    # ---------------------------------------------------------------- #
    def transfer(self, blob: bytes, *, rng: np.random.Generator,
                 src=None, dst=None, channel: str = CH_IMAGE,
                 corrupt_prob: float = 0.0,
                 max_retries: int = MAX_RETRIES,
                 chaos=None, timeout_ms: float | None = None
                 ) -> TransferResult:
        """Ship one byte image over the link with CRC32 + retry +
        exponential backoff.

        The shared transfer half of Algorithm 1, reused by hot shard
        migration, the streaming-update delta protocol and replica sync.
        ``rng`` is the *engine* rng (required — every call site threads
        its own generator so corruption simulation is reproducible per
        run) and is consulted only when ``corrupt_prob > 0``: attempts
        1..max_retries may then be corrupted in flight, while attempt
        max_retries+1 is clean by construction, so absent chaos delivery
        of the source-identical image is guaranteed.

        A chaos FaultPlan may corrupt/tear/lose/slow any attempt (final
        one included) from its own rng; if every attempt fails, or
        accumulated virtual time passes ``timeout_ms``, the bounded
        budget is exhausted and :class:`TransferTimeoutError` is raised
        — reachable only under chaos, and handled by the caller as a
        clean transactional abort.
        """
        crc = shard_crc32(blob)
        retrans = 0
        virtual_ms = 0.0
        for attempt in range(1, max_retries + 2):
            received, slow = _link_faults(chaos, blob)
            if (received is not None and corrupt_prob > 0.0
                    and attempt <= max_retries
                    and rng.random() < corrupt_prob):
                bad = bytearray(received)
                bad[int(rng.integers(len(bad)))] ^= 0xFF
                received = bytes(bad)
            virtual_ms += slow * (len(blob) / LINK_BYTES_PER_MS) \
                + HANDSHAKE_MS
            if received is not None \
                    and self._deliver(received, src, dst, channel) == crc:
                self.transfers += 1
                self.account(channel, len(blob), dst=dst)
                return TransferResult(received=received, ok=True,
                                      retransmissions=retrans,
                                      virtual_ms=virtual_ms)
            retrans += 1
            virtual_ms += min(BACKOFF_BASE_MS * 2.0 ** (attempt - 1),
                              BACKOFF_CAP_MS)
            if timeout_ms is not None and virtual_ms > timeout_ms:
                raise TransferTimeoutError(
                    f"transfer exceeded {timeout_ms:.1f} virtual ms "
                    f"after {attempt} attempts",
                    virtual_ms=virtual_ms, attempts=attempt)
        raise TransferTimeoutError(
            f"transfer failed all {max_retries + 1} attempts",
            virtual_ms=virtual_ms, attempts=max_retries + 1)

    def _deliver(self, received: bytes, src, dst, channel: str) -> int:
        """Move one attempt's bytes to `dst`; return the CRC32 the
        destination computed over what it got.  The sim backend's link
        is in-process memory: delivery is the identity."""
        return shard_crc32(received)

    # ---------------------------------------------------------------- #
    # standby reads + bulk collective-shaped movement
    # ---------------------------------------------------------------- #
    def fetch_replica(self, sid: int, machine: int):
        """The CRC-verified standby copy of `sid` held by `machine` —
        the ONLY legal accessor for another machine's replica bytes
        (RPR009).  The copy store itself is driver-resident in both
        backends; on an accelerator mesh this is where the remote read
        would issue."""
        return self._e.replicas.copies[sid][machine]

    def broadcast(self, channel: str, nbytes: int) -> None:
        """Driver -> every shard-holder rank (megabatch operands)."""
        self.account(channel, nbytes, dst=None)

    def gather(self, channel: str, nbytes: int) -> None:
        """Shard-holder ranks -> driver (candidate readbacks)."""
        self.account(channel, nbytes, dst=None)

    # ---------------------------------------------------------------- #
    # introspection / lifecycle
    # ---------------------------------------------------------------- #
    def measured(self) -> dict[str, int]:
        """Physical bytes-on-wire per channel.  The sim link moves no
        real bytes; the mesh backend meters its KV/device traffic."""
        return {ch: 0 for ch in CHANNELS}

    def stats(self) -> dict:
        return {"backend": self.backend,
                "transfers": int(self.transfers),
                "wire_bytes": dict(self.wire),
                "wire_ops": dict(self.ops),
                "measured_bytes": self.measured()}

    def close(self) -> None:
        """Release backend resources (worker ranks, KV keys)."""


class SimTransport(Transport):
    """The default in-process backend — the deterministic oracle every
    other backend is measured against."""

    backend = "sim"


class MeshTransport(Transport):
    """Real process ranks over ``jax.distributed``.

    ``world == 1`` ("loopback") needs no coordinator: every delivery
    round-trips the bytes through the local JAX device, so the mesh
    path runs in-process (tests, benchmarks).  ``world >= 2`` bootstraps
    ``jax.distributed.initialize(coordinator, world, rank)`` — rank 0
    drives the engine, ranks 1..world-1 run :meth:`serve` and act as the
    remote ends of every link: each transfer attempt's bytes ship to
    the destination rank (machine ``m`` lives on rank ``m % world``),
    which CRC-checks and acks them.  On the CPU CI fallback the byte
    channel is the coordination-service KV store (XLA's CPU backend has
    no multiprocess collectives); on accelerator meshes the same seam
    lowers to device collectives over the ``repro.dist.sharding`` rules.
    """

    backend = "mesh"
    _CHUNK = 1 << 16             # KV values stay comfortably small

    def __init__(self, world: int | None = None, rank: int | None = None,
                 coordinator: str | None = None,
                 timeout_ms: int = 120_000) -> None:
        super().__init__()
        env = os.environ
        self.world = int(world if world is not None
                         else env.get("REPRO_MESH_WORLD", "1"))
        self.rank = int(rank if rank is not None
                        else env.get("REPRO_MESH_RANK", "0"))
        self.coordinator = (coordinator
                            or env.get("REPRO_MESH_COORD", ""))
        self.timeout_ms = int(timeout_ms)
        self.phys: dict[str, int] = {ch: 0 for ch in CHANNELS}
        self._seq: dict[int, int] = {}
        self._pending_rows: dict[int, int] = {}
        self._client = None
        self._connected = False

    # ---------------------------------------------------------------- #
    # rank topology
    # ---------------------------------------------------------------- #
    def connect(self) -> None:
        if self._connected:
            return
        if self.world > 1:
            import jax
            from jax._src import distributed
            if distributed.global_state.client is None:
                jax.distributed.initialize(
                    coordinator_address=self.coordinator,
                    num_processes=self.world, process_id=self.rank)
            self._client = distributed.global_state.client
            # every rank must join the backend topology exchange, or
            # peers block 2 minutes waiting for this rank's devices
            jax.local_devices()
        self._connected = True

    def rank_of(self, machine) -> int:
        """One shard-group per rank: machine k lives on rank k % world."""
        if machine is None:
            return self.rank
        return int(machine) % max(self.world, 1)

    def plane_device(self, machine):
        """The local device machine `m`'s probe planes are pinned to."""
        import jax
        local = jax.local_devices()
        if machine is None:
            return local[0]
        return local[int(machine) % len(local)]

    def on_topology(self, engine) -> None:
        """Pin each machine's probe planes to its local device.

        With one local device per process (the CPU-rank fallback) the
        pin is the default device and resident planes are untouched —
        plane build/invalidate statistics stay bit-identical to sim.
        With several local devices (``DRYRUN_DEVICES`` debug runs) the
        planes re-home: existing slabs are invalidated once so the lazy
        repack lands them on their machine's device, and the assemble
        step meters the gather back to the launch device
        (``planes.stats["gather_bytes"]``).
        """
        planes = getattr(engine, "planes", None)
        if planes is None:
            return
        import jax
        if len(jax.local_devices()) <= 1:
            return
        routing = engine.routing

        def device_of(sid: int):
            return self.plane_device(routing.get(sid))

        planes.device_of = device_of
        for sid in list(engine.shards):
            planes.invalidate(sid)

    # ---------------------------------------------------------------- #
    # delivery
    # ---------------------------------------------------------------- #
    def _deliver(self, received: bytes, src, dst, channel: str) -> int:
        self.connect()
        r = self.rank_of(dst)
        if self.world > 1:
            if r != self.rank:
                return self._kv_ship(r, channel, received)
            # destination machine lives on this rank: no wire crossed,
            # so the physical meter stays silent
            return shard_crc32(received)
        # world == 1 loopback: round-trip through the local device so
        # the bytes really move off the Python heap and back
        arr = np.frombuffer(received, dtype=np.uint8)
        import jax
        back = bytes(np.asarray(jax.device_put(arr)))
        self.phys[channel] += len(received)
        return shard_crc32(back)

    # KV byte protocol (driver side): header + chunked payload under
    # t/<rank>/<seq>/..., CRC ack from the worker, then cleanup.  The
    # payload rides the *string* KV API base64-encoded — the `_bytes`
    # variant is unreliable in the pinned jaxlib (segfaults on get).
    def _kv_ship(self, r: int, channel: str, blob: bytes,
                 op: str = "xfer", pull_n: int = 0) -> int:
        self.connect()
        c = self._client
        seq = self._seq.get(r, 0)
        self._seq[r] = seq + 1
        base = f"t/{r}/{seq}"
        b64 = base64.b64encode(blob).decode("ascii")
        chunks = [b64[i:i + self._CHUNK]
                  for i in range(0, len(b64), self._CHUNK)] or [""]
        hdr = json.dumps({"op": op, "ch": channel, "n": len(blob),
                          "k": len(chunks), "pull": int(pull_n)})
        for i, chunk in enumerate(chunks):
            c.key_value_set(f"{base}/c{i}", chunk)
        c.key_value_set(f"{base}/h", hdr)
        ack = json.loads(c.blocking_key_value_get(
            f"{base}/a", self.timeout_ms))
        for i in range(len(chunks)):
            c.key_value_delete(f"{base}/c{i}")
        c.key_value_delete(f"{base}/h")
        c.key_value_delete(f"{base}/a")
        self.phys[channel] += len(blob) + len(hdr)
        if pull_n:
            self.phys[channel] += int(pull_n)
        return int(ack["crc"])

    def serve(self) -> int:
        """Worker-rank loop: answer the driver's shipments until a quit
        op arrives.  Returns the number of ops served."""
        self.connect()
        c = self._client
        seq = 0
        while True:
            base = f"t/{self.rank}/{seq}"
            hdr = json.loads(c.blocking_key_value_get(
                f"{base}/h", self.timeout_ms))
            blob = base64.b64decode("".join(
                c.blocking_key_value_get(f"{base}/c{i}", self.timeout_ms)
                for i in range(hdr["k"])))
            c.key_value_set(
                f"{base}/a",
                json.dumps({"crc": shard_crc32(blob),
                            "pull": hdr.get("pull", 0)}))
            seq += 1
            if hdr["op"] == "quit":
                return seq

    # ---------------------------------------------------------------- #
    # collective-shaped movement
    # ---------------------------------------------------------------- #
    def account(self, channel: str, nbytes: int, dst=None) -> None:
        super().account(channel, nbytes, dst=dst)
        # candidate rows originate at the holder's rank; batch them into
        # one pull per rank (flushed at measurement/close) instead of a
        # KV round-trip per probed (path, shard)
        if channel == CH_ROWS and self.world > 1 and nbytes:
            r = self.rank_of(dst)
            if r != self.rank:
                self._pending_rows[r] = (self._pending_rows.get(r, 0)
                                         + int(nbytes))

    def broadcast(self, channel: str, nbytes: int) -> None:
        super().broadcast(channel, nbytes)
        if self.world > 1 and nbytes:
            for r in range(self.world):
                if r != self.rank:
                    self._kv_ship(r, channel, bytes(int(nbytes)), op="oper")

    def gather(self, channel: str, nbytes: int) -> None:
        super().gather(channel, nbytes)
        if self.world > 1 and nbytes:
            workers = [r for r in range(self.world) if r != self.rank]
            share = int(nbytes) // len(workers)
            rem = int(nbytes) - share * len(workers)
            for i, r in enumerate(workers):
                n = share + (rem if i == 0 else 0)
                if n:
                    self._kv_ship(r, channel, b"", op="pull", pull_n=n)

    def flush(self) -> None:
        """Materialize batched row pulls on the wire."""
        if self.world > 1 and self._pending_rows:
            for r, n in sorted(self._pending_rows.items()):
                self._kv_ship(r, CH_ROWS, b"", op="pull", pull_n=n)
            self._pending_rows.clear()

    # ---------------------------------------------------------------- #
    # introspection / lifecycle
    # ---------------------------------------------------------------- #
    def measured(self) -> dict[str, int]:
        self.flush()
        return dict(self.phys)

    def close(self) -> None:
        self.flush()
        if self.world > 1 and self._connected:
            for r in range(self.world):
                if r != self.rank:
                    self._kv_ship(r, CH_CONTROL, b"", op="quit")


def make_transport(backend: str = "sim", **kw) -> Transport:
    """Backend factory used by ``DistributedGNNPE.build(backend=...)``."""
    if backend == "sim":
        return SimTransport()
    if backend == "mesh":
        return MeshTransport(**kw)
    raise ValueError(f"unknown transport backend {backend!r}")


_DEFAULT: SimTransport | None = None


def default_transport() -> SimTransport:
    """The process-wide SimTransport behind the legacy free functions
    (``migration.crc_transfer``, standalone ``ReplicaSet`` use) — one
    shared ledger for callers that predate the seam."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = SimTransport()
    return _DEFAULT


def predicted_wire(transport: Transport, world: int) -> dict[str, int]:
    """The census: physical bytes ``MeshTransport(world=world)`` would
    put on the wire for the logical traffic recorded in `transport`
    (typically a SimTransport twin's ledger).

    Model (mirrors the mesh delivery rules exactly):

      * point-to-point transfers and rows reach the wire iff their
        destination machine maps to a non-driver rank (``m % world``);
        with ``world == 1`` (loopback) every transfer round-trips the
        local device instead, so all transfer bytes count and rows
        count zero;
      * operands broadcast to each of the ``world - 1`` worker ranks;
      * readbacks gather their full logical volume from the workers.

    Protocol headers (CH_CONTROL and the per-op JSON header) are NOT
    modeled — they are the slack inside the <=10% census gate.
    """
    pred = {ch: 0 for ch in CHANNELS}
    p2p = (CH_IMAGE, CH_DELTA, CH_REPLICA, CH_ROWS)
    for (ch, dst), n in transport.by_dst.items():
        if ch not in p2p:
            continue
        if world > 1:
            if dst is not None and int(dst) % world != 0:
                pred[ch] += n
        elif ch != CH_ROWS:
            pred[ch] += n
    if world > 1:
        pred[CH_OPERANDS] = transport.wire[CH_OPERANDS] * (world - 1)
        pred[CH_READBACK] = transport.wire[CH_READBACK]
    return pred
