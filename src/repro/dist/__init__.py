"""Distributed GNN-PE runtime (paper §4-§6).

Modules:
  partition   — METIS-role graph partitioner (min edge-cut + size balance).
  shard       — ultra-fine shards with halo context + CRC32'd byte images.
  loadbalance — multi-metric load fusion, sigma trigger, Algorithm-1 planner.
  transport   — THE inter-machine seam: every cross-machine byte flows
                through Transport.transfer/account/broadcast/gather
                (RPR009).  SimTransport = deterministic oracle;
                MeshTransport = real jax.distributed process ranks.
  migration   — CRC-verified hot shard migration with exponential backoff
                and two-phase prepare/commit (non-interruptible queries).
  chaos       — deterministic seeded fault schedules (FaultPlan), named
                hook points, typed failures, chaos-oracle script runner.
  replica     — k-replica standby placement with anti-affinity, CRC'd
                full/delta sync, failover promotion, quorum audit.
  cluster     — the DistributedGNNPE engine tying everything together.
  meshrun     — multi-process rank launcher + cross-backend scenarios
                (identity / megabatch / chaos / census).
  sharding    — logical-axis -> mesh-axis rule registry for the JAX models.
"""
