"""Distributed GNN-PE runtime (paper §4-§6).

Modules:
  partition   — METIS-role graph partitioner (min edge-cut + size balance).
  shard       — ultra-fine shards with halo context + CRC32'd byte images.
  loadbalance — multi-metric load fusion, sigma trigger, Algorithm-1 planner.
  migration   — CRC-verified hot shard migration (non-interruptible queries).
  cluster     — the DistributedGNNPE engine tying everything together.
  sharding    — logical-axis -> mesh-axis rule registry for the JAX models.
"""
