"""Degraded-mode serving: replica-read routing, budgets, health states.

PR 8 made the cluster *crash-consistent* — no fault schedule may produce
a wrong answer — but not *available*: a machine loss stalled every query
touching its shards until promotion completed, and a query racing a
fault surfaced as ``ClusterUnavailableError`` even while a CRC-identical
standby copy sat on a live machine.  This module is the serving half of
the strengthened contract:

> **Never wrong, AND answered whenever >= 1 live CRC-verified copy of
> every needed shard exists.**

Three pieces (docs/robustness.md has the full narrative):

  * :class:`ShardRouter` — resolves every shard read to the primary or,
    when the primary is dead, the first live standby from
    ``ReplicaSet.holders`` (bit-identical by the CRC-sync construction).
    Reads are served from standbys *before and without* promotion;
    :meth:`ShardRouter.read` fires the ``router.read`` link hook so
    chaos schedules can stall/lose individual read attempts, and charges
    any fault-induced stall to the query's :class:`QueryOutcome` (the
    fault-free path costs exactly 0 extra virtual ms, which is what
    keeps chaos runs latency-comparable to their fault-free twins).
  * :class:`QueryBudget` — per-query deadline / retry / hedge knobs.  A
    lost or slow read attempt retries with ``crc_transfer``'s
    exponential-backoff discipline; once the cumulative stall passes
    ``hedge_after_ms`` the router issues a hedged read to the next live
    holder instead of waiting out the primary.
  * the cluster health state machine — HEALTHY -> DEGRADED -> BROWNOUT,
    driven by quorum coverage (any shard standby-served or lost) and
    crash rate.  BROWNOUT applies admission control: queries whose
    ``priority`` sits below :data:`BROWNOUT_PRIORITY_FLOOR` are shed
    with a typed :class:`AdmissionRejected` — never a silent drop and
    never a wrong answer.  Unlike PR 8's one-way latch, the state
    un-latches: ``DistributedGNNPE.recover()`` promotes deferred
    victims, restores the replication factor and clears the crash
    window, returning the cluster to HEALTHY.

RPR008 (reprolint) keeps this module the single place shard reads are
resolved: serving code in ``repro.dist`` may not subscript
``.shards``/``.routing`` directly.  The standby bytes themselves come
through the transport seam (``engine.transport.fetch_replica`` — RPR009
bans direct ``replicas.copies`` reads outside it), so a mesh backend can
home them remotely without this module changing.
"""

from __future__ import annotations

import dataclasses

from repro.dist.chaos import (CORRUPT, SLOW, TIMEOUT, TORN, HOOK_READ,
                              ClusterUnavailableError, TransferTimeoutError)
from repro.dist.transport import (BACKOFF_BASE_MS, BACKOFF_CAP_MS,
                                  HANDSHAKE_MS)

__all__ = ["HEALTHY", "DEGRADED", "BROWNOUT", "READ_RTT_MS",
           "BROWNOUT_FAULT_WINDOW", "BROWNOUT_FAULT_RATE",
           "BROWNOUT_PRIORITY_FLOOR", "QueryBudget", "QueryOutcome",
           "AdmissionRejected", "QueryDeadlineExceeded", "Route",
           "ClusterHealth", "ShardRouter"]

# cluster health states (strictly ordered by severity)
HEALTHY = "healthy"
DEGRADED = "degraded"     # >= 1 shard standby-served or under-replicated
BROWNOUT = "brownout"     # lost quorum somewhere, or crash-rate spike

# one routed read round-trip, virtual ms (same constant family as the
# migration link: a read RPC is a handshake-sized control exchange; the
# candidate-row payload is already accounted per-row by `_account_rows`)
READ_RTT_MS = HANDSHAKE_MS

# fault-rate half of the BROWNOUT trigger: >= BROWNOUT_FAULT_RATE crashes
# within BROWNOUT_FAULT_WINDOW qclock ticks trips admission control even
# while every shard still has a live copy (the cluster is losing machines
# faster than re-replication can restore margins)
BROWNOUT_FAULT_WINDOW = 16.0
BROWNOUT_FAULT_RATE = 2

# queries at or above this priority are NEVER shed: brownout admission
# control exists to protect them, not to break the availability contract
BROWNOUT_PRIORITY_FLOOR = 1


class AdmissionRejected(RuntimeError):
    """BROWNOUT admission control shed this query (typed, never silent).

    Only queries whose ``QueryBudget.priority`` is below
    :data:`BROWNOUT_PRIORITY_FLOOR` are ever shed, and only while the
    health state machine reports BROWNOUT — a rejected query was never
    executed, so retrying after recovery is always safe."""

    def __init__(self, message: str, priority: int = 0,
                 floor: int = BROWNOUT_PRIORITY_FLOOR,
                 state: str = BROWNOUT) -> None:
        super().__init__(message)
        self.priority = priority
        self.floor = floor
        self.state = state


class QueryDeadlineExceeded(RuntimeError):
    """The query's ``timeout_ms`` budget was exhausted by fault-induced
    read stalls before an answer could be assembled.  Typed and clean:
    no partial result escapes, the engine state is untouched, and the
    caller may retry with a larger budget."""

    def __init__(self, message: str, budget_ms: float = 0.0,
                 spent_ms: float = 0.0) -> None:
        super().__init__(message)
        self.budget_ms = budget_ms
        self.spent_ms = spent_ms


@dataclasses.dataclass(frozen=True)
class QueryBudget:
    """Per-query serving knobs threaded through probe and join stages.

    ``timeout_ms`` — virtual-ms deadline for fault-induced stall; None
    disables it.  ``max_attempts`` — read attempts per shard before the
    router gives up with :class:`TransferTimeoutError`.
    ``hedge_after_ms`` — cumulative stall after which a hedged read goes
    to the next live holder.  ``priority`` — brownout admission class
    (default 1 = never shed)."""

    timeout_ms: float | None = None
    max_attempts: int = 4
    hedge_after_ms: float = 16.0
    priority: int = 1


@dataclasses.dataclass
class QueryOutcome:
    """Typed serving outcome attached to every ``QueryTelemetry``."""

    served_degraded: bool = False   # >= 1 shard read came from a standby
    retries: int = 0                # read attempts lost/retransmitted
    hedges: int = 0                 # reads re-issued to another holder
    deadline_exceeded: bool = False
    stall_ms: float = 0.0           # fault-induced read stall (virtual)
    health: str = HEALTHY           # cluster state when the query ran


@dataclasses.dataclass(frozen=True)
class Route:
    """One resolved shard read: which live machine serves which copy."""

    sid: int
    machine: int
    shard: object                   # the CRC-verified Shard copy served
    degraded: bool = False          # True = standby (primary is dead)


class ClusterHealth:
    """Crash-rate window for the fault-rate half of BROWNOUT.

    Timestamps are engine qclock ticks (virtual, deterministic — never
    wall time), recorded by ``handle_machine_failure`` and cleared by
    ``recover()`` once re-replication restored coverage."""

    def __init__(self) -> None:
        self.crash_ticks: list[float] = []

    def record_crash(self, tick: float) -> None:
        self.crash_ticks.append(float(tick))

    def recent_crashes(self, tick: float,
                       window: float = BROWNOUT_FAULT_WINDOW) -> int:
        return sum(1 for t in self.crash_ticks if tick - t <= window)

    def clear_window(self) -> None:
        self.crash_ticks.clear()


class ShardRouter:
    """Resolves shard reads to primary-or-standby and meters them.

    The router owns the ONLY legal read path for serving code (RPR008):
    ``metadata`` for the master-side <1KB index metadata (root MBRs —
    content-identical on every copy, so readable even while the primary
    is dead), ``resolve``/``read`` for the actual candidate probe.
    """

    def __init__(self, engine) -> None:
        self._e = engine
        self.health = ClusterHealth()
        self.standby_reads = 0      # shard reads served from a standby
        self.shed_queries = 0       # brownout admission rejections

    # ------------------------------------------------------------------ #
    # resolution
    # ------------------------------------------------------------------ #
    def metadata(self, sid: int):
        """The master's metadata copy of the shard index (root MBR,
        tree shapes).  Every copy is CRC-identical, so this is readable
        regardless of machine liveness — it determines which shards a
        query *needs* before any read is routed."""
        return self._e.shards[sid].index

    def primary(self, sid: int) -> int:
        """The machine the routing table homes ``sid`` on (may be dead —
        use :meth:`resolve` to get a live serving machine)."""
        return self._e.routing[sid]

    def holders(self, sid: int) -> list[int]:
        """Live standby machines holding a CRC-verified copy of ``sid``,
        least-loaded first.

        Ordering reuses the balancer's fused per-machine load metric
        (``loadbalance.machine_load`` via ``engine._last_loads``, the
        same signal migration planning runs on), with machine id as the
        deterministic tiebreak.  Before any workload epoch every load is
        0.0, so the order degrades to the legacy lowest-id walk — and
        standby reads of a hot shard spread off the hottest holder as
        soon as real load telemetry exists."""
        e = self._e
        if not e.replicas.k:
            return []
        live = e.replicas.holders(sid, e.dead_machines)
        loads = e._last_loads
        return sorted(live, key=lambda m: (float(loads[m]) if m < len(loads)
                                           else 0.0, m))

    def resolve(self, sid: int) -> Route:
        """Primary if live, else the first live standby holder.

        Raises the structured :class:`ClusterUnavailableError` only when
        *every* copy of the shard is on a dead machine — the one case
        the strengthened contract permits a non-answer."""
        e = self._e
        mk = e.routing[sid]
        if mk not in e.dead_machines:
            return Route(sid, mk, e.shards[sid], degraded=False)
        live = self.holders(sid)
        if not live:
            if e.failover_mode != "route":
                # legacy promote-mode semantics (PR 8): the simulator's
                # master still reaches the byte image of a machine that
                # was marked dead without failover — serve it, exactly
                # as the pre-router engine did.  Only route mode holds
                # the strict "live copy or typed error" line.
                return Route(sid, mk, e.shards[sid], degraded=False)
            raise ClusterUnavailableError(
                f"shard {sid}: every copy is on a dead machine",
                reason="no-live-copy", sids=(sid,),
                machines=tuple(sorted(e.dead_machines)))
        m = live[0]                  # least-loaded live holder
        return Route(sid, m, e.transport.fetch_replica(sid, m),
                     degraded=True)

    def degraded_sids(self) -> set[int]:
        """Shards whose primary is dead (standby-served or lost)."""
        e = self._e
        return {sid for sid, mk in e.routing.items()
                if mk in e.dead_machines}

    def lost_sids(self) -> list[int]:
        """Shards with NO live copy at all — the lost quorum set."""
        return sorted(sid for sid in self.degraded_sids()
                      if not self.holders(sid))

    # ------------------------------------------------------------------ #
    # metered reads: retry / backoff / hedging under the fault plan
    # ------------------------------------------------------------------ #
    def read(self, sid: int, budget: QueryBudget | None = None,
             tel=None) -> Route:
        """One routed shard read under the deadline/hedge budget.

        Fires the ``router.read`` link hook per attempt.  With no plan
        attached (or no fault due at this visit) the read is free —
        0 extra virtual ms — so fault-free telemetry is bit-identical
        whether or not a chaos plan is watching.  Fault handling:

          * CORRUPT/TORN — caught by the CRC discipline; costs one
            retransmission round-trip plus ``crc_transfer``-style
            backoff, then retries the same holder.
          * TIMEOUT — the attempt is lost; after ``hedge_after_ms`` of
            cumulative stall the retry goes to the *next* live holder
            (a hedged read) instead of the stalled one.
          * SLOW — the attempt is delivered ``factor`` x slower; if a
            hedge would beat it, the hedge wins and the stall is capped
            at ``hedge_after_ms + READ_RTT_MS``.

        Exhausting ``max_attempts`` raises ``TransferTimeoutError``;
        breaching ``timeout_ms`` raises :class:`QueryDeadlineExceeded`.
        Stall and retry/hedge counts land in ``tel.outcome``.
        """
        rt = self.resolve(sid)
        out = getattr(tel, "outcome", None)
        if rt.degraded:
            self.standby_reads += 1
            if out is not None:
                out.served_degraded = True
        chaos = self._e.chaos
        if chaos is None:
            return rt
        b = budget if budget is not None else QueryBudget()
        alternates = [m for m in self.holders(sid) if m != rt.machine]
        stall = 0.0
        for attempt in range(1, b.max_attempts + 1):
            due = chaos.fire(HOOK_READ)
            kinds = {f.kind for f in due}
            if not kinds & {CORRUPT, TORN, TIMEOUT, SLOW}:
                break                        # clean delivery, 0 ms
            backoff = min(BACKOFF_BASE_MS * 2.0 ** (attempt - 1),
                          BACKOFF_CAP_MS)
            if kinds & {CORRUPT, TORN}:
                # CRC catches the damage; retransmit on the same route
                stall += READ_RTT_MS + backoff
                if out is not None:
                    out.retries += 1
            elif TIMEOUT in kinds:
                stall += READ_RTT_MS + backoff
                if out is not None:
                    out.retries += 1
                if stall >= b.hedge_after_ms and alternates:
                    m = alternates.pop(0)
                    rt = Route(sid, m,
                               self._e.transport.fetch_replica(sid, m),
                               degraded=True)
                    self.standby_reads += 1
                    if out is not None:
                        out.hedges += 1
                        out.served_degraded = True
            else:                            # SLOW: delivered, just late
                factor = max(f.factor for f in due if f.kind == SLOW)
                cost = factor * READ_RTT_MS
                if cost > b.hedge_after_ms + READ_RTT_MS and alternates:
                    # the hedged copy answers before the slow one does
                    m = alternates.pop(0)
                    rt = Route(sid, m,
                               self._e.transport.fetch_replica(sid, m),
                               degraded=True)
                    self.standby_reads += 1
                    stall += b.hedge_after_ms + READ_RTT_MS
                    if out is not None:
                        out.hedges += 1
                        out.served_degraded = True
                else:
                    stall += cost
                break                        # SLOW still delivers
            if b.timeout_ms is not None and stall > b.timeout_ms:
                if out is not None:
                    out.deadline_exceeded = True
                    out.stall_ms += stall
                raise QueryDeadlineExceeded(
                    f"shard {sid}: read stall {stall:.1f}ms exceeded "
                    f"budget {b.timeout_ms:.1f}ms",
                    budget_ms=b.timeout_ms, spent_ms=stall)
        else:
            if out is not None:
                out.stall_ms += stall
            raise TransferTimeoutError(
                f"shard {sid}: routed read exhausted "
                f"{b.max_attempts} attempts", virtual_ms=stall,
                attempts=b.max_attempts)
        if out is not None:
            out.stall_ms += stall
        return rt

    # ------------------------------------------------------------------ #
    # health state machine
    # ------------------------------------------------------------------ #
    def state(self) -> str:
        """HEALTHY -> DEGRADED -> BROWNOUT, recomputed from coverage.

        BROWNOUT: some shard lost every copy, or the crash-rate window
        tripped.  DEGRADED: every shard still has a live copy but at
        least one is standby-served (its primary is dead, promotion
        deferred).  Un-latches naturally: once ``recover()`` promotes
        victims (routing references no corpse any more) and clears the
        crash window, this recomputes to HEALTHY — no one-way latch,
        even while the dead machines stay dead."""
        e = self._e
        degraded = self.degraded_sids()
        if any(not self.holders(sid) for sid in degraded):
            return BROWNOUT
        if self.health.recent_crashes(e._qclock) >= BROWNOUT_FAULT_RATE:
            return BROWNOUT
        return DEGRADED if degraded else HEALTHY

    def admit(self, budget: QueryBudget | None) -> str:
        """Brownout admission control: typed shed, never silent.

        Returns the health state (stamped into the query outcome).
        Raises :class:`AdmissionRejected` only for queries *below* the
        priority floor while the state machine reports BROWNOUT."""
        state = self.state()
        pri = budget.priority if budget is not None else 1
        if state == BROWNOUT and pri < BROWNOUT_PRIORITY_FLOOR:
            self.shed_queries += 1
            raise AdmissionRejected(
                f"brownout admission control shed priority-{pri} query "
                f"(floor {BROWNOUT_PRIORITY_FLOOR})",
                priority=pri, state=state)
        return state

    def stats(self) -> dict:
        return {"standby_reads": self.standby_reads,
                "shed_queries": self.shed_queries,
                "state": self.state(),
                "degraded_sids": sorted(self.degraded_sids()),
                "lost_sids": self.lost_sids()}
