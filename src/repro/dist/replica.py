"""k-replica shard placement with anti-affinity and CRC-verified sync.

Before this module, a machine crash forced `handle_machine_failure` to
re-deserialize the dead machine's shards from their (conveniently still
reachable) in-simulator byte images — a stand-in with no real-world
analogue.  :class:`ReplicaSet` gives every shard ``k`` standing replicas
on live machines *other than* its primary (anti-affinity), kept current
by piggybacking CRC-verified transfers on the two paths that already
move shard bytes:

  * **full sync** after a shard is (re)built or migrated — the complete
    canonical image ships to any replica target missing a current copy;
  * **delta sync** during ``apply_updates`` — the same canonical delta
    image the primary installs is staged to every replica holder inside
    the update transaction's STAGE phase, and installed at COMMIT, so
    replicas can never diverge from primaries by a torn fault window.

Failover then *promotes* a replica (pure dictionary move, zero transfer
on the critical path) instead of rebuilding.  Because replica images
arrive through the same ``Transport.transfer`` + ``Shard.deserialize`` /
``apply_shard_delta`` pipeline as primaries (RPR003), a promoted shard
is bit-identical to the lost primary — exactness is preserved by
construction, and the chaos oracle verifies it empirically.

Quorum semantics: a shard is *available* while at least one live copy
(primary or replica) exists.  Losing the last copy — or the last live
machine — is genuine quorum loss, surfaced as a typed
:class:`~repro.dist.chaos.ClusterUnavailableError`; under-replication
(fewer than ``k`` live replicas because machines died) only degrades
fault tolerance, never correctness.
"""

from __future__ import annotations

import numpy as np

from repro.dist.chaos import ClusterUnavailableError
from repro.dist.shard import Shard, apply_shard_delta
from repro.dist.transport import (CH_DELTA, CH_IMAGE, Transport,
                                  default_transport)

__all__ = ["ReplicaSet"]


class ReplicaSet:
    """Standby copies: ``copies[sid][machine] -> decoded Shard``.

    Placement is deterministic (ring walk from the primary, skipping the
    primary and dead machines), so the same cluster history yields the
    same replica layout on every run.
    """

    def __init__(self, k: int, n_machines: int) -> None:
        self.k = int(k)
        self.n_machines = int(n_machines)
        self.copies: dict[int, dict[int, Shard]] = {}
        self.bytes_synced = 0
        self.promotions = 0
        self.virtual_ms = 0.0

    # ------------------------------------------------------------------ #
    # placement
    # ------------------------------------------------------------------ #
    def plan_targets(self, sid: int, primary: int, dead: set) -> list[int]:
        """The k live anti-affine machines after `primary` on the ring."""
        targets: list[int] = []
        for step in range(1, self.n_machines):
            m = (primary + step) % self.n_machines
            if m != primary and m not in dead:
                targets.append(m)
            if len(targets) == self.k:
                break
        return targets

    def holders(self, sid: int, dead: set) -> list[int]:
        return sorted(m for m in self.copies.get(sid, {}) if m not in dead)

    # ------------------------------------------------------------------ #
    # sync
    # ------------------------------------------------------------------ #
    def sync_full(self, sid: int, shard: Shard, primary: int, dead: set,
                  rng: np.random.Generator, chaos=None,
                  transport: Transport | None = None) -> int:
        """Ship the full canonical image to every target missing a copy.

        The infallible purge runs FIRST (copies on dead machines, on the
        primary, or off the planned ring are dropped), then each missing
        target receives the image over the CRC link, installed as it
        arrives — so even a TransferTimeoutError mid-sync leaves only
        valid, anti-affine copies behind (degraded redundancy, never
        wrongness).  Returns bytes shipped.
        """
        if self.k == 0:
            return 0
        t = transport if transport is not None else default_transport()
        targets = self.plan_targets(sid, primary, dead)
        have = self.copies.setdefault(sid, {})
        for m in list(have):
            if m == primary or m in dead or m not in targets:
                del have[m]
        blob = None
        shipped = 0
        for m in targets:
            if m in have:
                continue
            if blob is None:
                blob = shard.serialize()
            tr = t.transfer(blob, rng=rng, src=primary, dst=m,
                            channel=CH_IMAGE, chaos=chaos)
            self.virtual_ms += tr.virtual_ms
            have[m] = Shard.deserialize(tr.received)
            shipped += len(blob)
        self.bytes_synced += shipped
        return shipped

    def stage_delta(self, sid: int, delta_blob: bytes, dead: set,
                    rng: np.random.Generator, chaos=None,
                    transport: Transport | None = None) -> list:
        """STAGE phase of replica delta sync: transfer + decode the
        canonical delta for every live holder of `sid`, mutating
        nothing.  Returns staged ``[(sid, machine, new Shard, n bytes)]``
        for :meth:`commit_delta`.  Raises TransferTimeoutError under
        chaos — the caller's transaction then aborts fully-old.
        """
        t = transport if transport is not None else default_transport()
        staged = []
        for m in self.holders(sid, dead):
            tr = t.transfer(delta_blob, rng=rng, dst=m, channel=CH_DELTA,
                            chaos=chaos)
            self.virtual_ms += tr.virtual_ms
            new = apply_shard_delta(self.copies[sid][m], tr.received)
            staged.append((sid, m, new, len(delta_blob)))
        return staged

    def commit_delta(self, staged: list) -> None:
        """COMMIT phase: pure assignment of the staged replica shards."""
        for sid, m, shard, nbytes in staged:
            self.copies[sid][m] = shard
            self.bytes_synced += nbytes

    # ------------------------------------------------------------------ #
    # failover
    # ------------------------------------------------------------------ #
    def promote(self, sid: int, dead: set) -> tuple:
        """Pop a live replica of `sid` for promotion to primary.

        Returns ``(machine, Shard)`` — deterministic pick (lowest live
        holder id).  Raises :class:`ClusterUnavailableError` when no
        live copy exists: that is genuine quorum loss for this shard.
        """
        live = self.holders(sid, dead)
        if not live:
            raise ClusterUnavailableError(
                f"shard {sid}: no live replica to promote",
                reason="no-live-copy", sids=(sid,),
                machines=tuple(sorted(dead)))
        m = live[0]
        shard = self.copies[sid].pop(m)
        self.promotions += 1
        return m, shard

    def drop_machine(self, m: int) -> int:
        """Forget every replica homed on machine `m` (it died)."""
        n = 0
        for sid in list(self.copies):
            if m in self.copies[sid]:
                del self.copies[sid][m]
                n += 1
        return n

    def drop_shard(self, sid: int) -> None:
        self.copies.pop(sid, None)

    # ------------------------------------------------------------------ #
    # audit
    # ------------------------------------------------------------------ #
    def audit(self, routing: dict, dead: set) -> list:
        """Wrongness violations only (under-replication is 'degraded',
        not wrong): replicas homed on dead machines, co-located with
        their primary, or kept for shards that no longer exist."""
        bad = []
        for sid, by_machine in self.copies.items():
            primary = routing.get(sid)
            if primary is None:
                bad.append(f"replica for unknown shard {sid}")
                continue
            for m in by_machine:
                if m in dead:
                    bad.append(f"shard {sid}: replica on dead machine {m}")
                if m == primary:
                    bad.append(f"shard {sid}: replica co-located with "
                               f"primary {m}")
        return bad

    def stats(self) -> dict:
        return {"k": self.k,
                "replicas": sum(len(v) for v in self.copies.values()),
                "bytes_synced": int(self.bytes_synced),
                "promotions": int(self.promotions),
                "virtual_ms": float(self.virtual_ms)}
