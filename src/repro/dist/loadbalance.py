"""Correlation-aware dynamic load balancing (paper §4, Algorithm 1).

Machine load is a weighted fusion of three normalized metrics:

  L(M_k) = 0.4 * CPU(M_k) + 0.3 * Comm(M_k)/Comm_max + 0.3 * Mem(M_k)

The cluster triggers rebalancing when the standard deviation sigma of
machine loads exceeds a threshold; right after a migration the threshold
is temporarily raised by `alpha_decay` (0.7 at t=0, linearly decaying to
0 after 60 s) so the balancer cannot thrash.

`plan_migrations` is the planning half of Algorithm 1: pick shards on
overloaded machines, preferring shards weakly correlated with the rest
of their machine's working set (corr_fn) and with high label-affinity to
the target (wlabel_fn), and accept only moves whose simulated effect
strictly reduces sigma.  The execution half (CRC-verified transfer) lives
in repro.dist.migration.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = ["MachineTelemetry", "MigrationPlan", "machine_load",
           "cluster_sigma", "alpha_decay", "plan_migrations",
           "W_CPU", "W_COMM", "W_MEM", "SIGMA_THRESHOLD"]

W_CPU, W_COMM, W_MEM = 0.4, 0.3, 0.3
SIGMA_THRESHOLD = 0.10          # sigma_0: trigger when std(loads) exceeds it
ALPHA_MAX = 0.7                 # anti-thrash boost right after a migration
ALPHA_WINDOW_S = 60.0           # decays to zero over this many seconds
MAX_MOVES_PER_PLAN = 8


@dataclasses.dataclass
class MachineTelemetry:
    """Per-machine, per-shard load metrics for one balancing epoch.

    cpu/comm/mem map shard id -> that shard's contribution on this
    machine (cpu and mem normalized cluster-wide, comm in raw bytes).
    corr optionally carries per-shard workload-correlation estimates and
    hot the machine's share of recent hot-query traffic.
    """

    machine_id: int
    shard_ids: list
    cpu: dict
    comm: dict
    mem: dict
    corr: dict = dataclasses.field(default_factory=dict)
    hot: float = 0.0


def machine_load(t: MachineTelemetry, comm_max: float) -> float:
    """Multi-metric fusion L(M_k) (paper §4.1)."""
    cpu = float(sum(t.cpu.values()))
    comm = float(sum(t.comm.values()))
    mem = float(sum(t.mem.values()))
    return (W_CPU * cpu
            + W_COMM * min(comm / max(comm_max, 1e-9), 1.0)
            + W_MEM * mem)


def cluster_sigma(loads: np.ndarray) -> float:
    """Population std of machine loads — the rebalance trigger signal."""
    loads = np.asarray(loads, dtype=np.float64)
    return float(loads.std()) if loads.size else 0.0


def alpha_decay(seconds_since_migration: float) -> float:
    """Anti-thrash factor: 0.7 right after a migration, 0 after 60 s."""
    return max(0.0, ALPHA_MAX * (1.0 - seconds_since_migration
                                 / ALPHA_WINDOW_S))


def _shard_load(t: MachineTelemetry, sid, comm_max: float) -> float:
    return (W_CPU * t.cpu.get(sid, 0.0)
            + W_COMM * t.comm.get(sid, 0.0) / max(comm_max, 1e-9)
            + W_MEM * t.mem.get(sid, 0.0))


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    trigger: bool
    moves: list              # [(sid, src_machine, tgt_machine), ...]
    sigma_before: float
    sigma_after: float       # projected sigma once moves are applied


def plan_migrations(telemetry: list[MachineTelemetry],
                    corr_fn: Callable = lambda sid, machine: 0.0,
                    wlabel_fn: Callable = lambda sid, machine: 1.0,
                    shard_sizes: dict | None = None,
                    sigma_threshold: float = SIGMA_THRESHOLD,
                    seconds_since_migration: float = ALPHA_WINDOW_S,
                    max_moves: int = MAX_MOVES_PER_PLAN) -> MigrationPlan:
    """Algorithm 1 (planning): greedy sigma-reducing shard moves.

    Only machines above the mean load at plan time can donate, only
    machines below it can receive; every accepted move strictly reduces
    the simulated sigma, so applying the plan is guaranteed to lower the
    cluster imbalance it was computed from.
    """
    shard_sizes = shard_sizes or {}
    comm_max = max((sum(t.comm.values()) for t in telemetry), default=1.0)
    comm_max = max(comm_max, 1e-9)
    loads = np.array([machine_load(t, comm_max) for t in telemetry])
    sigma0 = cluster_sigma(loads)
    threshold = sigma_threshold * (1.0
                                   + alpha_decay(seconds_since_migration))
    if sigma0 <= threshold or len(telemetry) < 2:
        return MigrationPlan(False, [], sigma0, sigma0)

    mean = loads.mean()
    donors = {t.machine_id for t, l in zip(telemetry, loads) if l > mean}
    receivers = {t.machine_id for t, l in zip(telemetry, loads)
                 if l <= mean}
    tele_of = {t.machine_id: t for t in telemetry}
    sim = {t.machine_id: l for t, l in zip(telemetry, loads)}
    placed = {sid: t.machine_id for t in telemetry for sid in t.shard_ids}
    moved: set = set()
    moves: list[tuple] = []

    for _ in range(max_moves):
        src = max(donors, key=lambda k: sim[k])
        tgt = min(receivers, key=lambda k: sim[k])
        sigma_cur = cluster_sigma(np.array(list(sim.values())))
        t_src = tele_of[src]
        candidates = [sid for sid, mk in placed.items()
                      if mk == src and sid not in moved]
        if not candidates:
            break
        # correlation-aware preference: big load contribution, weakly
        # correlated with the donor's remaining working set, high label
        # affinity with the receiver, cheap to ship
        max_size = max(shard_sizes.values(), default=1.0) or 1.0

        def rank(sid):
            sl = _shard_load(t_src, sid, comm_max)
            cost = shard_sizes.get(sid, 0.0) / max_size
            return sl * (1.0 - float(corr_fn(sid, src))) \
                * (0.5 + 0.5 * float(wlabel_fn(sid, tgt))) \
                / (1.0 + 0.25 * cost)
        candidates.sort(key=rank, reverse=True)
        accepted = None
        for sid in candidates:
            sl = _shard_load(t_src, sid, comm_max)
            if sl <= 0.0:
                continue
            trial = dict(sim)
            trial[src] -= sl
            trial[tgt] += sl
            if cluster_sigma(np.array(list(trial.values()))) \
                    < sigma_cur - 1e-12:
                accepted = (sid, sl)
                break
        if accepted is None:
            break
        sid, sl = accepted
        sim[src] -= sl
        sim[tgt] += sl
        placed[sid] = tgt
        moved.add(sid)
        moves.append((sid, src, tgt))

    sigma1 = cluster_sigma(np.array(list(sim.values())))
    return MigrationPlan(True, moves, sigma0, sigma1)
