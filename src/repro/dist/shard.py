"""Ultra-fine shards: owned region + halo context + CRC'd byte images.

A shard is the unit of placement, migration, and failover.  It carries:

  * its local graph — the induced subgraph on the owned vertices plus a
    `halo_hops`-deep ring of context vertices, so every owned vertex sees
    its full n-hop neighborhood and every short data path with a locally
    owned canonical endpoint is enumerable locally;
  * `global_ids` mapping local -> global vertex ids (sorted ascending, so
    local order agrees with global order);
  * `owned_mask` implementing the canonical-owner rule: an edge/path is
    *indexed* by exactly the shard that owns its smaller-global-id
    endpoint — every edge indexed by exactly one shard, no duplicates;
  * optionally a `ShardIndex` (embedded path tables + aR-trees, built by
    the cluster engine).

`serialize`/`deserialize` produce a canonical byte image (numpy npz —
deterministic, so re-serialization is byte-identical) used as the replica
format for migration (CRC32-verified, Algorithm 1) and failover.
"""

from __future__ import annotations

import dataclasses
import io
import zlib

import numpy as np

from repro.core.artree import ARTree, build_artree
from repro.core.embedding import EmbeddedPaths
from repro.core.graph import LabeledGraph
from repro.core.matching import ShardIndex

__all__ = ["Shard", "make_shard", "make_shards", "shard_crc32",
           "halo_region", "shard_delta", "apply_shard_delta"]


def shard_crc32(blob: bytes) -> int:
    """Index-consistency checksum used by Algorithm-1 migration."""
    return zlib.crc32(blob) & 0xFFFFFFFF


@dataclasses.dataclass
class Shard:
    """One ultra-fine shard of the data graph.

    Attributes:
      sid:        shard id (== its part id in the partition).
      graph:      local induced subgraph (owned + halo vertices).
      global_ids: int64 [n_local] global id of each local vertex.
      owned_mask: bool [n_local]  True iff the vertex is owned (not halo).
      index:      per-shard path index (set by the cluster engine).
    """

    sid: int
    graph: LabeledGraph
    global_ids: np.ndarray
    owned_mask: np.ndarray
    index: ShardIndex | None = None

    @property
    def n_owned(self) -> int:
        return int(self.owned_mask.sum())

    def nbytes(self) -> int:
        total = (self.global_ids.nbytes + self.owned_mask.nbytes
                 + self.graph.labels.nbytes + self.graph.edge_list.nbytes)
        if self.index is not None:
            total += self.index.nbytes()
        return total

    def label_histogram(self, n_labels: int) -> np.ndarray:
        h = np.bincount(self.graph.labels[self.owned_mask],
                        minlength=n_labels).astype(np.float64)
        return h / max(h.sum(), 1.0)

    # ------------------------------------------------------------------ #
    # canonical byte image (replica / migration format)
    # ------------------------------------------------------------------ #
    def serialize(self) -> bytes:
        arrays: dict[str, np.ndarray] = {
            "sid": np.int64(self.sid),
            "global_ids": self.global_ids.astype(np.int64),
            "owned_mask": self.owned_mask.astype(np.bool_),
            "graph": np.frombuffer(self.graph.serialize(), dtype=np.uint8),
        }
        lengths = sorted(self.index.embedded) if self.index is not None else []
        arrays["lengths"] = np.asarray(lengths, dtype=np.int64)
        for l in lengths:
            ep = self.index.embedded[l]
            arrays[f"pv{l}"] = ep.vertices.astype(np.int32)
            arrays[f"pe{l}"] = ep.embeddings.astype(np.float32)
            arrays[f"tree{l}"] = np.frombuffer(
                self.index.trees[l].serialize(), dtype=np.uint8)
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        return buf.getvalue()

    @staticmethod
    def deserialize(blob: bytes) -> "Shard":
        z = np.load(io.BytesIO(blob))
        graph = LabeledGraph.deserialize(z["graph"].tobytes())
        lengths = [int(l) for l in z["lengths"]]
        index = None
        if lengths:
            embedded = {
                l: EmbeddedPaths(vertices=z[f"pv{l}"],
                                 embeddings=z[f"pe{l}"], length=l)
                for l in lengths
            }
            trees = {l: ARTree.deserialize(z[f"tree{l}"].tobytes())
                     for l in lengths}
            index = ShardIndex(embedded=embedded, trees=trees)
        return Shard(sid=int(z["sid"]),
                     graph=graph,
                     global_ids=z["global_ids"].copy(),
                     owned_mask=z["owned_mask"].copy(),
                     index=index)


def halo_region(graph: LabeledGraph, owned: np.ndarray,
                halo_hops: int) -> np.ndarray:
    """Owned vertex set expanded by `halo_hops` BFS rings (global ids)."""
    in_region = np.zeros(graph.n_vertices, dtype=bool)
    in_region[owned] = True
    frontier = owned
    for _ in range(halo_hops):
        if frontier.size == 0:
            break
        starts = graph.indptr[frontier]
        stops = graph.indptr[frontier + 1]
        counts = (stops - starts).astype(np.int64)
        total = int(counts.sum())
        if total == 0:
            break
        offs = np.arange(total, dtype=np.int64) - np.repeat(
            np.cumsum(counts) - counts, counts)
        nbrs = graph.indices[np.repeat(starts, counts) + offs]
        new = np.unique(nbrs[~in_region[nbrs]])
        in_region[new] = True
        frontier = new
    return np.flatnonzero(in_region)


def make_shard(graph: LabeledGraph, assignment: np.ndarray, sid: int,
               halo_hops: int = 2) -> Shard:
    """Cut ONE shard (owned region + halo) out of the data graph.

    Single-shard twin of `make_shards`, so the streaming-update path can
    rebuild exactly the touched shards of a mutated graph — the result
    is bit-identical to the shard a full `make_shards` on the same
    (graph, assignment) would produce at position `sid`.
    """
    assignment = np.asarray(assignment)
    owned = np.flatnonzero(assignment == sid).astype(np.int64)
    region = halo_region(graph, owned, halo_hops)
    local, vids = graph.induced_subgraph(region)
    owned_mask = assignment[vids] == sid
    return Shard(sid=sid, graph=local, global_ids=vids.astype(np.int64),
                 owned_mask=owned_mask)


def make_shards(graph: LabeledGraph, assignment: np.ndarray, n_parts: int,
                halo_hops: int = 2) -> list[Shard]:
    """Cut the data graph into shards with `halo_hops` rings of context.

    The canonical-owner rule (owned_mask + min-global-id endpoint) makes
    every edge of the global graph indexed by exactly one shard, while the
    halo guarantees the owning shard actually contains the edge and the
    full message-passing context of its owned vertices.
    """
    return [make_shard(graph, assignment, sid, halo_hops)
            for sid in range(n_parts)]


# --------------------------------------------------------------------------- #
# streaming-update delta images (CRC'd like migration replicas)
# --------------------------------------------------------------------------- #
def shard_delta(old: Shard, new: Shard) -> bytes:
    """Canonical delta image: only the components that changed.

    Compares ``new`` (the re-indexed shard) against ``old`` (the replica
    the hosting machine already holds) and serializes just the changed
    parts: the region (graph + global_ids + owned_mask) if membership or
    edges moved, and each path length whose table/embeddings changed.
    Unchanged lengths ship a carry marker instead of bytes — applying
    the delta keeps the OLD objects for them (identity preserved, so
    their resident probe planes stay warm).  Changed lengths ship the
    embedding matrix but NOT the aR-tree: `build_artree` is
    deterministic and bit-stable, so the receiver bulk-reloads an
    identical tree from the embeddings (+ the branching factor),
    roughly halving changed-length delta bytes.  The blob is
    npz-canonical: CRC32-able and byte-stable, exactly like the
    migration replica format it rides next to.
    """
    arrays: dict[str, np.ndarray] = {"sid": np.int64(new.sid)}
    ids_changed = not np.array_equal(old.global_ids, new.global_ids)
    region_changed = (
        ids_changed
        or not np.array_equal(old.owned_mask, new.owned_mask)
        or old.graph.n_vertices != new.graph.n_vertices
        or not np.array_equal(old.graph.labels, new.graph.labels)
        or not np.array_equal(old.graph.edge_list, new.graph.edge_list))
    arrays["has_region"] = np.bool_(region_changed)
    if region_changed:
        arrays["global_ids"] = new.global_ids.astype(np.int64)
        arrays["owned_mask"] = new.owned_mask.astype(np.bool_)
        arrays["graph"] = np.frombuffer(new.graph.serialize(),
                                        dtype=np.uint8)
    lengths = sorted(new.index.embedded) if new.index is not None else []
    changed, carried = [], []
    for l in lengths:
        ep_new = new.index.embedded[l]
        ep_old = (old.index.embedded.get(l)
                  if old.index is not None else None)
        # carry is gated on the LOCAL-ID MAPPING (global_ids), not the
        # whole region: an edge/label change inside the region leaves
        # any length whose table + embeddings are bit-identical fully
        # valid — it carries, and its resident probe plane stays warm
        same = (not ids_changed and ep_old is not None
                and np.array_equal(ep_old.vertices, ep_new.vertices)
                and np.array_equal(ep_old.embeddings, ep_new.embeddings))
        if same:
            carried.append(l)
        else:
            changed.append(l)
            arrays[f"pv{l}"] = ep_new.vertices.astype(np.int32)
            arrays[f"pe{l}"] = ep_new.embeddings.astype(np.float32)
            # the branching factor is the ONLY tree datum that ships;
            # a tree-less staged index (cluster builds none sender-side)
            # inherits it from the previous epoch's tree
            tree = new.index.trees.get(l) or (
                old.index.trees.get(l) if old.index is not None else None)
            arrays[f"tb{l}"] = np.int64(tree.branching if tree is not None
                                        else 16)
    arrays["changed"] = np.asarray(changed, np.int64)
    arrays["carried"] = np.asarray(carried, np.int64)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def apply_shard_delta(old: Shard, blob: bytes) -> Shard:
    """Install a CRC-verified delta on top of the local replica.

    Carried lengths keep the old EmbeddedPaths/ARTree OBJECTS (identity
    intact — the plane cache's staleness check sees the same tree and
    keeps the slab resident); changed components are decoded from the
    delta.  The merged shard is byte-identical to the sender's
    re-indexed shard (`Shard.serialize` equality is property-tested).
    """
    z = np.load(io.BytesIO(blob))
    if int(z["sid"]) != old.sid:
        raise ValueError("delta addressed to a different shard")
    if bool(z["has_region"]):
        graph = LabeledGraph.deserialize(z["graph"].tobytes())
        global_ids = z["global_ids"].copy()
        owned_mask = z["owned_mask"].copy()
    else:
        graph, global_ids, owned_mask = (old.graph, old.global_ids,
                                         old.owned_mask)
    embedded: dict[int, EmbeddedPaths] = {}
    trees: dict[int, ARTree] = {}
    for l in [int(x) for x in z["carried"]]:
        embedded[l] = old.index.embedded[l]
        trees[l] = old.index.trees[l]
    for l in [int(x) for x in z["changed"]]:
        emb = z[f"pe{l}"]
        embedded[l] = EmbeddedPaths(vertices=z[f"pv{l}"],
                                    embeddings=emb, length=l)
        # receiver-side bulk reload: bit-identical to the sender's tree
        # (build_artree is deterministic), so the tree never ships
        trees[l] = build_artree(emb, branching=int(z[f"tb{l}"]))
    index = ShardIndex(embedded=embedded, trees=trees) if embedded else None
    return Shard(sid=old.sid, graph=graph, global_ids=global_ids,
                 owned_mask=owned_mask, index=index)
