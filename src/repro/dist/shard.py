"""Ultra-fine shards: owned region + halo context + CRC'd byte images.

A shard is the unit of placement, migration, and failover.  It carries:

  * its local graph — the induced subgraph on the owned vertices plus a
    `halo_hops`-deep ring of context vertices, so every owned vertex sees
    its full n-hop neighborhood and every short data path with a locally
    owned canonical endpoint is enumerable locally;
  * `global_ids` mapping local -> global vertex ids (sorted ascending, so
    local order agrees with global order);
  * `owned_mask` implementing the canonical-owner rule: an edge/path is
    *indexed* by exactly the shard that owns its smaller-global-id
    endpoint — every edge indexed by exactly one shard, no duplicates;
  * optionally a `ShardIndex` (embedded path tables + aR-trees, built by
    the cluster engine).

`serialize`/`deserialize` produce a canonical byte image (numpy npz —
deterministic, so re-serialization is byte-identical) used as the replica
format for migration (CRC32-verified, Algorithm 1) and failover.
"""

from __future__ import annotations

import dataclasses
import io
import zlib

import numpy as np

from repro.core.artree import ARTree
from repro.core.embedding import EmbeddedPaths
from repro.core.graph import LabeledGraph
from repro.core.matching import ShardIndex

__all__ = ["Shard", "make_shards", "shard_crc32", "halo_region"]


def shard_crc32(blob: bytes) -> int:
    """Index-consistency checksum used by Algorithm-1 migration."""
    return zlib.crc32(blob) & 0xFFFFFFFF


@dataclasses.dataclass
class Shard:
    """One ultra-fine shard of the data graph.

    Attributes:
      sid:        shard id (== its part id in the partition).
      graph:      local induced subgraph (owned + halo vertices).
      global_ids: int64 [n_local] global id of each local vertex.
      owned_mask: bool [n_local]  True iff the vertex is owned (not halo).
      index:      per-shard path index (set by the cluster engine).
    """

    sid: int
    graph: LabeledGraph
    global_ids: np.ndarray
    owned_mask: np.ndarray
    index: ShardIndex | None = None

    @property
    def n_owned(self) -> int:
        return int(self.owned_mask.sum())

    def nbytes(self) -> int:
        total = (self.global_ids.nbytes + self.owned_mask.nbytes
                 + self.graph.labels.nbytes + self.graph.edge_list.nbytes)
        if self.index is not None:
            total += self.index.nbytes()
        return total

    def label_histogram(self, n_labels: int) -> np.ndarray:
        h = np.bincount(self.graph.labels[self.owned_mask],
                        minlength=n_labels).astype(np.float64)
        return h / max(h.sum(), 1.0)

    # ------------------------------------------------------------------ #
    # canonical byte image (replica / migration format)
    # ------------------------------------------------------------------ #
    def serialize(self) -> bytes:
        arrays: dict[str, np.ndarray] = {
            "sid": np.int64(self.sid),
            "global_ids": self.global_ids.astype(np.int64),
            "owned_mask": self.owned_mask.astype(np.bool_),
            "graph": np.frombuffer(self.graph.serialize(), dtype=np.uint8),
        }
        lengths = sorted(self.index.embedded) if self.index is not None else []
        arrays["lengths"] = np.asarray(lengths, dtype=np.int64)
        for l in lengths:
            ep = self.index.embedded[l]
            arrays[f"pv{l}"] = ep.vertices.astype(np.int32)
            arrays[f"pe{l}"] = ep.embeddings.astype(np.float32)
            arrays[f"tree{l}"] = np.frombuffer(
                self.index.trees[l].serialize(), dtype=np.uint8)
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        return buf.getvalue()

    @staticmethod
    def deserialize(blob: bytes) -> "Shard":
        z = np.load(io.BytesIO(blob))
        graph = LabeledGraph.deserialize(z["graph"].tobytes())
        lengths = [int(l) for l in z["lengths"]]
        index = None
        if lengths:
            embedded = {
                l: EmbeddedPaths(vertices=z[f"pv{l}"],
                                 embeddings=z[f"pe{l}"], length=l)
                for l in lengths
            }
            trees = {l: ARTree.deserialize(z[f"tree{l}"].tobytes())
                     for l in lengths}
            index = ShardIndex(embedded=embedded, trees=trees)
        return Shard(sid=int(z["sid"]),
                     graph=graph,
                     global_ids=z["global_ids"].copy(),
                     owned_mask=z["owned_mask"].copy(),
                     index=index)


def halo_region(graph: LabeledGraph, owned: np.ndarray,
                halo_hops: int) -> np.ndarray:
    """Owned vertex set expanded by `halo_hops` BFS rings (global ids)."""
    in_region = np.zeros(graph.n_vertices, dtype=bool)
    in_region[owned] = True
    frontier = owned
    for _ in range(halo_hops):
        if frontier.size == 0:
            break
        starts = graph.indptr[frontier]
        stops = graph.indptr[frontier + 1]
        counts = (stops - starts).astype(np.int64)
        total = int(counts.sum())
        if total == 0:
            break
        offs = np.arange(total, dtype=np.int64) - np.repeat(
            np.cumsum(counts) - counts, counts)
        nbrs = graph.indices[np.repeat(starts, counts) + offs]
        new = np.unique(nbrs[~in_region[nbrs]])
        in_region[new] = True
        frontier = new
    return np.flatnonzero(in_region)


def make_shards(graph: LabeledGraph, assignment: np.ndarray, n_parts: int,
                halo_hops: int = 2) -> list[Shard]:
    """Cut the data graph into shards with `halo_hops` rings of context.

    The canonical-owner rule (owned_mask + min-global-id endpoint) makes
    every edge of the global graph indexed by exactly one shard, while the
    halo guarantees the owning shard actually contains the edge and the
    full message-passing context of its owned vertices.
    """
    assignment = np.asarray(assignment)
    shards: list[Shard] = []
    for sid in range(n_parts):
        owned = np.flatnonzero(assignment == sid).astype(np.int64)
        region = halo_region(graph, owned, halo_hops)
        local, vids = graph.induced_subgraph(region)
        owned_mask = assignment[vids] == sid
        shards.append(Shard(sid=sid, graph=local,
                            global_ids=vids.astype(np.int64),
                            owned_mask=owned_mask))
    return shards
