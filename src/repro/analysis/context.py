"""Per-file analysis context + the shared kernel-contract table.

The contract table is parsed from the AST of
``src/repro/kernels/dominance/ops.py`` (plus ``kernel.py`` for the
``BLOCK_*`` constants) — reprolint never imports project modules, so it
runs without jax and cannot be confused by runtime monkey-patching.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from functools import lru_cache
from pathlib import Path

from repro.analysis.astutil import module_int_constants

SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Z0-9, ]+)")

CONTRACT_MODULES = ("src/repro/kernels/dominance/ops.py",
                    "src/repro/kernels/dominance/kernel.py")


@dataclasses.dataclass
class FileContext:
    """One parsed source file handed to every applicable rule."""

    path: Path                   # absolute
    rel: str                     # repo-relative, POSIX separators
    source: str
    tree: ast.AST
    root: Path                   # repo root (contract-table anchor)

    @classmethod
    def parse(cls, path: Path, root: Path) -> "FileContext | None":
        try:
            source = path.read_text()
            tree = ast.parse(source, filename=str(path))
        except (SyntaxError, UnicodeDecodeError, OSError):
            return None
        rel = path.resolve().relative_to(root.resolve()).as_posix()
        return cls(path=path, rel=rel, source=source, tree=tree, root=root)

    def suppressed_lines(self) -> dict[int, set[str]]:
        """line -> suppressed rule ids.  ``# reprolint: disable=RPR004``
        on a code line suppresses that line; on a comment-only line it
        suppresses the next line."""
        out: dict[int, set[str]] = {}
        for i, text in enumerate(self.source.splitlines(), start=1):
            m = SUPPRESS_RE.search(text)
            if not m:
                continue
            ids = {t.strip() for t in m.group(1).split(",") if t.strip()}
            line = i + 1 if text.lstrip().startswith("#") else i
            out.setdefault(line, set()).update(ids)
        return out

    def contracts(self) -> "ContractTable":
        return load_contracts(self.root)

    def local_contracts(self) -> dict | None:
        """KERNEL_CONTRACTS defined in THIS file (fixture self-tests),
        resolved against this file's own constants."""
        consts = module_int_constants(self.tree)
        return _extract_contracts(self.tree, consts)


@dataclasses.dataclass
class ContractTable:
    """Declared kernel contracts + the constant table they resolve in."""

    constants: dict              # name -> int (buckets + blocks)
    contracts: dict              # callee terminal name -> contract dict

    def boundary_names(self) -> set[str]:
        return set(self.contracts)


def _literal(node: ast.AST, consts: dict):
    """Evaluate a contract-table value node: constants, names bound to
    ints, strings, tuples/lists/dicts thereof."""
    if isinstance(node, ast.Constant):
        return node.value
    if isinstance(node, ast.Name):
        return consts.get(node.id, node.id)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(_literal(e, consts) for e in node.elts)
    if isinstance(node, ast.Dict):
        return {_literal(k, consts): _literal(v, consts)
                for k, v in zip(node.keys, node.values) if k is not None}
    if isinstance(node, ast.Call):  # dict(...) sugar
        if getattr(node.func, "id", None) == "dict":
            return {kw.arg: _literal(kw.value, consts)
                    for kw in node.keywords if kw.arg}
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _literal(node.operand, consts)
        return -v if isinstance(v, (int, float)) else None
    return None


def _extract_contracts(tree: ast.AST, consts: dict) -> dict | None:
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) \
                and len(stmt.targets) == 1 \
                and getattr(stmt.targets[0], "id", None) \
                == "KERNEL_CONTRACTS":
            table = _literal(stmt.value, consts)
            return table if isinstance(table, dict) else None
    return None


@lru_cache(maxsize=4)
def load_contracts(root: Path) -> ContractTable:
    consts: dict[str, int] = {}
    contracts: dict = {}
    for rel in CONTRACT_MODULES:
        path = root / rel
        if not path.exists():
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        consts.update(module_int_constants(tree))
    for rel in CONTRACT_MODULES:
        path = root / rel
        if not path.exists():
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        found = _extract_contracts(tree, consts)
        if found:
            contracts.update(found)
    return ContractTable(constants=consts, contracts=contracts)
