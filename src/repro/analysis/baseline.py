"""Baseline: documented, accepted findings that don't fail the run.

Entries match on (rule, path, normalized line content) — NOT the line
number — so unrelated edits above a baselined site don't churn the
file.  Each entry carries a mandatory ``reason``; an entry that stops
matching anything is reported as stale (and fails the run) so the
baseline can only shrink, never silently rot.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.analysis.finding import Finding

DEFAULT_BASELINE = Path(__file__).with_name("baseline.json")


def load(path: Path | None = None) -> list[dict]:
    p = path or DEFAULT_BASELINE
    if not p.exists():
        return []
    data = json.loads(p.read_text())
    entries = data.get("entries", [])
    for e in entries:
        for field in ("rule", "path", "content", "reason"):
            if field not in e:
                raise ValueError(
                    f"baseline entry missing '{field}': {e}")
    return entries


def _line_content(finding: Finding, sources: dict[str, list[str]]) -> str:
    lines = sources.get(finding.path, [])
    if 1 <= finding.line <= len(lines):
        return lines[finding.line - 1].strip()
    return ""


def apply(findings: list[Finding], entries: list[dict],
          sources: dict[str, list[str]]
          ) -> tuple[list[Finding], list[Finding], list[dict]]:
    """Split into (kept, baselined, stale_entries).

    Each entry absorbs at most one finding per occurrence (duplicate
    identical lines need duplicate entries).
    """
    pool: dict[tuple, list[dict]] = {}
    for e in entries:
        pool.setdefault((e["rule"], e["path"], e["content"].strip()),
                        []).append(e)
    kept, baselined = [], []
    for f in findings:
        key = (f.rule, f.path, _line_content(f, sources))
        bucket = pool.get(key)
        if bucket:
            bucket.pop()
            baselined.append(f)
        else:
            kept.append(f)
    stale = [e for bucket in pool.values() for e in bucket]
    return kept, baselined, stale


def render_entry(finding: Finding, sources: dict[str, list[str]],
                 reason: str = "TODO: document why this is accepted"
                 ) -> dict:
    return {"rule": finding.rule, "path": finding.path,
            "line": finding.line,
            "content": _line_content(finding, sources),
            "reason": reason}
