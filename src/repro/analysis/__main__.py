"""CLI: ``python -m repro.analysis [--paths ...] [--format text|json]``.

Exit codes: 0 clean (baselined/suppressed findings allowed), 1 findings
or stale baseline entries, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import baseline as baseline_mod
from repro.analysis.runner import (find_root, format_json, format_text,
                                   run_paths)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: static invariant checks for this repo "
                    "(rule catalog in docs/static-analysis.md)")
    ap.add_argument("--paths", nargs="+",
                    default=["src", "tests", "benchmarks"],
                    help="files or directories to scan (repo-relative)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", nargs="+", default=None, metavar="RPR00x",
                    help="run only these rule ids")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings as failures")
    ap.add_argument("--write-baseline", action="store_true",
                    help="append current findings to analysis/baseline.json"
                         " with TODO reasons (then document them!)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    root = find_root(Path.cwd())
    result = run_paths(args.paths, root=root,
                       rule_ids=set(args.rules) if args.rules else None,
                       use_baseline=not args.no_baseline)

    if args.write_baseline:
        sources = {}
        for f in result.findings:
            p = root / f.path
            sources[f.path] = p.read_text().splitlines()
        entries = baseline_mod.load() + [
            baseline_mod.render_entry(f, sources) for f in result.findings]
        baseline_mod.DEFAULT_BASELINE.write_text(
            json.dumps({"entries": entries}, indent=2) + "\n")
        print(f"wrote {len(entries)} entries to "
              f"{baseline_mod.DEFAULT_BASELINE}")
        return 0

    out = (format_json(result) if args.format == "json"
           else format_text(result, verbose=args.verbose))
    print(out)
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
