"""Finding objects: one rule violation at one source location."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """A single rule violation.

    ``path`` is repo-relative (POSIX separators) so baselines written
    on one checkout match any other; ``line`` is 1-based.
    """

    rule: str                    # "RPR001"
    path: str                    # "src/repro/dist/cluster.py"
    line: int
    message: str
    hint: str = ""               # how to fix (or suppress) it
    col: int = 0

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def render(self) -> str:
        out = f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def to_json(self) -> dict:
        return dataclasses.asdict(self)
