"""RPR005: implicit host-device sync inside the pipelined dispatch region.

The PR-4 wall-clock win is overlap: ``_mb_dispatch`` launches batch
k+1's fused probe asynchronously while batch k's host-side join runs,
and only ``mega_readback`` (called from consume) is allowed to block.
Forcing a device value inside the dispatch half — ``np.asarray``,
``.item()``, ``float()``, ``.block_until_ready()`` on anything the
launch produced — serializes the pipeline back to the latency the
serial plane path already had, without failing any correctness test.

Scope: the dispatch-region functions by name (``mega_dispatch``,
``_mb_dispatch``).  Taint: names bound from boundary-launch results
(``megabatch_leaf_probe*``, ``fused_plan_descent*``, ``mega_dispatch``)
and the in-flight device attributes (``finals``, ``counts_dev``,
``gverts_dev``, ``leaves``).  Host-side operands (qmat stacks, packed
masks) are untainted — forcing those is normal packing work.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import FuncEnv, iter_functions, terminal
from repro.analysis.registry import Rule, register

DISPATCH_REGION_FUNCS = {"mega_dispatch", "_mb_dispatch"}
LAUNCHES = {"megabatch_leaf_probe", "megabatch_leaf_probe_jit",
            "fused_plan_descent", "fused_plan_descent_jit",
            "mega_dispatch", "gather_pack_lanes_jit"}
DEVICE_ATTRS = {"finals", "counts_dev", "gverts_dev", "leaves"}
FORCING_CALLS = {"asarray", "array", "float", "int", "bool",
                 "device_get"}
FORCING_METHODS = {"item", "block_until_ready", "tolist"}


def _tainted_names(func: ast.AST, env: FuncEnv) -> set[str]:
    tainted: set[str] = set()
    changed = True
    while changed:
        changed = False
        for node in ast.walk(func):
            if not isinstance(node, ast.Assign):
                continue
            v = node.value
            hit = False
            if isinstance(v, ast.Call) and terminal(v.func) in LAUNCHES:
                hit = True
            else:
                for sub in ast.walk(v):
                    if isinstance(sub, ast.Attribute) \
                            and sub.attr in DEVICE_ATTRS:
                        hit = True
                    if isinstance(sub, ast.Name) and sub.id in tainted:
                        hit = True
            if hit:
                for tgt in node.targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name) \
                                and n.id not in tainted:
                            tainted.add(n.id)
                            changed = True
    return tainted


def _mentions_device(expr: ast.AST, tainted: set[str]) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Name) and sub.id in tainted:
            return True
        if isinstance(sub, ast.Attribute) and sub.attr in DEVICE_ATTRS:
            return True
    return False


@register
class ImplicitSyncRule(Rule):
    id = "RPR005"
    name = "implicit-sync-in-dispatch-region"

    def check(self, ctx):
        for qualname, func in iter_functions(ctx.tree):
            if func.name not in DISPATCH_REGION_FUNCS:
                continue
            env = FuncEnv(func)
            tainted = _tainted_names(func, env)
            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                t = terminal(node.func)
                if t in FORCING_METHODS \
                        and isinstance(node.func, ast.Attribute):
                    target = node.func.value
                elif t in FORCING_CALLS and node.args:
                    target = node.args[0]
                else:
                    continue
                if not _mentions_device(target, tainted):
                    continue
                yield self.finding(
                    ctx, node,
                    f"'{ast.unparse(node.func)}' forces a device value "
                    "inside the pipelined dispatch region — this blocks "
                    "the async launch and serializes the batch pipeline",
                    hint="keep device arrays opaque until mega_readback "
                         "(the consume half); move host logic before "
                         "the launch or after readback")
