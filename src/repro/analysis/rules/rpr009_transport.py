"""RPR009: every cross-machine byte goes through the transport seam.

The transport refactor (repro.dist.transport) holds its cross-backend
bit-identity contract — "SimTransport and MeshTransport agree on
matches, counters, and comm bytes" — only if NO engine code moves
another machine's bytes around the seam.  Two bypass shapes exist:

  * calling the legacy link primitives (``crc_transfer`` /
    ``_link_faults``) directly — those ship bytes through the
    process-wide default SimTransport, so a mesh engine would silently
    run that transfer in-process: the fault-free run still passes and
    the divergence only surfaces as a wire-ledger mismatch (or worse,
    bytes that never physically reach their rank);
  * reading another machine's replica image via a
    ``replicas.copies[sid][m]`` subscript — the standby bytes must come
    through ``transport.fetch_replica`` (the remote-read site on a real
    mesh), exactly as RPR008 funnels primary reads through the router.

Heuristic, inside ``src/repro/dist/``: any Call to ``crc_transfer`` or
``_link_faults`` (plain or attribute form) is flagged, and any
Load-context subscript of an attribute named ``copies`` is flagged
unless it sits inside an assignment/delete target (ownership mutations
— e.g. the COMMIT-phase ``del self.replicas.copies[sid][m]`` — stay
legal).  ``transport.py`` itself and ``replica.py`` (the store's owner
module) are exempt; ``migration.py``'s ``crc_transfer`` *definition* is
the out-of-engine compat shim and defines, not calls, the primitive.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.registry import Rule, register

LINK_PRIMITIVES = frozenset({"crc_transfer", "_link_faults"})

REPLICA_STORE_ATTR = "copies"


def _iter_functions(tree: ast.AST) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _mutation_target_ids(tree: ast.AST) -> set:
    """ids of every AST node inside an assignment or delete target —
    ownership mutations of the replica store are the owner's business,
    only *reads* of another machine's bytes must cross the seam."""
    out: set = set()

    def mark(node: ast.AST) -> None:
        for sub in ast.walk(node):
            out.add(id(sub))

    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.Delete)):
            for tgt in node.targets:
                mark(tgt)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            mark(node.target)
    return out


@register
class TransportSeamRule(Rule):
    id = "RPR009"
    name = "transport-seam"
    scope = ("src/repro/dist/*.py",)

    def check(self, ctx):
        if ctx.rel.endswith("/transport.py"):
            return
        in_targets = _mutation_target_ids(ctx.tree)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if name in LINK_PRIMITIVES:
                    yield self.finding(
                        ctx, node,
                        f"direct call to link primitive '{name}' — bytes "
                        "bypass the engine's transport backend (a mesh "
                        "engine would ship this transfer in-process, off "
                        "the wire ledger)",
                        hint="route the transfer through "
                             "engine.transport.transfer(...); "
                             "migration.crc_transfer is a compat shim "
                             "for out-of-engine callers only")
                continue
            if ctx.rel.endswith("/replica.py"):
                continue            # the store's owner module
            if not isinstance(node, ast.Subscript):
                continue
            if not isinstance(node.ctx, ast.Load):
                continue
            if id(node) in in_targets:
                continue            # inside an assign/delete target
            val = node.value
            if isinstance(val, ast.Attribute) \
                    and val.attr == REPLICA_STORE_ATTR:
                yield self.finding(
                    ctx, node,
                    "direct read of the replica store "
                    "('.copies[...]') outside the transport — standby "
                    "bytes must come through the seam so a mesh "
                    "backend can home them remotely",
                    hint="use engine.transport.fetch_replica(sid, "
                         "machine) for standby reads")
