"""RPR003: un-CRC'd transfer of a shard/delta byte image.

Paper innovation 1's index-consistency guarantee assumes every byte
image that crosses a machine boundary is verified: ``crc_transfer``
(CRC32 + bounded retry) or ``hot_migrate`` (which calls it).  Decoding
a blob that did NOT come out of a verified transfer silently accepts
link corruption as index state.

The rule scopes to the engine (``src/repro/dist/``): any call to
``Shard.deserialize`` / ``apply_shard_delta`` whose blob argument does
not flow from a ``crc_transfer(...)`` result (the ``.received`` field,
possibly through assignment chains) is flagged.  ``serialize`` /
``shard_delta`` production sites are fine — only consumption of a blob
that crossed a link needs the check.  Local round-trips (tests, same-
machine persistence) are out of scope by path.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import (FuncEnv, call_arg, iter_functions,
                                    terminal)
from repro.analysis.registry import Rule, register

# terminal call name -> index of the blob argument
DECODERS = {"deserialize": 0, "apply_shard_delta": 1}
# functions that ARE the verified-transfer machinery
TRANSFER_FUNCS = {"crc_transfer"}


class _BlobFlow:
    def __init__(self, env: FuncEnv):
        self.env = env

    def verified(self, expr: ast.AST, depth: int = 8) -> bool:
        if depth <= 0:
            return False
        if isinstance(expr, ast.Attribute):
            if expr.attr in ("received", "blob"):
                return True
            return False
        if isinstance(expr, ast.Name):
            bound = self.env.assigns.get(expr.id)
            return bound is not None and self.verified(bound, depth - 1)
        if isinstance(expr, ast.Call):
            return terminal(expr.func) in TRANSFER_FUNCS
        if isinstance(expr, ast.Subscript):
            return self.verified(expr.value, depth - 1)
        return False


@register
class UncrcdTransferRule(Rule):
    id = "RPR003"
    name = "un-crcd-transfer"
    scope = ("src/repro/dist/*.py",)

    def check(self, ctx):
        for qualname, func in iter_functions(ctx.tree):
            # skip the transfer machinery itself AND the decoder
            # implementations: component decodes inside `deserialize` /
            # `apply_shard_delta` operate on a payload the caller
            # already verified at the machine boundary
            if func.name in TRANSFER_FUNCS or func.name in DECODERS:
                continue
            env = FuncEnv(func)
            flow = _BlobFlow(env)
            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                t = terminal(node.func)
                if t not in DECODERS:
                    continue
                arg = call_arg(node, DECODERS[t], "blob")
                if arg is None or flow.verified(arg):
                    continue
                yield self.finding(
                    ctx, node,
                    f"'{t}' decodes blob '{ast.unparse(arg)}' that did "
                    "not come from a crc_transfer — link corruption "
                    "would be accepted as index state",
                    hint="ship the image via migration.crc_transfer "
                         "(or hot_migrate) and decode tr.received")
