"""RPR008: serving-path code must resolve shards through the router.

Degraded-mode serving (ISSUE 9) holds its availability contract —
"every schedule with a live copy gets the exact answer" — only if the
query path never bypasses :class:`~repro.dist.router.ShardRouter`.  A
direct ``self.shards[sid]`` / ``self.routing[sid]`` read inside a
serving function silently reads the PRIMARY's image even when that
primary is dead and a CRC-verified standby holds the live copy: the
fault-free run still passes, and the regression only surfaces as an
availability hole under a crash schedule.  Build, failover, migration
and audit code legitimately own those dictionaries; the read side of
query execution must go through ``router.resolve`` / ``router.read``
(which also attributes comm bytes to the machine that actually served).

Heuristic: inside ``src/repro/dist/`` functions on the serving path
(by name — query/probe/consume/accounting stages), any Load-context
subscript of an attribute named ``shards`` or ``routing`` is flagged.
Writes (``self.shards[sid] = ...``) and every non-serving function are
untouched, and ``router.py`` itself is exempt — the router is the one
component allowed to dereference the index.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.registry import Rule, register

SERVING_FUNCS = frozenset({
    "query", "query_batch", "_execute_serial", "_consume_query",
    "_mb_dispatch", "_mb_consume", "_plan_probe", "_account_rows",
    "_finish_query",
})

INDEX_ATTRS = ("shards", "routing")


def _walk_own(func: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested defs (those
    are visited by their own iter_functions entry)."""
    stack = list(ast.iter_child_nodes(func))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _iter_functions(tree: ast.AST) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


@register
class RouterBypassRule(Rule):
    id = "RPR008"
    name = "router-resolution"
    scope = ("src/repro/dist/*.py",)

    def check(self, ctx):
        if ctx.rel.endswith("/router.py"):
            return
        for func in _iter_functions(ctx.tree):
            if func.name not in SERVING_FUNCS:
                continue
            for node in _walk_own(func):
                if not isinstance(node, ast.Subscript):
                    continue
                if not isinstance(node.ctx, ast.Load):
                    continue
                val = node.value
                if not (isinstance(val, ast.Attribute)
                        and val.attr in INDEX_ATTRS):
                    continue
                yield self.finding(
                    ctx, node,
                    f"serving function '{func.name}' subscripts "
                    f"'.{val.attr}' directly — this bypasses the "
                    "ShardRouter and reads the primary's image even "
                    "when a standby holds the only live copy",
                    hint="resolve through self.router.resolve(sid) / "
                         "self.router.read(sid, ...) on the query path")
