"""RPR001: unbucketed shape at a jit boundary.

Every operand a function passes to a `KERNEL_CONTRACTS` callee whose
``caller_bucketed`` entry names it must have bucket-derived dims: a jit
boundary retraces per distinct operand shape, so a dim that tracks raw
data cardinality (``len(batch)``, ``stacked.shape[0]``) compiles a new
executable on nearly every call.  The PR-3 retrace bound demands every
such dim flow from ``bucket(...)`` / ``mega_query_bucket(...)`` /
``*_BUCKET`` constants (see ops.py).

Mechanics: inside each function that calls a contract callee, every
checked argument's names are resolved to their defining expression; a
``np.zeros/full/empty/ones`` origin gets its shape dims classified by
``FuncEnv.is_bucketed`` (attribute loads = engine state = safe; raw
``len``/``sum``/``.shape`` of stacked hosts = unsafe).  Origins that
are parameters or attributes are assumed checked upstream.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import (ARRAY_CTORS, FuncEnv, call_arg,
                                    iter_functions, names_in, shape_dims,
                                    terminal)
from repro.analysis.registry import Rule, register


@register
class UnbucketedShapeRule(Rule):
    id = "RPR001"
    name = "unbucketed-shape-at-jit-boundary"

    def check(self, ctx):
        contracts = ctx.contracts().contracts
        if not contracts:
            return
        boundary = set(contracts)
        for qualname, func in iter_functions(ctx.tree):
            calls = [n for n in ast.walk(func)
                     if isinstance(n, ast.Call)
                     and terminal(n.func) in boundary]
            if not calls:
                continue
            env = FuncEnv(func)
            reported: set[int] = set()
            for call in calls:
                spec = contracts[terminal(call.func)]
                for opname, idx in spec.get("caller_bucketed", {}).items():
                    arg = call_arg(call, idx, opname)
                    if arg is None:
                        continue
                    yield from self._check_operand(
                        ctx, env, call, arg, opname,
                        terminal(call.func), reported)

    def _check_operand(self, ctx, env, call, arg, opname, callee,
                       reported):
        for name in sorted(names_in(arg)):
            origin = env.origin(ast.Name(id=name, ctx=ast.Load()))
            if not isinstance(origin, ast.Call):
                continue
            t = terminal(origin.func)
            if t not in ARRAY_CTORS:
                continue
            bad = [d for d in shape_dims(origin)
                   if not env.is_bucketed(d)]
            if not bad or id(origin) in reported:
                continue
            reported.add(id(origin))
            dims = ", ".join(ast.unparse(d) for d in bad)
            yield self.finding(
                ctx, origin,
                f"operand '{opname}' of jit boundary '{callee}' is "
                f"built with unbucketed dim(s) [{dims}] — every "
                "distinct value retraces the launch",
                hint="round the dim with bucket(n, <*_BUCKET>) from "
                     "repro.kernels.dominance.ops (pad rows must be "
                     "inert: zero mask bits / -inf boxes / +inf queries)")
