"""Built-in reprolint rules; importing this package registers them."""

from repro.analysis.rules import (rpr001_buckets, rpr002_epoch, rpr003_crc,
                                  rpr004_wallclock, rpr005_sync,
                                  rpr006_contract, rpr007_chaosrng,
                                  rpr008_router, rpr009_transport)

__all__ = ["rpr001_buckets", "rpr002_epoch", "rpr003_crc",
           "rpr004_wallclock", "rpr005_sync", "rpr006_contract",
           "rpr007_chaosrng", "rpr008_router", "rpr009_transport"]
