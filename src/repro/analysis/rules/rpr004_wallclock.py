"""RPR004: wall-clock / ambient randomness in determinism-critical code.

The engine's counters, PE-score labels, and rebalance decisions are
asserted bit-identical across probe modes, megabatching, updates, and
migration.  That only holds because everything they consume is virtual:
``leaves_tested * VIRTUAL_MS_PER_LEAF`` for PE labels,
``EPOCH_VIRTUAL_S`` for the rebalance clock, seeded ``default_rng``
for every stochastic choice.  A single ``time.time()`` or unseeded RNG
in these modules silently breaks the whole bit-identity test pyramid
(the PR-2 determinism sweep fixed exactly such a leak).

Scope: the determinism-critical module list below.  Allowlisted (and
therefore NOT scoped): ``launch/`` and ``train/trainer.py`` (bench/
fit wall timing is their job), plus function ``_fit_pe_model`` (wall
time goes only into the ``pe_fit_report`` diagnostic, never labels).
Wall-clock *diagnostic* fields inside scoped modules (e.g. the engine's
plan/probe/join ms telemetry, which is never asserted) carry inline
``# reprolint: disable=RPR004`` annotations or baseline entries.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import dotted, iter_functions
from repro.analysis.registry import Rule, register

WALL_CLOCK = {"time.time", "time.perf_counter", "time.monotonic",
              "time.process_time", "time.perf_counter_ns",
              "time.time_ns", "datetime.now", "datetime.utcnow",
              "datetime.datetime.now", "datetime.datetime.utcnow"}
LEGACY_NP_RANDOM = {"random", "rand", "randn", "randint", "choice",
                    "shuffle", "permutation", "seed", "uniform",
                    "normal", "standard_normal", "zipf"}
STDLIB_RANDOM = {"random.random", "random.randint", "random.choice",
                 "random.shuffle", "random.uniform", "random.sample",
                 "random.randrange", "random.seed"}
ALLOWED_FUNCS = {"_fit_pe_model"}


@register
class WallClockRule(Rule):
    id = "RPR004"
    name = "wall-clock-determinism"
    scope = (
        "src/repro/core/*.py",
        "src/repro/cache/*.py",
        "src/repro/data/*.py",
        "src/repro/dist/*.py",
        "src/repro/kernels/*.py",
        "src/repro/kernels/*/*.py",
    )

    def check(self, ctx):
        allowed_spans = []
        for qualname, func in iter_functions(ctx.tree):
            if func.name in ALLOWED_FUNCS:
                allowed_spans.append(
                    (func.lineno, max(func.lineno,
                                      func.end_lineno or func.lineno)))
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            label = self._nondeterministic(node)
            if label is None:
                continue
            if any(a <= node.lineno <= b for a, b in allowed_spans):
                continue
            yield self.finding(
                ctx, node,
                f"{label} in a determinism-critical module — PE labels, "
                "counters, and rebalance decisions must be virtual "
                "(bit-identical across modes/machines)",
                hint="use leaves_tested * VIRTUAL_MS_PER_LEAF / "
                     "EPOCH_VIRTUAL_S / a seeded np.random.default_rng; "
                     "for a pure wall-clock diagnostic add "
                     "`# reprolint: disable=RPR004 -- <why>`")

    @staticmethod
    def _nondeterministic(call: ast.Call) -> str | None:
        d = dotted(call.func)
        if d is None:
            return None
        if d in WALL_CLOCK or d in STDLIB_RANDOM:
            return f"wall-clock/ambient call '{d}()'"
        parts = d.split(".")
        # only numpy's GLOBAL rng is ambient state; jax.random is keyed
        # (explicitly seeded) and rng-object methods carry their seed
        if len(parts) >= 3 and parts[-3] in ("np", "numpy") \
                and parts[-2] == "random" \
                and parts[-1] in LEGACY_NP_RANDOM:
            return f"global-RNG call '{d}()'"
        if parts[-1] == "default_rng" and not call.args \
                and not call.keywords:
            return "unseeded 'default_rng()'"
        return None
