"""RPR007: chaos hook points must draw randomness ONLY from the plan rng.

The chaos oracle's whole contract is that a run under a FaultPlan stays
*bit-identical* to its fault-free twin (or fails typed).  That holds
only if fault handling never consumes the ENGINE rng stream: the
fault-free run draws nothing at a hook, so a chaos run drawing from
``self._rng`` (or a freshly minted generator) there would desynchronize
every later engine rng draw and silently break the comparison the whole
test pyramid rests on.  Corruption byte positions, torn-image cut
points and unpinned crash targets must all come from ``FaultPlan.rng``.

Heuristic: inside any ``src/repro/dist/`` function that fires a hook
(calls ``<plan>.fire(...)``), every random-drawing call must be rooted
at ``<plan>.rng`` for one of the fired plans, and no new generator may
be constructed (``default_rng`` anywhere in such a function is flagged,
seeded or not).  Functions without a ``.fire`` call are untouched —
the engine rng is exactly what ``crc_transfer``'s corruption simulation
should use.  Nested defs are scanned independently (a ``.fire`` in a
closure does not constrain its enclosing function).
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astutil import dotted, iter_functions
from repro.analysis.registry import Rule, register

RNG_DRAWS = {"random", "integers", "choice", "uniform", "normal",
             "standard_normal", "shuffle", "permutation", "exponential",
             "bytes"}


def _walk_own(func: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested defs (those
    are visited by their own iter_functions entry)."""
    stack = list(ast.iter_child_nodes(func))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


@register
class ChaosRngRule(Rule):
    id = "RPR007"
    name = "chaos-rng-isolation"
    scope = ("src/repro/dist/*.py",)

    def check(self, ctx):
        for _qualname, func in iter_functions(ctx.tree):
            roots = set()
            for node in _walk_own(func):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "fire":
                    r = dotted(node.func.value)
                    if r is not None:
                        roots.add(r)
            if not roots:
                continue
            allowed = tuple(r + ".rng." for r in roots)
            for node in _walk_own(func):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func)
                if d is None:
                    continue
                parts = d.split(".")
                t = parts[-1]
                if t == "default_rng":
                    yield self.finding(
                        ctx, node,
                        f"'{d}()' constructs a generator inside chaos "
                        f"hook handler '{func.name}' — fault decisions "
                        "must come from the threaded FaultPlan rng",
                        hint=f"draw from {' or '.join(sorted(roots))}"
                             ".rng instead")
                    continue
                if t not in RNG_DRAWS or len(parts) < 2:
                    continue      # bare names are builtins (bytes(...))
                if d.startswith(allowed):
                    continue
                yield self.finding(
                    ctx, node,
                    f"'{d}()' draws randomness in chaos hook handler "
                    f"'{func.name}' from outside the FaultPlan rng — "
                    "this desynchronizes the engine rng stream between "
                    "chaos and fault-free runs, breaking bit-identity",
                    hint=f"use {' or '.join(sorted(roots))}.rng for "
                         "every fault decision")
