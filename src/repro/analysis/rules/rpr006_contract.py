"""RPR006: declared kernel BlockSpec/grid/dtype/pad contract.

`KERNEL_CONTRACTS` (repro/kernels/dominance/ops.py) declares, per jit
boundary, the kernel block each bucketed axis must divide into, the
wire dtype of packed-bit operands, and the pad fill each operand's
semantics assume (+inf queries match nothing, -inf boxes dominate
nothing).  This rule checks three things statically:

1. declaration consistency — for every operand with both a bucket and
   a block, ``bucket % block == 0`` (a bucketed slab is then an exact
   grid of blocks, the relation tests/test_probeplane.py pins at
   runtime), and every ``packed_multiple`` divides its bucket;
2. packed-bit dtype — call-site arguments for operands declared
   ``uint32`` must originate from a ``.view(np.uint32)`` /
   ``dtype=uint32`` construction;
3. pad fill — ``np.full``-style origins of contract operands must use
   the declared fill sign (``-inf`` vs ``+inf``).

A file that defines its own ``KERNEL_CONTRACTS`` (fixtures) is checked
against its own table; everything else checks against the canonical
one.  Origins the AST cannot resolve (parameters, attributes) are
skipped — runtime padding-edge tests in tests/test_kernels.py cover
those from the same table.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import (ARRAY_CTORS, FuncEnv, call_arg,
                                    dotted, is_neg_inf, is_pos_inf,
                                    iter_functions, names_in, terminal)
from repro.analysis.registry import Rule, register


def _contract_assign(tree: ast.AST) -> ast.AST | None:
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) \
                and getattr(stmt.targets[0], "id", None) \
                == "KERNEL_CONTRACTS":
            return stmt
    return None


def _is_uint32_origin(origin: ast.AST) -> bool | None:
    """True/False when decidable from the origin expression, else None."""
    if not isinstance(origin, ast.Call):
        return None
    t = terminal(origin.func)
    if t == "view" and origin.args:
        d = dotted(origin.args[0])
        return d is not None and d.split(".")[-1] == "uint32"
    if t in ARRAY_CTORS | {"asarray", "array"}:
        for cand in list(origin.args[1:]) + [
                kw.value for kw in origin.keywords
                if kw.arg in (None, "dtype")]:
            d = dotted(cand)
            if d is not None:
                return d.split(".")[-1] == "uint32"
        return None
    return None


@register
class KernelContractRule(Rule):
    id = "RPR006"
    name = "kernel-blockspec-contract"

    def check(self, ctx):
        if ctx.rel == "src/repro/kernels/dominance/ops.py":
            # the canonical table refers to BLOCK_* names imported from
            # kernel.py — resolve through the merged constant table
            table = ctx.contracts().contracts
            yield from self._check_declarations(ctx, table)
        else:
            local = ctx.local_contracts()
            if local is not None:
                yield from self._check_declarations(ctx, local)
                table = local
            else:
                table = ctx.contracts().contracts
        if table:
            yield from self._check_call_sites(ctx, table)

    # -- 1. declaration consistency ---------------------------------------
    def _check_declarations(self, ctx, table):
        anchor = _contract_assign(ctx.tree)
        if anchor is None:
            return
        for callee, spec in table.items():
            if not isinstance(spec, dict):
                continue
            blocks = spec.get("blocks", {})
            buckets = spec.get("buckets", {})
            for op in set(blocks) & set(buckets):
                blk, bkt = blocks[op], buckets[op]
                if isinstance(blk, int) and isinstance(bkt, int) \
                        and blk > 0 and bkt % blk != 0:
                    yield self.finding(
                        ctx, anchor,
                        f"contract '{callee}.{op}': bucket {bkt} is not "
                        f"a multiple of kernel block {blk} — bucketed "
                        "slabs would need a partial trailing block",
                        hint="make the *_BUCKET constant a multiple of "
                             "the kernel BLOCK_* it feeds")
            for op, mult in spec.get("packed_multiple", {}).items():
                bkt = buckets.get(op)
                if isinstance(bkt, int) and isinstance(mult, int) \
                        and mult > 0 and bkt % mult != 0:
                    yield self.finding(
                        ctx, anchor,
                        f"contract '{callee}.{op}': bucket {bkt} breaks "
                        f"the packed-axis multiple {mult} (bit packing "
                        "needs whole bytes/words per row)",
                        hint="pick a bucket divisible by the packing "
                             "width")

    # -- 2./3. call-site dtype + pad fill ----------------------------------
    def _check_call_sites(self, ctx, table):
        for qualname, func in iter_functions(ctx.tree):
            calls = [n for n in ast.walk(func)
                     if isinstance(n, ast.Call)
                     and terminal(n.func) in table]
            if not calls:
                continue
            env = FuncEnv(func)
            for call in calls:
                spec = table[terminal(call.func)]
                if not isinstance(spec, dict):
                    continue
                positions = spec.get("caller_bucketed", {})
                for op, want in spec.get("dtypes", {}).items():
                    if want != "uint32" or op not in positions:
                        continue
                    arg = call_arg(call, positions[op], op)
                    if arg is None:
                        continue
                    yield from self._check_uint32(ctx, env, call, arg,
                                                  op)
                for op, want in spec.get("pads", {}).items():
                    if op not in positions:
                        continue
                    arg = call_arg(call, positions[op], op)
                    if arg is None:
                        continue
                    yield from self._check_pad(ctx, env, arg, op, want,
                                               terminal(call.func))

    def _check_uint32(self, ctx, env, call, arg, op):
        verdict = self._uint32_verdict(env, arg)
        if verdict is False:
            yield self.finding(
                ctx, call,
                f"packed-bit operand '{op}' is not uint32 at the "
                "boundary — the in-kernel mask gather reads 32-bit "
                "words",
                hint="build the mask as bytes then "
                     ".view(np.uint32) (see pack_mask_bits)")

    def _uint32_verdict(self, env, expr, depth: int = 6):
        """Resolve the ARGUMENT expression (an inline ``.view(u32)``
        decides before any name-origin lookup, which would lose the
        reinterpreting view)."""
        if depth <= 0 or expr is None:
            return None
        if isinstance(expr, ast.Call):
            v = _is_uint32_origin(expr)
            if v is not None:
                return v
            t = terminal(expr.func)
            if t in ("asarray", "array") and expr.args:
                return self._uint32_verdict(env, expr.args[0], depth - 1)
            return None
        if isinstance(expr, ast.Name):
            return self._uint32_verdict(env, env.assigns.get(expr.id),
                                        depth - 1)
        return None

    def _check_pad(self, ctx, env, arg, op, want, callee):
        for name in sorted(names_in(arg)):
            origin = env.origin(ast.Name(id=name, ctx=ast.Load()))
            if not isinstance(origin, ast.Call):
                continue
            if terminal(origin.func) != "full":
                continue
            fill = call_arg(origin, 1, "fill_value")
            if fill is None:
                continue
            neg, pos = is_neg_inf(fill), is_pos_inf(fill)
            if not neg and not pos:
                continue
            if (want == "-inf" and pos) or (want == "+inf" and neg):
                yield self.finding(
                    ctx, origin,
                    f"operand '{op}' of '{callee}' is padded with "
                    f"{'+inf' if pos else '-inf'} but the kernel "
                    f"assumes {want} ("
                    + ("pad boxes must dominate nothing"
                       if want == "-inf"
                       else "pad queries must match nothing") + ")",
                    hint=f"pad '{op}' with {want} per KERNEL_CONTRACTS")
