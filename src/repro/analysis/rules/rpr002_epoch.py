"""RPR002: epoch-unsafe cache access.

The PR-5 exactness guarantee: a query result (or plan artifact) cached
before ``apply_updates`` must be unreachable afterwards.  That holds
because every result-cache / plan-LRU key is produced by
``_query_key``, which embeds ``_data_epoch``.  Any access keyed by
anything else reopens the stale-answer hole.

The rule scopes to the engine (``src/repro/dist/``) — tests and cache
benchmarks construct raw ValueCaches with synthetic keys on purpose.
A key expression is epoch-safe when it flows from:

* a call to ``_query_key(...)`` (directly or via an assignment chain),
* a parameter or dict slot literally named ``key`` (the engine's
  convention for passing a ``_query_key`` product down the call chain),
* an expression mentioning ``_data_epoch`` itself.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import (FuncEnv, call_arg, iter_functions,
                                    terminal)
from repro.analysis.registry import Rule, register

# terminal method name -> index of the key argument
KEYED_CALLS = {"access": 0, "peek": 0, "admit": 0, "get": 0, "put": 0,
               "store": 0, "_cache_lookup": 0, "_cache_peek": 0,
               "_plan_artifacts": 1}
# receivers that make those terminals a *result/plan* cache access
CACHE_RECEIVER_MARKERS = ("cache", "_plan_lru", "_slave_store")
KEYED_SUBSCRIPTS = ("_plan_lru",)


def _mentions_cache(receiver: ast.AST) -> bool:
    for node in ast.walk(receiver):
        name = getattr(node, "attr", None) or getattr(node, "id", None)
        if name and any(m in name for m in CACHE_RECEIVER_MARKERS):
            return True
    return False


class _KeyFlow:
    def __init__(self, env: FuncEnv):
        self.env = env

    def safe(self, expr: ast.AST, depth: int = 8) -> bool:
        if depth <= 0:
            return False
        if isinstance(expr, ast.Call):
            if terminal(expr.func) == "_query_key":
                return True
            return False
        if isinstance(expr, ast.Name):
            if expr.id == "key":
                # bound locally? follow it; a bare `key` parameter is
                # the engine's checked-at-caller convention
                bound = self.env.assigns.get(expr.id)
                if bound is None:
                    return expr.id in self.env.params
                return self.safe(bound, depth - 1)
            bound = self.env.assigns.get(expr.id)
            return bound is not None and self.safe(bound, depth - 1)
        if isinstance(expr, ast.Subscript):
            # it["key"] — dict slots named "key" carry _query_key
            # products across the dispatch/consume boundary
            sl = expr.slice
            return isinstance(sl, ast.Constant) and sl.value == "key"
        if isinstance(expr, ast.Attribute):
            return expr.attr in ("key", "_data_epoch")
        if isinstance(expr, ast.Tuple):
            return any(self.safe(e, depth - 1) for e in expr.elts)
        return False


@register
class EpochUnsafeCacheRule(Rule):
    id = "RPR002"
    name = "epoch-unsafe-cache-access"
    scope = ("src/repro/dist/*.py",)

    def check(self, ctx):
        for qualname, func in iter_functions(ctx.tree):
            if qualname.endswith("_query_key"):
                continue
            env = FuncEnv(func)
            flow = _KeyFlow(env)
            for node in ast.walk(func):
                if isinstance(node, ast.Call):
                    yield from self._check_call(ctx, flow, node)
                elif isinstance(node, ast.Subscript):
                    yield from self._check_subscript(ctx, flow, node)

    def _check_call(self, ctx, flow, call):
        t = terminal(call.func)
        if t not in KEYED_CALLS:
            return
        if isinstance(call.func, ast.Attribute):
            receiver = call.func.value
            if not _mentions_cache(receiver) \
                    and not t.startswith(("_cache", "_plan")):
                return
        elif not t.startswith(("_cache", "_plan")):
            return
        arg = call_arg(call, KEYED_CALLS[t], "key")
        if arg is None or flow.safe(arg):
            return
        yield self.finding(
            ctx, call,
            f"cache access '{t}' keyed by "
            f"'{ast.unparse(arg)}', which does not flow from "
            "_query_key/_data_epoch — a post-update query could be "
            "served a pre-update answer",
            hint="derive the key via self._query_key(query) (it embeds "
                 "_data_epoch) or thread an existing `key` through")

    def _check_subscript(self, ctx, flow, node):
        base = node.value
        name = getattr(base, "attr", None) or getattr(base, "id", None)
        if name not in KEYED_SUBSCRIPTS:
            return
        if isinstance(node.slice, ast.Slice) or flow.safe(node.slice):
            return
        yield self.finding(
            ctx, node,
            f"plan-LRU subscript keyed by '{ast.unparse(node.slice)}', "
            "which does not flow from _query_key/_data_epoch",
            hint="plan artifacts must be keyed by a _query_key product "
                 "so apply_updates invalidates them")
