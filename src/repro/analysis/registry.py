"""Rule registry: rules self-register at import via the decorator."""

from __future__ import annotations

import fnmatch
from typing import Callable, Iterable

from repro.analysis.finding import Finding

# rule id -> Rule instance; populated by @register at import time
RULES: dict[str, "Rule"] = {}


class Rule:
    """One invariant checker.

    ``check(ctx)`` yields Findings for a single parsed file; ``scope``
    is a tuple of repo-relative glob patterns the rule applies to
    (empty = every scanned file).  Path scoping lives here — not in the
    runner — because each invariant has a deliberate blast radius (e.g.
    RPR002 guards the engine, not test scaffolding that builds raw
    caches on purpose).
    """

    id: str = ""
    name: str = ""
    scope: tuple = ()

    def applies_to(self, rel_path: str) -> bool:
        if not self.scope:
            return True
        return any(fnmatch.fnmatch(rel_path, pat) for pat in self.scope)

    def check(self, ctx) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx, node, message: str, hint: str = "") -> Finding:
        return Finding(rule=self.id, path=ctx.rel, line=node.lineno,
                       col=getattr(node, "col_offset", 0),
                       message=message, hint=hint)


def register(cls: type) -> type:
    """Class decorator: instantiate and index the rule by id."""
    inst = cls()
    if not inst.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if inst.id in RULES:
        raise ValueError(f"duplicate rule id {inst.id}")
    RULES[inst.id] = inst
    return cls


def all_rules() -> list[Rule]:
    """Every registered rule, importing the built-in set on first use."""
    import repro.analysis.rules  # noqa: F401  (registers on import)
    return [RULES[k] for k in sorted(RULES)]


RuleFn = Callable[[object], Iterable[Finding]]
