"""reprolint — project-specific static analysis for the GNN-PE repo.

The repo's correctness story rests on conventions that ordinary tests
only catch when a hand-written case happens to exercise a violation:

* every operand entering a jitted Pallas launch is rounded to a named
  ``*_BUCKET`` constant (the PR-3 retrace bound),
* every result-cache / plan-LRU access is keyed through ``_query_key``
  and therefore ``_data_epoch`` (the PR-5 exactness guarantee),
* every shard/delta byte image crossing a machine boundary goes through
  ``crc_transfer`` / ``hot_migrate`` (index-consistency, paper inn. 1),
* wall-clock and ambient randomness never leak into PE-score labels or
  bit-identical-asserted counters (the PR-2 determinism sweep),
* nothing forces a host-device sync inside the pipelined megabatch
  dispatch region (the PR-4 overlap win),
* kernel call sites honor the declared BlockSpec/dtype/pad contracts
  (``repro.kernels.dominance.ops.KERNEL_CONTRACTS``).

reprolint walks the AST of every scanned file and enforces the whole
class of each invariant at CI time.  See docs/static-analysis.md for
the rule catalog (RPR001-RPR006), suppression syntax, and the baseline
mechanism.

CLI: ``python -m repro.analysis [--paths src tests benchmarks]
[--format text|json]`` — exit 0 iff no non-baselined findings.
"""

from repro.analysis.finding import Finding
from repro.analysis.registry import RULES, all_rules, register
from repro.analysis.runner import run_paths

__all__ = ["Finding", "RULES", "all_rules", "register", "run_paths"]
