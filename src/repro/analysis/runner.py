"""Collect files, run every applicable rule, fold in suppressions and
the baseline, and format the result."""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.analysis import baseline as baseline_mod
from repro.analysis.context import FileContext
from repro.analysis.finding import Finding
from repro.analysis.registry import all_rules

# never scanned: deliberate rule-violation fixtures and the offline
# hypothesis shim (vendored API surface, not project code)
EXCLUDE_PARTS = {"__pycache__", ".git", "analysis_fixtures"}
EXCLUDE_PREFIXES = ("src/hypothesis",)


@dataclasses.dataclass
class RunResult:
    findings: list          # non-baselined, non-suppressed (these fail)
    baselined: list         # matched a baseline entry
    suppressed: list        # silenced by an inline comment
    stale_baseline: list    # baseline entries that matched nothing
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.findings and not self.stale_baseline


def collect_files(paths: list[str], root: Path) -> list[Path]:
    out = []
    for p in paths:
        base = (root / p) if not Path(p).is_absolute() else Path(p)
        if base.is_file() and base.suffix == ".py":
            out.append(base)
            continue
        for f in sorted(base.rglob("*.py")):
            rel = f.resolve().relative_to(root.resolve()).as_posix()
            if set(f.parts) & EXCLUDE_PARTS:
                continue
            if rel.startswith(EXCLUDE_PREFIXES):
                continue
            out.append(f)
    return out


def run_paths(paths: list[str], root: Path | str | None = None,
              baseline_path: Path | None = None,
              rule_ids: set[str] | None = None,
              use_baseline: bool = True) -> RunResult:
    root = Path(root) if root else find_root()
    files = collect_files(paths, root)
    rules = [r for r in all_rules()
             if rule_ids is None or r.id in rule_ids]
    raw: list[Finding] = []
    suppressed: list[Finding] = []
    sources: dict[str, list[str]] = {}
    for path in files:
        ctx = FileContext.parse(path, root)
        if ctx is None:
            continue
        sources[ctx.rel] = ctx.source.splitlines()
        silenced = ctx.suppressed_lines()
        for rule in rules:
            if not rule.applies_to(ctx.rel):
                continue
            for f in rule.check(ctx):
                if f.rule in silenced.get(f.line, ()):
                    suppressed.append(f)
                else:
                    raw.append(f)
    raw.sort(key=lambda f: (f.path, f.line, f.rule))
    entries = baseline_mod.load(baseline_path) if use_baseline else []
    kept, baselined, stale = baseline_mod.apply(raw, entries, sources)
    return RunResult(findings=kept, baselined=baselined,
                     suppressed=suppressed, stale_baseline=stale,
                     n_files=len(files))


def find_root(start: Path | None = None) -> Path:
    """Nearest ancestor containing ROADMAP.md or .git (repo root)."""
    p = (start or Path(__file__)).resolve()
    for cand in [p] + list(p.parents):
        if (cand / "ROADMAP.md").exists() or (cand / ".git").exists():
            return cand
    return Path.cwd()


def format_text(result: RunResult, verbose: bool = False) -> str:
    lines = []
    for f in result.findings:
        lines.append(f.render())
    for e in result.stale_baseline:
        lines.append(f"{e['path']}: stale baseline entry for {e['rule']} "
                     f"(content no longer found: {e['content']!r}) — "
                     "remove it from analysis/baseline.json")
    status = "clean" if result.ok else f"{len(result.findings)} finding(s)"
    lines.append(f"reprolint: {result.n_files} files, {status}, "
                 f"{len(result.baselined)} baselined, "
                 f"{len(result.suppressed)} suppressed")
    if verbose and result.baselined:
        lines.append("baselined:")
        lines.extend(f"  {f.location()}: {f.rule}" for f in result.baselined)
    return "\n".join(lines)


def format_json(result: RunResult) -> str:
    return json.dumps({
        "ok": result.ok,
        "n_files": result.n_files,
        "findings": [f.to_json() for f in result.findings],
        "baselined": [f.to_json() for f in result.baselined],
        "suppressed": [f.to_json() for f in result.suppressed],
        "stale_baseline": result.stale_baseline,
    }, indent=2)
