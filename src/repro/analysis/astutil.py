"""Shared AST helpers for reprolint rules.

Everything here is a deliberate approximation: reprolint trades
soundness for a near-zero false-positive rate on THIS codebase (the
heuristics are documented per helper and in docs/static-analysis.md).
"""

from __future__ import annotations

import ast
import re
from typing import Iterator

BUCKET_CONST_RE = re.compile(r"^([A-Z][A-Z0-9_]*_BUCKET|BLOCK(_[A-Z0-9]+)+)$")

# shape-producing calls whose result's dims reprolint can inspect
ARRAY_CTORS = {"zeros", "full", "empty", "ones"}
# calls that forward their first argument's identity/shape unchanged
PASSTHROUGH_CALLS = {"asarray", "array", "ascontiguousarray", "view",
                     "astype", "copy", "ravel"}
# calls that certify a bucketed dim
BUCKETING_CALLS = {"bucket", "mega_query_bucket", "cdiv"}


def dotted(node: ast.AST) -> str | None:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def terminal(node: ast.AST) -> str | None:
    """Last component of a call target: 'self.planes.mega_dispatch' ->
    'mega_dispatch'; plain names return themselves."""
    if isinstance(node, ast.Call):
        node = node.func
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def iter_functions(tree: ast.AST) -> Iterator[tuple[str, ast.AST]]:
    """(qualname, node) for every (async) function, classes flattened."""

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from walk(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")

    yield from walk(tree, "")


def module_int_constants(tree: ast.AST) -> dict[str, int]:
    """Module-level ``NAME = <int expr>`` bindings, simple arithmetic
    folded (enough for bucket/block constants)."""
    consts: dict[str, int] = {}

    def fold(node):
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool):
            return node.value
        if isinstance(node, ast.Name):
            return consts.get(node.id)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = fold(node.operand)
            return None if v is None else -v
        if isinstance(node, ast.BinOp):
            a, b = fold(node.left), fold(node.right)
            if a is None or b is None:
                return None
            try:
                if isinstance(node.op, ast.Mult):
                    return a * b
                if isinstance(node.op, ast.Add):
                    return a + b
                if isinstance(node.op, ast.Sub):
                    return a - b
                if isinstance(node.op, ast.FloorDiv):
                    return a // b
                if isinstance(node.op, ast.Mod):
                    return a % b
            except ZeroDivisionError:
                return None
        return None

    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            v = fold(stmt.value)
            if v is not None:
                consts[stmt.targets[0].id] = v
    return consts


class FuncEnv:
    """Single-assignment view of one function body.

    Maps each locally assigned Name to its (last) value expression —
    last-write-wins is wrong under branching, but the scanned dispatch
    code is straight-line and the rules only use this to follow
    ``mask_bits = words.view(...)``-style definition chains.
    """

    def __init__(self, func: ast.AST):
        self.func = func
        self.assigns: dict[str, ast.AST] = {}
        self.loop_targets: set[str] = set()
        params = set()
        a = func.args
        for p in (a.posonlyargs + a.args + a.kwonlyargs):
            params.add(p.arg)
        if a.vararg:
            params.add(a.vararg.arg)
        if a.kwarg:
            params.add(a.kwarg.arg)
        self.params = params
        for node in ast.walk(func):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    self._bind(tgt, node.value)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                self._bind(node.target, node.value)
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                for n in ast.walk(node.target):
                    if isinstance(n, ast.Name):
                        self.loop_targets.add(n.id)
            elif isinstance(node, ast.comprehension):
                for n in ast.walk(node.target):
                    if isinstance(n, ast.Name):
                        self.loop_targets.add(n.id)

    def _bind(self, tgt: ast.AST, value: ast.AST) -> None:
        if isinstance(tgt, ast.Name):
            self.assigns[tgt.id] = value
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                if isinstance(el, ast.Name):
                    # tuple unpack: origin is the whole RHS (opaque)
                    self.assigns[el.id] = value

    # -- origin resolution ------------------------------------------------
    def origin(self, expr: ast.AST, depth: int = 8) -> ast.AST:
        """Follow Name bindings and pass-through calls to the defining
        expression: ``mask_bits -> words.view(u32) -> np.zeros(...)``."""
        seen = 0
        while seen < depth:
            seen += 1
            if isinstance(expr, ast.Name):
                nxt = self.assigns.get(expr.id)
                if nxt is None or nxt is expr:
                    return expr
                expr = nxt
                continue
            if isinstance(expr, ast.Call):
                t = terminal(expr.func)
                if t in PASSTHROUGH_CALLS:
                    base = (expr.func.value
                            if isinstance(expr.func, ast.Attribute)
                            else (expr.args[0] if expr.args else None))
                    # np.asarray(x) / x.view(...) both forward x
                    if t in {"asarray", "array", "ascontiguousarray"} \
                            and expr.args:
                        base = expr.args[0]
                    if base is not None:
                        expr = base
                        continue
                return expr
            return expr
        return expr

    # -- bucket-derived shape safety --------------------------------------
    def is_bucketed(self, expr: ast.AST, depth: int = 10) -> bool:
        """True iff a dim expression cannot vary per call except in
        bucket-sized steps.  Heuristics (see docs/static-analysis.md):

        * int literals, ``*_BUCKET`` / ``BLOCK_*`` names: safe
        * ``bucket(...)`` / ``mega_query_bucket(...)`` / ``pl.cdiv``: safe
        * arithmetic / max / min over safe operands: safe
        * attribute loads (``self.graph.n_vertices``, ``assembled.d_pad``)
          and ``X.shape[i]`` with an attribute base: safe — engine /
          assembly state is constant across queries, so it cannot drive
          per-call retraces
        * ``X.shape[i]`` with a Name base: safe iff X itself is safe
        * everything else (``len(...)``, ``sum(...)``, loop targets,
          parameters, stacked lists): unsafe
        """
        if depth <= 0:
            return False
        if isinstance(expr, ast.Constant):
            return isinstance(expr.value, int)
        if isinstance(expr, ast.Name):
            if BUCKET_CONST_RE.match(expr.id):
                return True
            if expr.id in self.loop_targets or expr.id in self.params:
                return False
            bound = self.assigns.get(expr.id)
            if bound is None:
                # unknown free name: module constant or import — only
                # trust the *_BUCKET naming convention (handled above)
                return False
            return self.is_bucketed(bound, depth - 1)
        if isinstance(expr, ast.UnaryOp):
            return self.is_bucketed(expr.operand, depth - 1)
        if isinstance(expr, ast.BinOp):
            return (self.is_bucketed(expr.left, depth - 1)
                    and self.is_bucketed(expr.right, depth - 1))
        if isinstance(expr, ast.Call):
            t = terminal(expr.func)
            if t in BUCKETING_CALLS:
                return True
            if t in {"max", "min", "int"}:
                return all(self.is_bucketed(a, depth - 1)
                           for a in expr.args)
            return False
        if isinstance(expr, ast.Subscript):
            # X.shape[i]
            base = expr.value
            if isinstance(base, ast.Attribute) and base.attr == "shape":
                owner = base.value
                if isinstance(owner, ast.Name):
                    # a parameter's .shape derives a dim from an operand
                    # that already exists — it cannot introduce NEW
                    # per-call shape variation (the caller's operand is
                    # checked at its own construction site)
                    if owner.id in self.params:
                        return True
                    return self.is_bucketed(owner, depth - 1)
                return isinstance(owner, (ast.Attribute, ast.Subscript))
            return False
        if isinstance(expr, ast.Attribute):
            return True
        if isinstance(expr, ast.IfExp):
            return (self.is_bucketed(expr.body, depth - 1)
                    and self.is_bucketed(expr.orelse, depth - 1))
        return False


def names_in(expr: ast.AST) -> set[str]:
    """All Name identifiers mentioned anywhere in an expression."""
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


def call_arg(call: ast.Call, index: int, name: str) -> ast.AST | None:
    """Argument by position or keyword; None when absent."""
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    if index < len(call.args):
        a = call.args[index]
        if isinstance(a, ast.Starred):
            return None
        return a
    return None


def shape_dims(ctor: ast.Call) -> list[ast.AST]:
    """Dim expressions of an array-constructor call's shape argument."""
    shape = call_arg(ctor, 0, "shape")
    if shape is None:
        return []
    if isinstance(shape, (ast.Tuple, ast.List)):
        return list(shape.elts)
    return [shape]


def is_neg_inf(expr: ast.AST) -> bool:
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub):
        return is_pos_inf(expr.operand)
    return False


def is_pos_inf(expr: ast.AST) -> bool:
    d = dotted(expr)
    if d is not None and d.split(".")[-1] == "inf":
        return True
    return isinstance(expr, ast.Constant) and expr.value == float("inf")
