"""gatedgcn [arXiv:2003.00982]: gated aggregator MPNN, 16L d_hidden=70."""

from __future__ import annotations

from repro.configs.common import GNN_SHAPES, ArchSpec
from repro.configs.families import build_gnn_cell
from repro.models.gnn_zoo import GNNConfigZoo


def make_config() -> GNNConfigZoo:
    return GNNConfigZoo(arch="gatedgcn", n_layers=16, d_hidden=70, d_in=16)


def make_smoke_config() -> GNNConfigZoo:
    return GNNConfigZoo(arch="gatedgcn", n_layers=3, d_hidden=16, d_in=8)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="gatedgcn", family="gnn", shapes=GNN_SHAPES,
                    skip_shapes={}, make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    build_cell=build_gnn_cell)
