"""deepseek-v3-671b [arXiv:2412.19437]: MLA + 1 shared + 256 routed top-8 + MTP.

61L d_model=7168 128H d_ff_expert=2048 vocab=129280; MLA q_lora=1536,
kv_lora=512, rope 64 / nope 128 / v 128; first 3 layers dense (d_ff=18432);
sigmoid router, aux-loss-free bias, routed scaling 2.5.

Deviation noted in DESIGN.md: group-limited routing (n_group=8) is not
implemented — plain top-8 over the 256 experts.  Params sharded
EP('model') x FSDP('data'); optimizer = 8-bit blockwise Adam.
Full attention (MLA compresses KV *width*, not length) — long_500k skipped.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import LM_SHAPES, ArchSpec
from repro.configs.families import build_lm_cell
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, MLAConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, head_dim=128, d_ff=18432, vocab=129280,
        rope_theta=10000.0,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                      router="sigmoid", router_scale=2.5, first_dense=3,
                      fsdp_experts=True),
        mtp=True)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=160, vocab=256, dtype=jnp.float32,
        remat=False,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      router="sigmoid", first_dense=1, capacity_factor=4.0),
        mtp=True)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="deepseek-v3-671b", family="lm", shapes=LM_SHAPES,
        skip_shapes={"long_500k": "full attention (MLA compresses width, "
                                  "not length) — skipped per DESIGN.md"},
        make_config=make_config, make_smoke_config=make_smoke_config,
        build_cell=build_lm_cell)
