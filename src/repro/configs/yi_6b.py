"""yi-6b [arXiv:2403.04652; hf]: llama-arch GQA.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, head_dim=128.
Full attention — long_500k is skipped (sub-quadratic required; see
DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import LM_SHAPES, ArchSpec
from repro.configs.families import build_lm_cell
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="yi-6b", n_layers=32, d_model=4096, n_heads=32,
                    n_kv_heads=4, head_dim=128, d_ff=11008, vocab=64000,
                    rope_theta=5_000_000.0)


def make_smoke_config() -> LMConfig:
    return LMConfig(name="yi-6b-smoke", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_ff=160, vocab=256,
                    dtype=jnp.float32, remat=False)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="yi-6b", family="lm", shapes=LM_SHAPES,
        skip_shapes={"long_500k": "full attention (no sub-quadratic path); "
                                  "524k decode KV would be quadratic-cost "
                                  "prefill-side — skipped per DESIGN.md"},
        make_config=make_config, make_smoke_config=make_smoke_config,
        build_cell=build_lm_cell)
