"""Architecture registry: ``--arch <id>`` -> ArchSpec.

One module per assigned architecture (public-literature configs, exact
numbers from the assignment table) + ``gnnpe`` for the paper's own system.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "yi-6b", "h2o-danube-1.8b", "glm4-9b", "qwen2-moe-a2.7b",
    "deepseek-v3-671b",
    "egnn", "gatedgcn", "nequip", "meshgraphnet",
    "bert4rec",
]

_MODULES = {
    "yi-6b": "yi_6b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "glm4-9b": "glm4_9b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "egnn": "egnn",
    "gatedgcn": "gatedgcn",
    "nequip": "nequip",
    "meshgraphnet": "meshgraphnet",
    "bert4rec": "bert4rec",
    "gnnpe": "gnnpe",
}


def get_spec(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.spec()
