"""nequip [arXiv:2101.03164]: O(3)-equivariant interatomic potentials.

5L d_hidden=32 l_max=2 n_rbf=8 cutoff=5, E(3) tensor products (real SH +
Gaunt coupling).  Non-molecular shapes get stub 3-D positions from
input_specs (the modality frontend rule).
"""

from __future__ import annotations

from repro.configs.common import GNN_SHAPES, ArchSpec
from repro.configs.families import build_gnn_cell
from repro.models.gnn_zoo import GNNConfigZoo


def make_config() -> GNNConfigZoo:
    return GNNConfigZoo(arch="nequip", n_layers=5, d_hidden=32, d_in=16,
                        l_max=2, n_rbf=8, cutoff=5.0)


def make_smoke_config() -> GNNConfigZoo:
    return GNNConfigZoo(arch="nequip", n_layers=2, d_hidden=8, d_in=8,
                        l_max=2, n_rbf=4, cutoff=5.0)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="nequip", family="gnn", shapes=GNN_SHAPES,
                    skip_shapes={}, make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    build_cell=build_gnn_cell)
