"""glm4-9b [hf:THUDM/glm-4-9b]: RoPE, GQA kv=2.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552, head_dim=128.
Full attention — long_500k skipped.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import LM_SHAPES, ArchSpec
from repro.configs.families import build_lm_cell
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="glm4-9b", n_layers=40, d_model=4096, n_heads=32,
                    n_kv_heads=2, head_dim=128, d_ff=13696, vocab=151552,
                    rope_theta=10000.0)


def make_smoke_config() -> LMConfig:
    return LMConfig(name="glm4-smoke", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_ff=160, vocab=256,
                    dtype=jnp.float32, remat=False)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="glm4-9b", family="lm", shapes=LM_SHAPES,
        skip_shapes={"long_500k": "full attention — skipped per DESIGN.md"},
        make_config=make_config, make_smoke_config=make_smoke_config,
        build_cell=build_lm_cell)
