"""bert4rec [arXiv:1904.06690]: bidirectional sequential recommender.

embed_dim=64 n_blocks=2 n_heads=2 seq_len=200; item table 10^6 rows
(matching the retrieval_cand candidate count) row-sharded over 'model'.
Encoder-only: no decode shapes exist in this family by construction.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import RECSYS_SHAPES, ArchSpec
from repro.configs.families import build_recsys_cell
from repro.models.bert4rec import Bert4RecConfig


def make_config() -> Bert4RecConfig:
    return Bert4RecConfig(n_items=1_000_000, embed_dim=64, n_blocks=2,
                          n_heads=2, seq_len=200, d_ff=256)


def make_smoke_config() -> Bert4RecConfig:
    return Bert4RecConfig(n_items=512, embed_dim=32, n_blocks=2, n_heads=2,
                          seq_len=16, d_ff=64, dtype=jnp.float32)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="bert4rec", family="recsys",
                    shapes=RECSYS_SHAPES, skip_shapes={},
                    make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    build_cell=build_recsys_cell)
