"""gnnpe: the paper's own system as a selectable config.

Wraps the distributed GNN-PE engine (cluster build + workload) the same way
the arch zoo wraps its models.  The 'cell' lowered for the dry-run is the
batched dominance-embedding encoder + index probe — the device-side hot path
of the engine (host-side orchestration stays on CPU by design).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.common import ArchSpec, CellSpec, ShapeDef, sds
from repro.core.gnn import GNNConfig

GNNPE_SHAPES = {
    "embed_1m": ShapeDef("embed_1m", "train",
                         {"n_vertices": 1_000_000, "n_edges": 6_000_000,
                          "n_paths": 4_000_000, "path_len": 2,
                          "n_labels": 32}),
    "probe_64k": ShapeDef("probe_64k", "serve",
                          {"n_boxes": 65_536, "dim": 12,
                           "n_queries": 1024}),
}


def make_config() -> GNNConfig:
    return GNNConfig(n_labels=32, d_embed=2, d_label=2, n_hops=2)


def make_smoke_config() -> GNNConfig:
    return GNNConfig(n_labels=8, d_embed=2, d_label=2, n_hops=2)


def build_cell(cfg: GNNConfig, shape: ShapeDef, dp: tuple) -> CellSpec:
    from repro.core import gnn as gnn_lib

    if shape.shape_id == "probe_64k":
        n, d, q = (shape.dims[k] for k in ("n_boxes", "dim", "n_queries"))

        def probe(uppers, queries):
            # batched dominance filter (the aR-tree leaf test)
            return jnp.all(queries[:, None, :] <= uppers[None, :, :] + 1e-5,
                           axis=-1)

        args = (sds((n, d), jnp.float32), sds((q, d), jnp.float32))
        return CellSpec(probe, args, (P(dp, None), P()), P(None, dp),
                        description=f"dominance probe q={q} n={n}")

    nv = shape.dims["n_vertices"]
    ne = 2 * shape.dims["n_edges"]
    npth = shape.dims["n_paths"]
    lp1 = shape.dims["path_len"] + 1
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: gnn_lib.init_params(cfg, k), key)
    pspecs = jax.tree.map(lambda _: P(), params_shape)

    def embed(params, labels, degrees, src, dst, paths):
        return gnn_lib.encode_paths(params, cfg, labels, degrees, src, dst,
                                    paths)

    args = (params_shape, sds((nv,), jnp.int32), sds((nv,), jnp.int32),
            sds((ne,), jnp.int32), sds((ne,), jnp.int32),
            sds((npth, lp1), jnp.int32))
    in_sh = (pspecs, P(), P(), P(dp), P(dp), P(dp, None))
    return CellSpec(embed, args, in_sh, P(dp, None),
                    static_argnums=(),
                    description=f"embed paths n={npth}")


def spec() -> ArchSpec:
    return ArchSpec(arch_id="gnnpe", family="engine", shapes=GNNPE_SHAPES,
                    skip_shapes={}, make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    build_cell=build_cell)
