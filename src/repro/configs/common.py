"""Shared config machinery: ArchSpec / CellSpec, shape sets, sharding specs.

A *cell* is one (architecture x input shape); `build_cell` returns the jit
target (step_fn), its abstract inputs (ShapeDtypeStructs — never allocated),
and in/out shardings for the production mesh.  The same CellSpec backs the
multi-pod dry-run, the roofline analysis, and the smoke tests (which call
the cells with tiny real arrays instead).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["ShapeDef", "ArchSpec", "CellSpec", "LM_SHAPES", "GNN_SHAPES",
           "RECSYS_SHAPES", "lm_param_specs", "tree_replicated", "sds"]


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    """One input-shape cell."""

    shape_id: str
    kind: str                 # train | prefill | decode | serve
    dims: dict[str, int]


LM_SHAPES = {
    "train_4k": ShapeDef("train_4k", "train",
                         {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeDef("prefill_32k", "prefill",
                            {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeDef("decode_32k", "decode",
                           {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeDef("long_500k", "decode",
                          {"seq_len": 524288, "global_batch": 1}),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeDef("full_graph_sm", "train",
                              {"n_nodes": 2708, "n_edges": 10556,
                               "d_feat": 1433, "d_out": 7}),
    "minibatch_lg": ShapeDef("minibatch_lg", "train",
                             {"n_nodes": 169_984, "n_edges": 337_920,
                              "d_feat": 602, "d_out": 41}),
    "ogb_products": ShapeDef("ogb_products", "train",
                             {"n_nodes": 2_449_029, "n_edges": 61_859_140,
                              "d_feat": 100, "d_out": 47}),
    "molecule": ShapeDef("molecule", "train",
                         {"n_nodes": 30, "n_edges": 64, "batch": 128,
                          "d_feat": 16, "d_out": 1}),
}

RECSYS_SHAPES = {
    "train_batch": ShapeDef("train_batch", "train", {"batch": 65_536}),
    "serve_p99": ShapeDef("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeDef("serve_bulk", "serve", {"batch": 262_144}),
    "retrieval_cand": ShapeDef("retrieval_cand", "serve",
                               {"batch": 1, "n_candidates": 1_000_000}),
}


@dataclasses.dataclass
class CellSpec:
    """Everything the dry-run needs for one (arch x shape x mesh) compile."""

    step_fn: Callable
    abstract_args: tuple          # ShapeDtypeStructs, positional
    in_shardings: Any             # pytree of PartitionSpec (or None)
    out_shardings: Any
    donate_argnums: tuple = ()
    static_argnums: tuple = ()
    description: str = ""


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    family: str                                  # lm | gnn | recsys | engine
    shapes: dict[str, ShapeDef]
    skip_shapes: dict[str, str]                  # shape_id -> reason
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    build_cell: Callable[[Any, ShapeDef, tuple], CellSpec]
    # build_cell(config, shape, dp_axes) — dp_axes = ('data',) or
    # ('pod','data') depending on the mesh.


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def tree_replicated(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


# --------------------------------------------------------------------------- #
# LM parameter sharding (Megatron col/row split + optional FSDP)
# --------------------------------------------------------------------------- #
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w_uq", "w_uk", "w_uv",
        "shared_gate", "shared_up", "w1"}
_ROW = {"wo", "w_down", "shared_down", "w2"}


def _leaf_spec(path: tuple, leaf, cfg, fsdp: bool, dp: tuple) -> P:
    """PartitionSpec for one LM param leaf, keyed by its name + rank.

    Stacked layer params carry a leading n_layers dim (from the scan
    vmap-init), detected by rank vs the name's base rank.
    """
    name = None
    stacked = False
    for k in reversed(path):
        if isinstance(k, jax.tree_util.DictKey):
            name = str(k.key)
            break
    for k in path:
        if isinstance(k, jax.tree_util.DictKey) and \
                str(k.key) in ("dense_stack", "moe_stack"):
            stacked = True
    rank = len(leaf.shape)
    base = rank - 1 if stacked else rank
    lead = (None,) if stacked else ()

    if name in ("embed",):
        return P(*lead, "model", dp[-1] if fsdp else None)
    if name in ("w_out",):
        return P(*lead, dp[-1] if fsdp else None, "model")
    if name in ("router", "router_bias", "w_dq", "w_dkv", "w_kpe",
                "q_norm", "kv_norm", "ln1", "ln2", "final_norm", "pos",
                "mtp_norm", "mtp_proj", "b", "b1", "b2", "b3"):
        return P(*lead, *([None] * base))
    if name in _COL:
        if base == 3:                     # MoE expert stack [E, d, f]
            return P(*lead, "model", dp[-1] if fsdp else None, None)
        return P(*lead, dp[-1] if fsdp else None, "model")
    if name in _ROW:
        if base == 3:                     # [E, f, d]
            return P(*lead, "model", dp[-1] if fsdp else None, None)
        return P(*lead, "model", dp[-1] if fsdp else None)
    return P(*lead, *([None] * base))


def lm_param_specs(params_shape: Any, cfg: Any, fsdp: bool,
                   dp: tuple) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, cfg, fsdp, dp), params_shape)


def opt_state_specs(opt_shape: Any, param_specs: Any) -> Any:
    """Adam mu/nu mirror the param specs; 8-bit flat codes shard over data.

    Works because AdamState / Adam8bitState are NamedTuples whose first
    fields mirror the param tree structure.
    """
    from repro.train.optimizer import Adam8bitState, AdamState

    if isinstance(opt_shape, AdamState):
        return AdamState(mu=param_specs, nu=param_specs, step=P())
    if isinstance(opt_shape, Adam8bitState):
        # codes are flat multiples of 256 -> always divisible by 'data';
        # scales (1/256 the size) may be tiny/odd -> replicated.
        flat = jax.tree.map(lambda _: P("data"), opt_shape.mu_codes)
        flat_s = jax.tree.map(lambda _: P(), opt_shape.mu_scales)
        return Adam8bitState(mu_codes=flat, mu_scales=flat_s,
                             nu_codes=flat, nu_scales=flat_s, step=P())
    return jax.tree.map(lambda _: P(), opt_shape)
