"""Family-level cell builders: LM / GNN / RecSys -> CellSpec.

Each builder returns the jit target for one (arch x shape): a full train
step (fwd + bwd + clip + optimizer), a prefill, or a one-token decode step,
together with abstract inputs and PartitionSpecs for the production mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.common import (CellSpec, ShapeDef, lm_param_specs,
                                  opt_state_specs, sds)
from repro.train.optimizer import (adam8bit_init, adam8bit_update, adamw_init,
                                   adamw_update, clip_by_global_norm)

__all__ = ["build_lm_cell", "build_gnn_cell", "build_recsys_cell"]


# --------------------------------------------------------------------------- #
# LM family
# --------------------------------------------------------------------------- #
def _lm_optimizer(cfg):
    """deepseek-scale models use 8-bit blockwise optimizer states."""
    if cfg.param_count() > 50e9:
        return adam8bit_init, functools.partial(adam8bit_update, lr=3e-4,
                                                weight_decay=0.1)
    return adamw_init, functools.partial(adamw_update, lr=3e-4)


def make_lm_train_step(cfg):
    from repro.models.transformer import lm_loss
    _, opt_update = _lm_optimizer(cfg)

    def train_step(params, opt, tokens, labels):
        loss, grads = jax.value_and_grad(lm_loss)(params, cfg, tokens, labels)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = opt_update(params, grads, opt)
        return params, opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def build_lm_cell(cfg, shape: ShapeDef, dp: tuple) -> CellSpec:
    from repro.models import transformer as T

    b = shape.dims["global_batch"]
    s = shape.dims["seq_len"]
    fsdp = cfg.param_count() * 2 > 200e9     # bf16 bytes vs ~0.2TB threshold
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: T.init_params(cfg, k), key)
    pspecs = lm_param_specs(params_shape, cfg, fsdp, dp)

    if shape.kind == "train":
        opt_init, _ = _lm_optimizer(cfg)
        opt_shape = jax.eval_shape(opt_init, params_shape)
        ospecs = opt_state_specs(opt_shape, pspecs)
        step = make_lm_train_step(cfg)
        args = (params_shape, opt_shape,
                sds((b, s), jnp.int32), sds((b, s), jnp.int32))
        in_sh = (pspecs, ospecs, P(dp, None), P(dp, None))
        out_sh = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
        return CellSpec(step, args, in_sh, out_sh, donate_argnums=(0, 1),
                        description=f"train_step b={b} s={s}")

    if shape.kind == "prefill":
        def prefill_last(params, tokens):
            logits = T.prefill(params, cfg, tokens)
            return logits[:, -1, :]
        args = (params_shape, sds((b, s), jnp.int32))
        return CellSpec(prefill_last, args, (pspecs, P(dp, None)),
                        P(dp, "model"),
                        description=f"prefill b={b} s={s}")

    # decode: one new token against a seq_len-deep KV cache
    t_max = s if cfg.sliding_window is None else min(s, cfg.sliding_window)
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, b, t_max))
    # batch=1 (long_500k) cannot shard over dp — replicate batch, shard KV
    bdp = dp if b >= 32 else None
    if cfg.mla is None:
        cspecs = {"k": P(None, bdp, "model", None, None),
                  "v": P(None, bdp, "model", None, None),
                  "slot_pos": P()}
    else:
        cspecs = {"ckv": P(None, bdp, "model", None),
                  "kpe": P(None, bdp, "model", None),
                  "slot_pos": P()}

    def serve_step(params, cache, tokens, pos):
        return T.decode_step(params, cfg, cache, tokens, pos)

    args = (params_shape, cache_shape, sds((b, 1), jnp.int32),
            sds((), jnp.int32))
    in_sh = (pspecs, cspecs, P(bdp, None), P())
    out_sh = (P(bdp, None, "model"), cspecs)
    return CellSpec(serve_step, args, in_sh, out_sh, donate_argnums=(1,),
                    description=f"decode b={b} kv={t_max} (pos={s - 1})")


# --------------------------------------------------------------------------- #
# GNN family
# --------------------------------------------------------------------------- #
def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _gnn_dims(shape: ShapeDef) -> tuple[int, int]:
    n = shape.dims["n_nodes"] * shape.dims.get("batch", 1)
    e = shape.dims["n_edges"] * shape.dims.get("batch", 1)
    # symmetric message passing (both directions) + pad-to-shard: node and
    # edge counts round up to a multiple of 8192 so row sharding divides the
    # production meshes (16 and 2x16); pad rows are dead via the masks.
    return _round_up(n, 8192), _round_up(2 * e, 8192)


def build_gnn_cell(cfg, shape: ShapeDef, dp: tuple) -> CellSpec:
    from repro.models.gnn_zoo import GNNBatch, gnn_loss, init_gnn

    n, e = _gnn_dims(shape)
    d_in = shape.dims["d_feat"]
    d_out = shape.dims["d_out"]
    import dataclasses as dc
    cfg = dc.replace(cfg, d_in=d_in, d_out=d_out)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: init_gnn(cfg, k), key)
    pspecs = jax.tree.map(lambda _: P(), params_shape)
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    ospecs = opt_state_specs(opt_shape, pspecs)

    def train_step(params, opt, nodes, positions, src, dst, nmask, emask,
                   targets):
        batch = GNNBatch(nodes=nodes, positions=positions, edge_src=src,
                         edge_dst=dst,
                         edge_feats=jnp.zeros((src.shape[0], 0), nodes.dtype),
                         node_mask=nmask, edge_mask=emask,
                         graph_ids=jnp.zeros(nodes.shape[0], jnp.int32))
        loss, grads = jax.value_and_grad(gnn_loss)(params, cfg, batch,
                                                   targets)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(params, grads, opt, lr=1e-3)
        return params, opt, {"loss": loss, "grad_norm": gnorm}

    f32 = jnp.float32
    args = (params_shape, opt_shape, sds((n, d_in), f32), sds((n, 3), f32),
            sds((e,), jnp.int32), sds((e,), jnp.int32), sds((n,), jnp.bool_),
            sds((e,), jnp.bool_), sds((n, d_out), f32))
    # node/target ROWS shard over dp (feature dims are odd published sizes);
    # edges shard over dp; gathers/scatters across rows become halo
    # collectives under GSPMD.
    in_sh = (pspecs, ospecs, P(dp, None), P(dp, None), P(dp), P(dp), P(dp),
             P(dp), P(dp, None))
    out_sh = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
    return CellSpec(train_step, args, in_sh, out_sh, donate_argnums=(0, 1),
                    description=f"gnn train n={n} e={e}")


# --------------------------------------------------------------------------- #
# RecSys family (BERT4Rec)
# --------------------------------------------------------------------------- #
N_MASKED = 20          # cloze positions per sequence
N_NEG = 8192           # shared sampled-softmax negatives
TOPK_BULK = 100


def build_recsys_cell(cfg, shape: ShapeDef, dp: tuple) -> CellSpec:
    from repro.models import bert4rec as B

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: B.init_bert4rec(cfg, k), key)

    def pspec_leaf(path, leaf):
        name = None
        for k in reversed(path):
            if isinstance(k, jax.tree_util.DictKey):
                name = str(k.key)
                break
        if name == "items":
            return P("model", None)
        return P(*([None] * len(leaf.shape)))

    pspecs = jax.tree_util.tree_map_with_path(pspec_leaf, params_shape)
    b = shape.dims["batch"]
    s = cfg.seq_len

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        ospecs = opt_state_specs(opt_shape, pspecs)

        def train_step(params, opt, items, mask_pos, labels, negatives):
            def loss_fn(p):
                return B.sampled_cloze_loss(p, cfg, items, mask_pos, labels,
                                            negatives)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt = adamw_update(params, grads, opt, lr=1e-3)
            return params, opt, {"loss": loss, "grad_norm": gnorm}

        args = (params_shape, opt_shape, sds((b, s), jnp.int32),
                sds((b, N_MASKED), jnp.int32), sds((b, N_MASKED), jnp.int32),
                sds((N_NEG,), jnp.int32))
        in_sh = (pspecs, ospecs, P(dp, None), P(dp, None), P(dp, None), P())
        out_sh = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
        return CellSpec(train_step, args, in_sh, out_sh,
                        donate_argnums=(0, 1),
                        description=f"cloze train b={b} s={s}")

    if shape.shape_id == "retrieval_cand":
        c = shape.dims["n_candidates"]

        def retrieve(params, items, candidates):
            return B.retrieval_scores(params, cfg, items, candidates)

        # batch=1: replicate the user sequence; candidates shard over model
        args = (params_shape, sds((b, s), jnp.int32), sds((c,), jnp.int32))
        return CellSpec(retrieve, args, (pspecs, P(), P("model")),
                        P(None, "model"),
                        description=f"retrieval b={b} cands={c}")

    if shape.shape_id == "serve_bulk":
        def bulk(params, items):
            return B.bulk_topk_scores(params, cfg, items, k=TOPK_BULK)
        args = (params_shape, sds((b, s), jnp.int32))
        return CellSpec(bulk, args, (pspecs, P(dp, None)),
                        (P(dp, None), P(dp, None)),
                        description=f"bulk top-{TOPK_BULK} b={b}")

    def serve(params, items):
        return B.serve_scores(params, cfg, items)

    args = (params_shape, sds((b, s), jnp.int32))
    return CellSpec(serve, args, (pspecs, P(dp, None)), P(dp, "model"),
                    description=f"serve b={b}")
