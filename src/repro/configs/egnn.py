"""egnn [arXiv:2102.09844]: E(n)-equivariant GNN, 4L d_hidden=64."""

from __future__ import annotations

from repro.configs.common import GNN_SHAPES, ArchSpec
from repro.configs.families import build_gnn_cell
from repro.models.gnn_zoo import GNNConfigZoo


def make_config() -> GNNConfigZoo:
    return GNNConfigZoo(arch="egnn", n_layers=4, d_hidden=64, d_in=16)


def make_smoke_config() -> GNNConfigZoo:
    return GNNConfigZoo(arch="egnn", n_layers=2, d_hidden=16, d_in=8)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="egnn", family="gnn", shapes=GNN_SHAPES,
                    skip_shapes={}, make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    build_cell=build_gnn_cell)
