"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 4 shared + 60 routed top-4.

24L d_model=2048 16H (kv=16) d_ff_expert=1408 vocab=151936, MoE 60e top-4.
60 experts are padded to 64 for clean EP over the 16-way model axis
(DESIGN.md §6); the 4 pad experts are dead (router columns exist but
receive no load-balancing pressure and can be pruned at export).
Full attention — long_500k skipped.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import LM_SHAPES, ArchSpec
from repro.configs.families import build_lm_cell
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=5632, vocab=151936,
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=64, top_k=4, d_ff_expert=1408, n_shared=4,
                      router="softmax"))


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=160, vocab=256, dtype=jnp.float32,
        remat=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      capacity_factor=4.0))


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen2-moe-a2.7b", family="lm", shapes=LM_SHAPES,
        skip_shapes={"long_500k": "full attention — skipped per DESIGN.md"},
        make_config=make_config, make_smoke_config=make_smoke_config,
        build_cell=build_lm_cell)
