"""h2o-danube-1.8b [arXiv:2401.16818; hf]: llama+mistral mix with SWA.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, head_dim=80,
sliding window 4096 — the one LM arch that RUNS long_500k (KV bounded by
the window).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.common import LM_SHAPES, ArchSpec
from repro.configs.families import build_lm_cell
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="h2o-danube-1.8b", n_layers=24, d_model=2560,
                    n_heads=32, n_kv_heads=8, head_dim=80, d_ff=6912,
                    vocab=32000, rope_theta=10000.0, sliding_window=4096)


def make_smoke_config() -> LMConfig:
    return LMConfig(name="danube-smoke", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_ff=160, vocab=256,
                    sliding_window=8, dtype=jnp.float32, remat=False)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="h2o-danube-1.8b", family="lm", shapes=LM_SHAPES,
        skip_shapes={},
        make_config=make_config, make_smoke_config=make_smoke_config,
        build_cell=build_lm_cell)
