"""meshgraphnet [arXiv:2010.03409]: 15L d_hidden=128 sum aggregator."""

from __future__ import annotations

from repro.configs.common import GNN_SHAPES, ArchSpec
from repro.configs.families import build_gnn_cell
from repro.models.gnn_zoo import GNNConfigZoo


def make_config() -> GNNConfigZoo:
    return GNNConfigZoo(arch="meshgraphnet", n_layers=15, d_hidden=128,
                        d_in=16, mlp_layers=2)


def make_smoke_config() -> GNNConfigZoo:
    return GNNConfigZoo(arch="meshgraphnet", n_layers=3, d_hidden=16, d_in=8,
                        mlp_layers=2)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="meshgraphnet", family="gnn", shapes=GNN_SHAPES,
                    skip_shapes={}, make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    build_cell=build_gnn_cell)
