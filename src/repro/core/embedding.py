"""Training of dominance embeddings + embedded path tables.

The certified-monotone GNN (repro/core/gnn.py) guarantees that every TRUE
match satisfies o(p_q) <= o(p_z).  Training therefore has a single job:
**maximize pruning power** — make non-matching (negative) pairs violate
dominance in at least one dimension, by as wide a margin as possible.

Negative pairs are mined from the shard itself: pairs of same-length paths
whose label sequences differ, or whose label sequences agree but whose local
structures differ (different degrees / neighbor label multisets).

The trainer is plain JAX (Adam implemented in repro/train/optimizer.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gnn as gnn_lib
from repro.core.graph import LabeledGraph
from repro.core.paths import PathTable, enumerate_paths

__all__ = ["EmbeddedPaths", "embed_shard_paths", "train_dominance_gnn",
           "dominates", "mine_negative_pairs", "splice_embedding_rows"]


@dataclasses.dataclass(frozen=True)
class EmbeddedPaths:
    """Embedded path table of a single length within one shard.

    Attributes:
      vertices:   int32 [P, l+1] path vertex ids (shard-local).
      embeddings: float32 [P, D] dominance embeddings, D=(l+1)*(d_e+d_l).
      length:     path length l (edges).
    """

    vertices: np.ndarray
    embeddings: np.ndarray
    length: int

    @property
    def n_paths(self) -> int:
        return int(self.vertices.shape[0])


def dominates(q: jnp.ndarray, z: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Element-wise dominance test q <= z (+eps slack), batched over z.

    q: [D], z: [N, D]  ->  bool [N].  eps absorbs float roundoff so true
    matches (which satisfy <= exactly in exact arithmetic) are never lost.
    """
    return jnp.all(q[None, :] <= z + eps, axis=-1)


def splice_embedding_rows(new_keys: list[bytes], clean_row: np.ndarray,
                          old_keys: list[bytes],
                          old_embeddings: np.ndarray, d: int,
                          fresh_fn) -> tuple[np.ndarray, int]:
    """Assemble a path-embedding matrix reusing clean rows from the
    previous index epoch.

    ``new_keys[i]`` identifies row i of the fresh canonical enumeration
    (global-id byte keys from `paths.path_row_keys`); a row is REUSED
    when ``clean_row[i]`` (no dirty vertex on the path) and the same key
    existed in the old table — its old embedding row is bit-identical
    to a recomputation because every input (the vertex embeddings of
    its clean vertices, their labels, and the per-row interleave) is
    unchanged.  All other rows are recomputed via ``fresh_fn(idx) ->
    float32 [len(idx), d]``.  Returns (embeddings [P, d], n_reused).

    This is the update path's entire embedding cost model: re-embed
    ONLY paths through dirty vertices (plus genuinely new paths), never
    the whole shard.
    """
    p = len(new_keys)
    emb = np.empty((p, d), np.float32)
    old_of = {k: i for i, k in enumerate(old_keys)}
    fresh_idx = []
    n_reused = 0
    for i, key in enumerate(new_keys):
        j = old_of.get(key) if clean_row[i] else None
        if j is None:
            fresh_idx.append(i)
        else:
            emb[i] = old_embeddings[j]
            n_reused += 1
    if fresh_idx:
        idx = np.asarray(fresh_idx, np.int64)
        emb[idx] = fresh_fn(idx)
    return emb, n_reused


# --------------------------------------------------------------------------- #
# negative-pair mining
# --------------------------------------------------------------------------- #
def mine_negative_pairs(graph: LabeledGraph, table: PathTable,
                        n_pairs: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Sample (a, b) index pairs where path a is NOT a position-wise match of b.

    A pair is negative if the label sequences differ in some position in both
    orientations, or labels agree but a has a strictly larger degree
    somewhere (then a cannot embed into b at that position).
    """
    rng = np.random.default_rng(seed)
    p = table.n_paths
    if p < 2:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    a = rng.integers(0, p, size=3 * n_pairs)
    b = rng.integers(0, p, size=3 * n_pairs)
    la = graph.labels[table.vertices[a]]
    lb = graph.labels[table.vertices[b]]
    deg = np.diff(graph.indptr).astype(np.int64)
    da = deg[table.vertices[a]]
    db = deg[table.vertices[b]]
    lab_mismatch = (la != lb).any(axis=1) & (la != lb[:, ::-1]).any(axis=1)
    deg_excess = (da > db).any(axis=1) & (da > db[:, ::-1]).any(axis=1)
    neg = lab_mismatch | deg_excess
    a, b = a[neg][:n_pairs], b[neg][:n_pairs]
    return a.astype(np.int64), b.astype(np.int64)


# --------------------------------------------------------------------------- #
# training
# --------------------------------------------------------------------------- #
def _pruning_loss(params: dict[str, Any], cfg: gnn_lib.GNNConfig,
                  labels: jnp.ndarray, degrees: jnp.ndarray,
                  edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                  paths: jnp.ndarray, neg_a: jnp.ndarray, neg_b: jnp.ndarray,
                  margin: float = 0.1) -> jnp.ndarray:
    """Hinge loss: negative pair (a,b) should violate dominance a<=b.

    violation amount = max_j (o_a[j] - o_b[j]); want it >= margin.
    Also a small weight-decay-like tightness term keeps embeddings bounded.
    """
    vemb = gnn_lib.vertex_embeddings(params, cfg, labels, degrees,
                                     edge_src, edge_dst)
    struct = gnn_lib.path_embeddings(vemb, paths)
    oa, ob = struct[neg_a], struct[neg_b]
    viol_fwd = jnp.max(oa - ob, axis=-1)
    lp1 = paths.shape[1]
    d = vemb.shape[1]
    ob_rev = ob.reshape(-1, lp1, d)[:, ::-1, :].reshape(ob.shape)
    viol_rev = jnp.max(oa - ob_rev, axis=-1)
    # must violate in BOTH orientations to be prunable
    viol = jnp.minimum(viol_fwd, viol_rev)
    hinge = jax.nn.relu(margin - viol).mean()
    tight = 1e-4 * (struct ** 2).mean()
    return hinge + tight


def train_dominance_gnn(graph: LabeledGraph, cfg: gnn_lib.GNNConfig,
                        path_length: int = 2, n_steps: int = 200,
                        n_pairs: int = 2048, lr: float = 3e-2,
                        seed: int = 0) -> dict[str, Any]:
    """Train one shard's GNN to maximize pruning power. Returns params."""
    from repro.train.optimizer import adam_init, adam_update

    key = jax.random.PRNGKey(seed)
    params = gnn_lib.init_params(cfg, key)
    table = enumerate_paths(graph, path_length, max_paths=4096, seed=seed)
    if table.n_paths < 2:
        return params
    neg_a, neg_b = mine_negative_pairs(graph, table, n_pairs, seed=seed)
    if neg_a.size == 0:  # graph too uniform to mine negatives; nothing to do
        return params

    src = jnp.asarray(np.repeat(np.arange(graph.n_vertices),
                                np.diff(graph.indptr)))
    dst = jnp.asarray(graph.indices.astype(np.int64))
    labels = jnp.asarray(graph.labels)
    degrees = jnp.asarray(graph.degrees)
    paths = jnp.asarray(table.vertices)
    na, nb = jnp.asarray(neg_a), jnp.asarray(neg_b)

    loss_fn = lambda p: _pruning_loss(p, cfg, labels, degrees, src, dst,
                                      paths, na, nb)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, g, opt, lr=lr)
        return params, opt, loss

    for _ in range(n_steps):
        params, opt, loss = step(params, opt)
    return params


def embed_shard_paths(graph: LabeledGraph, params: dict[str, Any],
                      cfg: gnn_lib.GNNConfig, max_length: int = 3,
                      max_paths_per_length: int | None = 200_000,
                      seed: int = 0) -> dict[int, EmbeddedPaths]:
    """Enumerate + embed all paths of length 1..max_length of one shard."""
    src = jnp.asarray(np.repeat(np.arange(graph.n_vertices),
                                np.diff(graph.indptr)))
    dst = jnp.asarray(graph.indices.astype(np.int64))
    labels = jnp.asarray(graph.labels)
    degrees = jnp.asarray(graph.degrees)
    out: dict[int, EmbeddedPaths] = {}
    for l in range(1, max_length + 1):
        table = enumerate_paths(graph, l, max_paths=max_paths_per_length,
                                seed=seed)
        if table.n_paths == 0:
            continue
        emb = gnn_lib.encode_paths(params, cfg, labels, degrees, src, dst,
                                   jnp.asarray(table.vertices))
        out[l] = EmbeddedPaths(vertices=table.vertices,
                               embeddings=np.asarray(emb), length=l)
    return out


def embed_query_paths(query: LabeledGraph, params: dict[str, Any],
                      cfg: gnn_lib.GNNConfig, table: PathTable) -> np.ndarray:
    """Embed query paths with the SAME encoder (query stars are sub-stars)."""
    src = jnp.asarray(np.repeat(np.arange(query.n_vertices),
                                np.diff(query.indptr)))
    dst = jnp.asarray(query.indices.astype(np.int64))
    emb = gnn_lib.encode_paths(params, cfg, jnp.asarray(query.labels),
                               jnp.asarray(query.degrees), src, dst,
                               jnp.asarray(table.vertices))
    return np.asarray(emb)
