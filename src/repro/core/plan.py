"""Algorithm 6 — PE-score driven query plan ranking.

Steps: extract query paths (1..5 edges, covering all edges) -> per-path
feature vectors -> batch PE-score inference -> sort descending -> resolve
shared-vertex dependencies (shorter first) -> group by main shard.

The returned plan is a list of (table_idx, row_idx) into the query's
PathTable list, consumed by repro.core.matching.exact_match and the
distributed executor.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import LabeledGraph
from repro.core.paths import PathTable, paths_of_query
from repro.core.pescore import PEScoreModel, path_feature_vector

__all__ = ["RankedPlan", "rank_query_plan", "degree_based_plan",
           "random_plan"]


@dataclasses.dataclass(frozen=True)
class RankedPlan:
    order: list[tuple[int, int]]          # (table_idx, row_idx), exec order
    scores: dict[tuple[int, int], float]  # predicted PE-score per path
    groups: list[list[tuple[int, int]]]   # shard-grouped execution


def _main_shard(path_vertices: np.ndarray, shard_of: np.ndarray | None) -> int:
    if shard_of is None:
        return 0
    shards = shard_of[path_vertices]
    vals, counts = np.unique(shards, return_counts=True)
    return int(vals[np.argmax(counts)])


def rank_query_plan(query: LabeledGraph, model: PEScoreModel,
                    shard_of: np.ndarray | None = None,
                    max_path_length: int = 3,
                    tables: list[PathTable] | None = None,
                    q_embs: list[np.ndarray] | None = None) -> RankedPlan:
    """Algorithm 6 end-to-end.

    q_embs: per-table [n_paths, D] query path embeddings; when given (and
    the model carries `mbr_uppers` root summaries) the features include
    the predicted per-shard root-skip fraction for each path.
    """
    tables = tables if tables is not None else \
        paths_of_query(query, max_path_length)
    mbr_uppers = getattr(model, "mbr_uppers", None)

    # Steps 1-2: features
    rows: list[tuple[int, int]] = []
    feats: list[np.ndarray] = []
    for ti, t in enumerate(tables):
        for r in range(t.n_paths):
            pv = t.vertices[r]
            cross = bool(shard_of is not None
                         and len(set(shard_of[pv].tolist())) > 1)
            qe = q_embs[ti][r] if q_embs is not None else None
            feats.append(path_feature_vector(query, pv, cross,
                                             model.global_features,
                                             model.label_freq,
                                             q_emb=qe,
                                             mbr_uppers=mbr_uppers))
            rows.append((ti, r))
    if not rows:
        return RankedPlan([], {}, [])

    # Step 3: batch inference
    scores = model.predict(np.stack(feats))
    score_of = {rows[i]: float(scores[i]) for i in range(len(rows))}

    # Step 4: sort by PE-score desc, then dependency resolution:
    # paths sharing >= 1 vertex execute in increasing length order.
    order = sorted(rows, key=lambda rc: -score_of[rc])
    changed = True
    while changed:
        changed = False
        for i in range(len(order)):
            for j in range(i + 1, len(order)):
                a, b = order[i], order[j]
                va = set(tables[a[0]].vertices[a[1]].tolist())
                vb = set(tables[b[0]].vertices[b[1]].tolist())
                la, lb = tables[a[0]].length, tables[b[0]].length
                if va & vb and la > lb:
                    order[i], order[j] = order[j], order[i]
                    changed = True
        # the bubble pass above converges (finite inversions)

    # Step 5: group by main shard, keep sorted order inside groups
    group_map: dict[int, list[tuple[int, int]]] = {}
    for rc in order:
        ms = _main_shard(tables[rc[0]].vertices[rc[1]], shard_of)
        group_map.setdefault(ms, []).append(rc)
    groups = [group_map[k] for k in sorted(
        group_map, key=lambda g: -max(score_of[rc] for rc in group_map[g]))]
    flat = [rc for g in groups for rc in g]
    return RankedPlan(order=flat, scores=score_of, groups=groups)


def degree_based_plan(query: LabeledGraph,
                      tables: list[PathTable] | None = None,
                      max_path_length: int = 3) -> RankedPlan:
    """Baseline: GNN-PE's original degree-based ordering (high degree first)."""
    tables = tables if tables is not None else \
        paths_of_query(query, max_path_length)
    rows, key = [], {}
    for ti, t in enumerate(tables):
        for r in range(t.n_paths):
            deg = query.degrees[t.vertices[r]].astype(np.float64)
            rows.append((ti, r))
            key[(ti, r)] = float(deg.mean())
    order = sorted(rows, key=lambda rc: -key[rc])
    return RankedPlan(order=order, scores=key, groups=[order])


def random_plan(query: LabeledGraph, seed: int = 0,
                tables: list[PathTable] | None = None,
                max_path_length: int = 3) -> RankedPlan:
    """Baseline: uniformly shuffled path order (gauntlet control arm)."""
    tables = tables if tables is not None else \
        paths_of_query(query, max_path_length)
    rows = [(ti, r) for ti, t in enumerate(tables)
            for r in range(t.n_paths)]
    rng = np.random.default_rng(seed)
    order = [rows[i] for i in rng.permutation(len(rows))]
    scores = {rc: 0.0 for rc in rows}
    return RankedPlan(order=order, scores=scores, groups=[order])
