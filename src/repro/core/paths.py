"""Path enumeration for GNN-PE.

GNN-PE decomposes both the data graph and query graphs into short simple
paths (length 1..L edges).  Data-side paths are embedded offline and indexed
in the aR-tree; query-side paths are embedded online and used to probe the
index.  Enumeration is fully vectorized (frontier expansion in numpy).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import LabeledGraph

__all__ = ["PathTable", "enumerate_paths", "paths_of_query",
           "path_row_keys"]


@dataclasses.dataclass(frozen=True)
class PathTable:
    """A batch of simple paths of equal length.

    Attributes:
      vertices: int32 [P, l+1]  vertex ids along each path.
      length:   int             number of edges l.
    """

    vertices: np.ndarray
    length: int

    @property
    def n_paths(self) -> int:
        return int(self.vertices.shape[0])

    def label_sequences(self, graph: LabeledGraph) -> np.ndarray:
        return graph.labels[self.vertices]

    def canonical_mask(self) -> np.ndarray:
        """Mask selecting one orientation per undirected path.

        A simple path and its reverse describe the same subgraph; we keep the
        orientation whose endpoint ids are lexicographically smaller.
        """
        first = self.vertices[:, 0]
        last = self.vertices[:, -1]
        return (first < last) | (first == last)  # first==last impossible (simple)


def _expand(
    frontier: np.ndarray, indptr: np.ndarray, indices: np.ndarray
) -> np.ndarray:
    """Expand paths [P, k] by one hop to [P', k+1], keeping simple paths."""
    tails = frontier[:, -1].astype(np.int64)
    start, stop = indptr[tails], indptr[tails + 1]
    counts = (stop - start).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros((0, frontier.shape[1] + 1), dtype=np.int32)
    # row r of the output comes from path row_ids[r] and neighbor offsets[r]
    row_ids = np.repeat(np.arange(frontier.shape[0], dtype=np.int64), counts)
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    nbrs = indices[start[row_ids] + offs]
    new = np.concatenate(
        [frontier[row_ids], nbrs[:, None].astype(np.int32)], axis=1
    )
    # simplicity: new vertex must not already be on the path
    dup = (new[:, :-1] == new[:, -1:]).any(axis=1)
    return new[~dup]


def enumerate_paths(
    graph: LabeledGraph,
    length: int,
    max_paths: int | None = None,
    seed: int = 0,
    canonical: bool = True,
) -> PathTable:
    """Enumerate simple paths with `length` edges.

    If the expansion exceeds ``max_paths`` an unbiased uniform subsample is
    kept (reservoir-free: permutation prefix with a fixed seed) — used for
    PE-score training-sample selection (paper samples ~1% of paths).
    """
    if length < 1:
        raise ValueError("length must be >= 1")
    frontier = graph.edge_list.copy()  # canonical u<v orientation
    # expansion works on directed paths: seed both directions
    frontier = np.concatenate([frontier, frontier[:, ::-1]], axis=0)
    for _ in range(length - 1):
        frontier = _expand(frontier, graph.indptr, graph.indices)
        if max_paths is not None and frontier.shape[0] > 4 * max_paths:
            rng = np.random.default_rng(seed)
            sel = rng.permutation(frontier.shape[0])[: 4 * max_paths]
            frontier = frontier[np.sort(sel)]
    table = PathTable(vertices=frontier, length=length)
    if canonical:
        frontier = frontier[table.canonical_mask()]
        table = PathTable(vertices=frontier, length=length)
    if max_paths is not None and table.n_paths > max_paths:
        rng = np.random.default_rng(seed)
        sel = np.sort(rng.permutation(table.n_paths)[:max_paths])
        table = PathTable(vertices=table.vertices[sel], length=length)
    return table


def path_row_keys(vertices: np.ndarray) -> list[bytes]:
    """One hashable key per path row (the row's int64 ids, as bytes).

    The incremental re-index matches a freshly enumerated table's rows
    against the previous epoch's table to reuse embeddings of unchanged
    (clean) paths: rows are keyed by their GLOBAL vertex-id sequence, so
    the caller maps shard-local ids through `global_ids` first.  A path
    and its reverse get distinct keys on purpose — enumeration is
    canonical (`canonical_mask`), so equal subgraphs produce equal rows.
    """
    a = np.ascontiguousarray(np.asarray(vertices, np.int64))
    return [r.tobytes() for r in a]


def paths_of_query(
    query: LabeledGraph, max_length: int = 3
) -> list[PathTable]:
    """Decompose a query graph into simple paths covering all edges.

    Returns one PathTable per length 1..max_length (empty tables skipped).
    Every edge of the query is guaranteed to be covered by the length-1
    table, matching Algorithm 6 step 1 ("covers all edges of q").
    """
    out = []
    for l in range(1, max_length + 1):
        t = enumerate_paths(query, l)
        if t.n_paths:
            out.append(t)
    return out
