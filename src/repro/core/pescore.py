"""Innovation 3 — PE-score model: histogram GBDT + distributed features.

PE-score(p) = PruningRate(p) × 1 / FilterTime(p)            (§6.2.1)
PruningRate(p) = 1 − N_valid(p) / N_total(p)

No XGBoost offline, so the framework carries its own histogram gradient
boosted trees (squared loss, depth-wise complete trees).  Fitting is numpy;
**inference is compiled JAX** — trees are packed into dense arrays
[n_trees, n_nodes] and evaluated as a vectorized gather walk, so a whole
query's paths are scored in one device call (paper: < 1 ms/path).

Adaptive tree count (§6.2.1): num_trees = min(50 + N_sample/1000, 300).

Shard-level features (§6.2.1-1): path-length ratios R_l, label-sequence
diversity D_t, degree stats (avg, max, power-law gamma), aggregated
path-count-weighted into global features.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import LabeledGraph, power_law_exponent
from repro.core.paths import PathTable

__all__ = ["GBDT", "fit_gbdt", "adaptive_tree_count", "ShardFeatures",
           "shard_features", "aggregate_global_features", "path_feature_vector",
           "PEScoreModel", "N_PATH_FEATURES"]

MAX_PATH_LEN = 5


def adaptive_tree_count(n_samples: int) -> int:
    return int(min(50 + n_samples / 1000, 300))


# --------------------------------------------------------------------------- #
# histogram GBDT (numpy fit, JAX inference)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GBDT:
    """Complete binary trees in dense layout.

    node i children are 2i+1 / 2i+2; leaves carry values; internal nodes
    carry (feature, threshold).  feature = -1 marks "pass-through" nodes
    (act as leaves early).
    """

    feature: np.ndarray    # int32 [T, n_nodes]
    threshold: np.ndarray  # f32   [T, n_nodes]
    value: np.ndarray      # f32   [T, n_nodes]
    depth: int
    base: float
    lr: float

    @property
    def n_trees(self) -> int:
        return int(self.feature.shape[0])

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(_gbdt_predict_jax(
            jnp.asarray(self.feature), jnp.asarray(self.threshold),
            jnp.asarray(self.value), self.depth, self.base, self.lr,
            jnp.asarray(x, jnp.float32)))


@functools.partial(jax.jit, static_argnames=("depth",))
def _gbdt_predict_jax(feature, threshold, value, depth: int, base, lr, x):
    """Vectorized gather-walk over all trees at once.  x: [N, F] -> [N]."""
    n = x.shape[0]
    t = feature.shape[0]
    node = jnp.zeros((n, t), dtype=jnp.int32)
    for _ in range(depth):
        feat = feature[jnp.arange(t)[None, :], node]          # [N, T]
        thr = threshold[jnp.arange(t)[None, :], node]
        xv = jnp.take_along_axis(x, jnp.maximum(feat, 0), axis=1)
        go_right = (xv > thr) & (feat >= 0)
        is_leaf = feat < 0
        nxt = jnp.where(go_right, 2 * node + 2, 2 * node + 1)
        node = jnp.where(is_leaf, node, nxt)
    vals = value[jnp.arange(t)[None, :], node]                # [N, T]
    return base + lr * vals.sum(axis=1)


def _fit_tree(x: np.ndarray, g: np.ndarray, w: np.ndarray, depth: int,
              n_bins: int, min_child: int
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One regression tree on residuals g with sample weights w."""
    n, f = x.shape
    n_nodes = 2 ** (depth + 1) - 1
    feature = -np.ones(n_nodes, dtype=np.int32)
    threshold = np.zeros(n_nodes, dtype=np.float32)
    value = np.zeros(n_nodes, dtype=np.float32)
    node_of = np.zeros(n, dtype=np.int64)

    # precompute per-feature bin edges (quantile bins)
    edges = []
    for j in range(f):
        qs = np.quantile(x[:, j], np.linspace(0, 1, n_bins + 1)[1:-1])
        edges.append(np.unique(qs))

    for node in range(2 ** depth - 1):       # internal nodes, level order
        mask = node_of == node
        if mask.sum() < 2 * min_child:
            value[node] = (np.average(g[mask], weights=w[mask])
                           if mask.any() else 0.0)
            continue
        gm, wm, xm = g[mask], w[mask], x[mask]
        sum_g, sum_w = (gm * wm).sum(), wm.sum()
        parent_score = (sum_g ** 2) / (sum_w + 1e-9)
        best = (0.0, -1, 0.0)                # (gain, feat, thr)
        for j in range(f):
            for thr in edges[j]:
                left = xm[:, j] <= thr
                wl = wm[left].sum()
                if wl < min_child or (sum_w - wl) < min_child:
                    continue
                gl = (gm[left] * wm[left]).sum()
                score = (gl ** 2) / (wl + 1e-9) + \
                        ((sum_g - gl) ** 2) / (sum_w - wl + 1e-9)
                gain = score - parent_score
                if gain > best[0]:
                    best = (gain, j, float(thr))
        if best[1] < 0:
            value[node] = float(sum_g / (sum_w + 1e-9))
            continue
        feature[node] = best[1]
        threshold[node] = best[2]
        go_right = x[:, best[1]] > best[2]
        node_of = np.where(mask & go_right, 2 * node + 2,
                           np.where(mask & ~go_right, 2 * node + 1, node_of))
    # leaf values (bottom level + early leaves already handled)
    for node in range(2 ** depth - 1, n_nodes):
        mask = node_of == node
        if mask.any():
            value[node] = float(np.average(g[mask], weights=w[mask]))
    return feature, threshold, value


def fit_gbdt(x: np.ndarray, y: np.ndarray, n_trees: int | None = None,
             depth: int = 3, lr: float = 0.2, n_bins: int = 16,
             min_child: int = 4, sample_weight: np.ndarray | None = None
             ) -> GBDT:
    """MSE gradient boosting, optionally frequency-weighted (§6.2.1-2)."""
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float64)
    n = x.shape[0]
    if n_trees is None:
        n_trees = adaptive_tree_count(n)
    w = (np.ones(n) if sample_weight is None
         else np.asarray(sample_weight, np.float64))
    base = float(np.average(y, weights=w)) if n else 0.0
    pred = np.full(n, base)
    feats, thrs, vals = [], [], []
    for _ in range(n_trees):
        resid = y - pred
        f_, t_, v_ = _fit_tree(x, resid, w, depth, n_bins, min_child)
        feats.append(f_), thrs.append(t_), vals.append(v_)
        # apply tree
        node = np.zeros(n, dtype=np.int64)
        for _ in range(depth):
            fn = f_[node]
            go_right = np.take_along_axis(
                x, np.maximum(fn, 0)[:, None], axis=1)[:, 0] > t_[node]
            nxt = np.where(go_right, 2 * node + 2, 2 * node + 1)
            node = np.where(fn < 0, node, nxt)
        pred = pred + lr * v_[node]
    return GBDT(np.stack(feats), np.stack(thrs), np.stack(vals),
                depth, base, lr)


# --------------------------------------------------------------------------- #
# distributed shard-level features
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ShardFeatures:
    """Per-shard features (§6.2.1-1)."""

    path_len_ratio: np.ndarray    # [MAX_PATH_LEN] R_l
    label_diversity: np.ndarray   # [MAX_PATH_LEN] D_t (normalized)
    avg_degree: float
    max_degree: float
    gamma: float
    n_paths: np.ndarray           # [MAX_PATH_LEN] N_l (for weighting)


def shard_features(graph: LabeledGraph,
                   path_tables: dict[int, PathTable]) -> ShardFeatures:
    n_l = np.zeros(MAX_PATH_LEN)
    div = np.zeros(MAX_PATH_LEN)
    for l, t in path_tables.items():
        if l > MAX_PATH_LEN:
            continue
        n_l[l - 1] = t.n_paths
        seqs = graph.labels[t.vertices]
        div[l - 1] = len({tuple(s) for s in seqs.tolist()}) / max(t.n_paths, 1)
    total = max(n_l.sum(), 1)
    d = graph.degrees
    return ShardFeatures(
        path_len_ratio=n_l / total,
        label_diversity=div,
        avg_degree=float(d.mean()) if d.size else 0.0,
        max_degree=float(d.max()) if d.size else 0.0,
        gamma=power_law_exponent(d),
        n_paths=n_l,
    )


def aggregate_global_features(per_shard: list[ShardFeatures]) -> np.ndarray:
    """Path-count-weighted aggregation (§6.2.1-1) -> global feature vector."""
    if not per_shard:
        return np.zeros(2 * MAX_PATH_LEN + 3, np.float32)
    w = np.stack([s.n_paths for s in per_shard])          # [m, L]
    wsum = np.maximum(w.sum(axis=0), 1.0)
    r_g = (w * np.stack([s.path_len_ratio for s in per_shard])).sum(0) / wsum
    d_g = (w * np.stack([s.label_diversity for s in per_shard])).sum(0) / wsum
    tot = np.maximum(w.sum(1, keepdims=True), 1.0)
    wk = (w.sum(1) / tot.sum()).ravel()
    avg_d = float((wk * np.array([s.avg_degree for s in per_shard])).sum())
    max_d = float(max(s.max_degree for s in per_shard))
    gam = float((wk * np.array([s.gamma for s in per_shard])).sum())
    return np.concatenate(
        [r_g, d_g, [avg_d, max_d, gam]]).astype(np.float32)


N_GLOBAL_FEATURES = 2 * MAX_PATH_LEN + 3
N_PATH_FEATURES = N_GLOBAL_FEATURES + 12


def path_feature_vector(query: LabeledGraph, path_vertices: np.ndarray,
                        cross_shard: bool, global_features: np.ndarray,
                        label_freq: np.ndarray | None = None,
                        q_emb: np.ndarray | None = None,
                        mbr_uppers: dict[int, np.ndarray] | None = None
                        ) -> np.ndarray:
    """X_qi: global features + path-specific features (Algorithm 6 step 2).

    label_freq: normalized label histogram of the DATA graph — paths built
    from rare labels have few candidates and prune hard, which is the main
    signal the ranker can exploit before executing anything.

    q_emb + mbr_uppers (per-length [S, D] root-MBR upper summaries) add
    two shard-skip features: the fraction of shards whose root MBR
    dominance-rejects this path in both orientations (predicted root
    skips — the paths the ranker should fire first, they prune whole
    shards for free), and the mean per-dimension exceed fraction (a soft
    margin).  Both are 0 when the embedding or the summaries are absent,
    keeping the feature layout fixed.
    """
    deg = query.degrees[path_vertices].astype(np.float64)
    labels = query.labels[path_vertices]
    length = path_vertices.shape[0] - 1
    if label_freq is not None and label_freq.size:
        lf = label_freq[np.clip(labels, 0, label_freq.size - 1)]
        rare_mean = float(-np.log(lf + 1e-9).mean())
        rare_max = float(-np.log(lf + 1e-9).max())
    else:
        rare_mean = rare_max = 0.0
    skip_frac = exceed_mean = 0.0
    if q_emb is not None and mbr_uppers:
        up = mbr_uppers.get(length)
        if up is not None and up.shape[0] and up.shape[1] == q_emb.shape[0]:
            eps = 1e-5
            d = q_emb.shape[0] // (length + 1)
            q_rev = q_emb.reshape(length + 1, d)[::-1].reshape(-1)
            f_ex = q_emb[None, :] > up + eps                   # [S, D]
            r_ex = q_rev[None, :] > up + eps
            skip_frac = float((f_ex.any(axis=1)
                               & r_ex.any(axis=1)).mean())
            exceed_mean = float(f_ex.mean())
    own = np.array([
        length,
        float(cross_shard),
        deg.mean(), deg.max(), deg.min(), deg.std(),
        len(set(labels.tolist())) / max(len(labels), 1),
        float(labels.mean()),
        rare_mean, rare_max,
        skip_frac, exceed_mean,
    ], dtype=np.float32)
    return np.concatenate([global_features, own])


# --------------------------------------------------------------------------- #
# PE-score model
# --------------------------------------------------------------------------- #
class PEScoreModel:
    """Fit on offline samples; predict per-query-path online."""

    def __init__(self) -> None:
        self.gbdt: GBDT | None = None
        self.global_features = np.zeros(N_GLOBAL_FEATURES, np.float32)
        self.label_freq = np.zeros(0, np.float32)   # data-graph label hist
        # per-length [S, D] root-MBR upper summaries (shard rows sorted by
        # id; -inf rows for shards with no tree at that length) — lets the
        # ranker predict root skips before launching anything
        self.mbr_uppers: dict[int, np.ndarray] = {}

    @staticmethod
    def label_pe_score(n_valid: float, n_total: float,
                       filter_time_ms: float) -> float:
        pruning_rate = 1.0 - n_valid / max(n_total, 1.0)
        return pruning_rate / max(filter_time_ms, 1e-3)

    def fit(self, x: np.ndarray, y: np.ndarray,
            freq_weight: np.ndarray | None = None) -> None:
        self.gbdt = fit_gbdt(x, y, sample_weight=freq_weight)

    def incremental_fit(self, x_new: np.ndarray, y_new: np.ndarray) -> None:
        """Append trees for new shards (<= 2 min per paper — here: cheap)."""
        if self.gbdt is None:
            self.fit(x_new, y_new)
            return
        resid = y_new - self.gbdt.predict(x_new)
        extra = fit_gbdt(x_new, resid, n_trees=10, lr=self.gbdt.lr)
        if self.gbdt.n_trees + extra.n_trees > 300:   # cap per paper
            return
        self.gbdt = GBDT(
            feature=np.concatenate([self.gbdt.feature, extra.feature]),
            threshold=np.concatenate([self.gbdt.threshold, extra.threshold]),
            value=np.concatenate([self.gbdt.value, extra.value]),
            depth=self.gbdt.depth, base=self.gbdt.base, lr=self.gbdt.lr)

    def predict(self, x: np.ndarray) -> np.ndarray:
        if self.gbdt is None:
            return np.zeros(np.atleast_2d(x).shape[0], np.float32)
        return self.gbdt.predict(np.atleast_2d(x))
