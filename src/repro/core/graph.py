"""Labeled graph substrate.

Static undirected vertex-labeled graphs stored in CSR form (numpy on host,
convertible to JAX arrays for device compute).  This is the data model shared
by the GNN-PE engine (paper), the partitioner, and the GNN architecture zoo.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["LabeledGraph", "degree_stats", "power_law_exponent"]


@dataclasses.dataclass(frozen=True)
class LabeledGraph:
    """Undirected vertex-labeled graph, CSR adjacency (both directions stored).

    Attributes:
      labels:    int32 [n]      vertex label ids.
      indptr:    int64 [n+1]    CSR row pointers.
      indices:   int32 [2*m]    CSR column indices (symmetric).
      edge_list: int32 [m, 2]   unique undirected edges with u < v.
    """

    labels: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray
    edge_list: np.ndarray

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(
        n_vertices: int,
        edges: np.ndarray | Sequence[tuple[int, int]],
        labels: np.ndarray | Sequence[int],
    ) -> "LabeledGraph":
        edges = np.asarray(edges, dtype=np.int32).reshape(-1, 2)
        labels = np.asarray(labels, dtype=np.int32)
        if labels.shape[0] != n_vertices:
            raise ValueError("labels length must equal n_vertices")
        # canonicalize: undirected, no self loops, dedup, u < v
        u = np.minimum(edges[:, 0], edges[:, 1])
        v = np.maximum(edges[:, 0], edges[:, 1])
        keep = u != v
        u, v = u[keep], v[keep]
        uniq = np.unique(np.stack([u, v], axis=1), axis=0)
        if uniq.size and (uniq.min() < 0 or uniq.max() >= n_vertices):
            raise ValueError("edge endpoint out of range")
        # symmetric CSR
        src = np.concatenate([uniq[:, 0], uniq[:, 1]])
        dst = np.concatenate([uniq[:, 1], uniq[:, 0]])
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(n_vertices + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return LabeledGraph(
            labels=labels,
            indptr=indptr,
            indices=dst.astype(np.int32),
            edge_list=uniq.astype(np.int32),
        )

    @staticmethod
    def from_networkx(g, labels: np.ndarray | None = None) -> "LabeledGraph":
        import networkx as nx  # local import: optional dependency path

        g = nx.convert_node_labels_to_integers(g, ordering="sorted")
        n = g.number_of_nodes()
        edges = np.asarray(list(g.edges()), dtype=np.int32).reshape(-1, 2)
        if labels is None:
            labels = np.asarray(
                [g.nodes[i].get("label", 0) for i in range(n)], dtype=np.int32
            )
        return LabeledGraph.from_edges(n, edges, labels)

    # ------------------------------------------------------------------ #
    # basic properties
    # ------------------------------------------------------------------ #
    @property
    def n_vertices(self) -> int:
        return int(self.labels.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.edge_list.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @property
    def n_labels(self) -> int:
        return int(self.labels.max()) + 1 if self.n_vertices else 0

    def avg_degree(self) -> float:
        return float(self.degrees.mean()) if self.n_vertices else 0.0

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        return bool(np.isin(v, self.neighbors(u)).any())

    def label_set(self) -> np.ndarray:
        return np.unique(self.labels)

    # ------------------------------------------------------------------ #
    # views
    # ------------------------------------------------------------------ #
    def induced_subgraph(
        self, vertex_ids: np.ndarray | Iterable[int]
    ) -> tuple["LabeledGraph", np.ndarray]:
        """Induced subgraph; returns (subgraph, old_vertex_ids)."""
        vids = np.unique(np.asarray(list(vertex_ids), dtype=np.int32))
        remap = -np.ones(self.n_vertices, dtype=np.int64)
        remap[vids] = np.arange(vids.shape[0])
        e = self.edge_list
        keep = (remap[e[:, 0]] >= 0) & (remap[e[:, 1]] >= 0)
        sub_edges = remap[e[keep]].astype(np.int32)
        return (
            LabeledGraph.from_edges(vids.shape[0], sub_edges, self.labels[vids]),
            vids,
        )

    def adjacency_bits(self) -> np.ndarray:
        """Bit-packed adjacency matrix, lazily built and cached.

        uint8 [n, ceil(n/8)]: bit ``v & 7`` of byte ``[u, v >> 3]`` is 1
        iff (u, v) is an edge.  The vectorized join uses it for O(1)
        batched adjacency tests; at n^2/8 bytes it is only built for
        graphs the join actually tables (callers gate on size).
        """
        cached = getattr(self, "_adj_bits", None)
        if cached is None:
            n = self.n_vertices
            bits = np.zeros((n, (n + 7) // 8), np.uint8)
            rows = np.repeat(np.arange(n, dtype=np.int64),
                             np.diff(self.indptr))
            cols = self.indices.astype(np.int64)
            np.bitwise_or.at(bits, (rows, cols >> 3),
                             np.uint8(1) << (cols & 7).astype(np.uint8))
            object.__setattr__(self, "_adj_bits", bits)
            cached = bits
        return cached

    def to_networkx(self):
        import networkx as nx

        g = nx.Graph()
        for v in range(self.n_vertices):
            g.add_node(v, label=int(self.labels[v]))
        g.add_edges_from(self.edge_list.tolist())
        return g

    def serialize(self) -> bytes:
        """Canonical byte image (used for CRC32 integrity in migration)."""
        head = np.asarray(
            [self.n_vertices, self.n_edges], dtype=np.int64
        ).tobytes()
        return (
            head
            + self.labels.astype(np.int32).tobytes()
            + self.edge_list.astype(np.int32).tobytes()
        )

    @staticmethod
    def deserialize(blob: bytes) -> "LabeledGraph":
        n, m = np.frombuffer(blob[:16], dtype=np.int64)
        off = 16
        labels = np.frombuffer(blob[off : off + 4 * n], dtype=np.int32).copy()
        off += 4 * int(n)
        edges = np.frombuffer(blob[off : off + 8 * m], dtype=np.int32).reshape(
            int(m), 2
        ).copy()
        return LabeledGraph.from_edges(int(n), edges, labels)


def degree_stats(graph: LabeledGraph) -> dict[str, float]:
    d = graph.degrees
    return {
        "avg_degree": float(d.mean()) if d.size else 0.0,
        "max_degree": float(d.max()) if d.size else 0.0,
        "power_law_gamma": power_law_exponent(d),
    }


def power_law_exponent(degrees: np.ndarray, d_min: int = 1) -> float:
    """MLE estimate of the power-law exponent gamma, P(d) ~ d^-gamma.

    Clauset-Shalizi-Newman continuous MLE restricted to d >= d_min.  Used as a
    shard-level feature for the PE-score model (paper section 6.2.1).
    """
    d = degrees[degrees >= max(d_min, 1)].astype(np.float64)
    if d.size < 2:
        return 0.0
    logs = np.log(d / (max(d_min, 1) - 0.5 + 0.5))  # continuous correction
    s = logs.sum()
    if s <= 0:
        return 0.0
    return float(1.0 + d.size / s)
