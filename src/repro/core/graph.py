"""Labeled graph substrate.

Static undirected vertex-labeled graphs stored in CSR form (numpy on host,
convertible to JAX arrays for device compute).  This is the data model shared
by the GNN-PE engine (paper), the partitioner, and the GNN architecture zoo.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["LabeledGraph", "GraphDelta", "apply_graph_delta",
           "degree_stats", "power_law_exponent"]


def _canon_edges(edges: np.ndarray) -> np.ndarray:
    """Canonical undirected edge set: u < v, unique, no self loops.

    The ONE canonical form, shared by `LabeledGraph.from_edges` and
    `apply_graph_delta`'s no-op detection — they must never diverge."""
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    u = np.minimum(edges[:, 0], edges[:, 1])
    v = np.maximum(edges[:, 0], edges[:, 1])
    keep = u != v
    if not keep.all():
        u, v = u[keep], v[keep]
    if u.size == 0:
        return np.zeros((0, 2), np.int64)
    return np.unique(np.stack([u, v], axis=1), axis=0)


@dataclasses.dataclass(frozen=True)
class LabeledGraph:
    """Undirected vertex-labeled graph, CSR adjacency (both directions stored).

    Attributes:
      labels:    int32 [n]      vertex label ids.
      indptr:    int64 [n+1]    CSR row pointers.
      indices:   int32 [2*m]    CSR column indices (symmetric).
      edge_list: int32 [m, 2]   unique undirected edges with u < v.
    """

    labels: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray
    edge_list: np.ndarray

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(
        n_vertices: int,
        edges: np.ndarray | Sequence[tuple[int, int]],
        labels: np.ndarray | Sequence[int],
    ) -> "LabeledGraph":
        edges = np.asarray(edges, dtype=np.int32).reshape(-1, 2)
        labels = np.asarray(labels, dtype=np.int32)
        if labels.shape[0] != n_vertices:
            raise ValueError("labels length must equal n_vertices")
        # canonicalize: undirected, no self loops, dedup, u < v
        uniq = _canon_edges(edges)
        if uniq.size and (uniq.min() < 0 or uniq.max() >= n_vertices):
            raise ValueError("edge endpoint out of range")
        # symmetric CSR
        src = np.concatenate([uniq[:, 0], uniq[:, 1]])
        dst = np.concatenate([uniq[:, 1], uniq[:, 0]])
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(n_vertices + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return LabeledGraph(
            labels=labels,
            indptr=indptr,
            indices=dst.astype(np.int32),
            edge_list=uniq.astype(np.int32),
        )

    @staticmethod
    def from_networkx(g, labels: np.ndarray | None = None) -> "LabeledGraph":
        import networkx as nx  # local import: optional dependency path

        g = nx.convert_node_labels_to_integers(g, ordering="sorted")
        n = g.number_of_nodes()
        edges = np.asarray(list(g.edges()), dtype=np.int32).reshape(-1, 2)
        if labels is None:
            labels = np.asarray(
                [g.nodes[i].get("label", 0) for i in range(n)], dtype=np.int32
            )
        return LabeledGraph.from_edges(n, edges, labels)

    # ------------------------------------------------------------------ #
    # basic properties
    # ------------------------------------------------------------------ #
    @property
    def n_vertices(self) -> int:
        return int(self.labels.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.edge_list.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @property
    def n_labels(self) -> int:
        return int(self.labels.max()) + 1 if self.n_vertices else 0

    def avg_degree(self) -> float:
        return float(self.degrees.mean()) if self.n_vertices else 0.0

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        return bool(np.isin(v, self.neighbors(u)).any())

    def label_set(self) -> np.ndarray:
        return np.unique(self.labels)

    # ------------------------------------------------------------------ #
    # views
    # ------------------------------------------------------------------ #
    def induced_subgraph(
        self, vertex_ids: np.ndarray | Iterable[int]
    ) -> tuple["LabeledGraph", np.ndarray]:
        """Induced subgraph; returns (subgraph, old_vertex_ids)."""
        vids = np.unique(np.asarray(list(vertex_ids), dtype=np.int32))
        remap = -np.ones(self.n_vertices, dtype=np.int64)
        remap[vids] = np.arange(vids.shape[0])
        e = self.edge_list
        keep = (remap[e[:, 0]] >= 0) & (remap[e[:, 1]] >= 0)
        sub_edges = remap[e[keep]].astype(np.int32)
        return (
            LabeledGraph.from_edges(vids.shape[0], sub_edges, self.labels[vids]),
            vids,
        )

    def adjacency_bits(self) -> np.ndarray:
        """Bit-packed adjacency matrix, lazily built and cached.

        uint8 [n, ceil(n/8)]: bit ``v & 7`` of byte ``[u, v >> 3]`` is 1
        iff (u, v) is an edge.  The vectorized join uses it for O(1)
        batched adjacency tests; at n^2/8 bytes it is only built for
        graphs the join actually tables (callers gate on size).
        """
        cached = getattr(self, "_adj_bits", None)
        if cached is None:
            n = self.n_vertices
            bits = np.zeros((n, (n + 7) // 8), np.uint8)
            rows = np.repeat(np.arange(n, dtype=np.int64),
                             np.diff(self.indptr))
            cols = self.indices.astype(np.int64)
            np.bitwise_or.at(bits, (rows, cols >> 3),
                             np.uint8(1) << (cols & 7).astype(np.uint8))
            object.__setattr__(self, "_adj_bits", bits)
            cached = bits
        return cached

    def to_networkx(self):
        import networkx as nx

        g = nx.Graph()
        for v in range(self.n_vertices):
            g.add_node(v, label=int(self.labels[v]))
        g.add_edges_from(self.edge_list.tolist())
        return g

    def serialize(self) -> bytes:
        """Canonical byte image (used for CRC32 integrity in migration)."""
        head = np.asarray(
            [self.n_vertices, self.n_edges], dtype=np.int64
        ).tobytes()
        return (
            head
            + self.labels.astype(np.int32).tobytes()
            + self.edge_list.astype(np.int32).tobytes()
        )

    @staticmethod
    def deserialize(blob: bytes) -> "LabeledGraph":
        n, m = np.frombuffer(blob[:16], dtype=np.int64)
        off = 16
        labels = np.frombuffer(blob[off : off + 4 * n], dtype=np.int32).copy()
        off += 4 * int(n)
        edges = np.frombuffer(blob[off : off + 8 * m], dtype=np.int32).reshape(
            int(m), 2
        ).copy()
        return LabeledGraph.from_edges(int(n), edges, labels)


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """A streaming update batch against a LabeledGraph.

    Semantics (global vertex ids are STABLE — the invariant every shard
    index, owner rule, and cached embedding relies on):

      * ``add_vertex_labels``: new vertices appended with ids
        n .. n+k-1 and the given labels;
      * ``del_vertices``: DETACH — all incident edges are removed and
        the label is kept as a tombstone (a detached vertex can no
        longer match any query vertex with degree >= 1, which is every
        vertex of a connected query).  At the graph level a detached id
        is just an isolated vertex; RETIREMENT across batches (no later
        edge may re-attach it) is enforced by the engine
        (`DistributedGNNPE.apply_updates` tracks `retired_ids`) — this
        function only rejects re-attachment within the same delta;
      * ``add_edges`` / ``del_edges``: undirected edge inserts/deletes,
        canonicalized like `LabeledGraph.from_edges` (u < v, self-loops
        dropped, duplicates collapsed).  Inserting a present edge or
        deleting an absent one is a recorded no-op, not an error.
    """

    add_vertex_labels: np.ndarray    # int32 [k] labels of appended vertices
    del_vertices: np.ndarray         # int64 [j] ids to detach
    add_edges: np.ndarray            # int32 [a, 2]
    del_edges: np.ndarray            # int32 [d, 2]

    @staticmethod
    def make(add_vertex_labels=(), del_vertices=(), add_edges=(),
             del_edges=()) -> "GraphDelta":
        return GraphDelta(
            add_vertex_labels=np.asarray(add_vertex_labels,
                                         np.int32).reshape(-1),
            del_vertices=np.asarray(del_vertices, np.int64).reshape(-1),
            add_edges=np.asarray(add_edges, np.int32).reshape(-1, 2),
            del_edges=np.asarray(del_edges, np.int32).reshape(-1, 2))

    @property
    def is_empty(self) -> bool:
        return (self.add_vertex_labels.size == 0
                and self.del_vertices.size == 0
                and self.add_edges.size == 0 and self.del_edges.size == 0)


def apply_graph_delta(graph: LabeledGraph, delta: GraphDelta
                      ) -> tuple[LabeledGraph, dict]:
    """Apply a GraphDelta; returns (new_graph, info).

    ``info`` reports what actually changed:
      * ``seeds``: int64 global ids whose local structure changed —
        endpoints of every inserted/deleted edge, detached vertices,
        and appended vertices.  These drive both the dirty-vertex
        forcing and the touched-shard blast zone of the incremental
        re-index.
      * ``n_added_edges`` / ``n_removed_edges``: effective counts after
        no-op filtering;
      * ``n_added_vertices`` / ``n_detached_vertices``.

    Raises ValueError on out-of-range endpoints or detach targets (an
    update referencing a vertex that does not exist is a routing bug,
    not a no-op).
    """
    n_old = graph.n_vertices
    n_new = n_old + int(delta.add_vertex_labels.size)
    det = np.unique(delta.del_vertices)
    if det.size and (det.min() < 0 or det.max() >= n_new):
        raise ValueError("detach target out of range")
    for e in (delta.add_edges, delta.del_edges):
        if e.size and (e.min() < 0 or e.max() >= n_new):
            raise ValueError("edge endpoint out of range")

    old = graph.edge_list.astype(np.int64)
    old_keys = old[:, 0] * n_new + old[:, 1]
    adds = _canon_edges(delta.add_edges)
    dels = _canon_edges(delta.del_edges)
    # edges incident to a detached vertex are deleted implicitly
    if det.size:
        det_mask = np.zeros(n_new, bool)
        det_mask[det] = True
        implicit = old[det_mask[old[:, 0]] | det_mask[old[:, 1]]]
        dels = _canon_edges(np.concatenate([dels, implicit])) \
            if dels.size else implicit
        if adds.size:               # adding an edge onto a detached id
            bad = det_mask[adds[:, 0]] | det_mask[adds[:, 1]]
            if bad.any():
                raise ValueError("cannot add an edge on a detached vertex")
    del_keys = dels[:, 0] * n_new + dels[:, 1] if dels.size else \
        np.zeros(0, np.int64)
    add_keys = adds[:, 0] * n_new + adds[:, 1] if adds.size else \
        np.zeros(0, np.int64)
    # an edge in BOTH lists has no well-defined outcome (it would
    # depend on whether the edge was already present): reject instead
    # of silently picking a state-dependent winner.  Note implicit
    # detach-deletes are exempt — adds onto detached ids already raised.
    if np.isin(add_keys, del_keys).any():
        raise ValueError("edge listed in both add_edges and del_edges")

    removed = np.isin(old_keys, del_keys)          # present AND deleted
    really_added = ~np.isin(add_keys, old_keys)    # absent AND inserted
    kept = old[~removed]
    new_edges = np.concatenate([kept, adds[really_added]]) if adds.size \
        else kept
    labels = np.concatenate([graph.labels,
                             delta.add_vertex_labels.astype(np.int32)])
    new_graph = LabeledGraph.from_edges(n_new, new_edges, labels)

    changed = np.concatenate([
        old[removed].ravel(),
        adds[really_added].ravel() if adds.size else np.zeros(0, np.int64),
        det,
        np.arange(n_old, n_new, dtype=np.int64)])
    info = {
        "seeds": np.unique(changed),
        "n_added_edges": int(really_added.sum()),
        "n_removed_edges": int(removed.sum()),
        "n_added_vertices": int(delta.add_vertex_labels.size),
        "n_detached_vertices": int(det.size),
    }
    return new_graph, info


def degree_stats(graph: LabeledGraph) -> dict[str, float]:
    d = graph.degrees
    return {
        "avg_degree": float(d.mean()) if d.size else 0.0,
        "max_degree": float(d.max()) if d.size else 0.0,
        "power_law_gamma": power_law_exponent(d),
    }


def power_law_exponent(degrees: np.ndarray, d_min: int = 1) -> float:
    """MLE estimate of the power-law exponent gamma, P(d) ~ d^-gamma.

    Clauset-Shalizi-Newman continuous MLE restricted to d >= d_min.  Used as a
    shard-level feature for the PE-score model (paper section 6.2.1).
    """
    d = degrees[degrees >= max(d_min, 1)].astype(np.float64)
    if d.size < 2:
        return 0.0
    logs = np.log(d / (max(d_min, 1) - 0.5 + 0.5))  # continuous correction
    s = logs.sum()
    if s <= 0:
        return 0.0
    return float(1.0 + d.size / s)
