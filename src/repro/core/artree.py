"""Array-packed aggregate R-tree (aR-tree) over path dominance embeddings.

Pointer-chasing R-trees are hostile to TPUs, so the index is adapted to a
*packed, level-order array layout* (DESIGN.md §3):

  * bulk load: points are sorted by a monotone space-filling key (the sum of
    normalized dims — ideal for dominance probes, which prune on upper
    bounds), then packed bottom-up with branching factor B; children of node
    i at level k are exactly nodes [i*B, (i+1)*B) at level k+1.
  * every node stores its box (lower/upper over descendants) and the
    aggregate leaf count (the "a" in aR-tree).
  * a dominance probe o(p_q) descends level-by-level: a subtree survives iff
    all_j q[j] <= upper[j] (+eps).  Host traversal short-circuits dead
    subtrees (numpy); the device path evaluates whole levels as dense masked
    AND-reduces (see repro/kernels/dominance for the Pallas leaf filter).

Zero false dismissals: for a true match, q <= z element-wise, and z <= upper
for every ancestor box of z, so no ancestor is ever pruned.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ARTree", "build_artree", "reload_artree", "query_dominating",
           "query_stats", "batched_query_dominating"]


@dataclasses.dataclass(frozen=True)
class ARTree:
    """Packed aR-tree.

    Attributes:
      lowers/uppers: per level (root -> leaf-parents), float32 [M_k, D] boxes.
      counts:        per level, int64 [M_k] aggregate leaf counts.
      points:        float32 [N, D] leaf points in packed (sorted) order.
      perm:          int64 [N] original index of packed point i.
      branching:     fan-out B.
    """

    lowers: list[np.ndarray]
    uppers: list[np.ndarray]
    counts: list[np.ndarray]
    points: np.ndarray
    perm: np.ndarray
    branching: int

    @property
    def n_points(self) -> int:
        return int(self.points.shape[0])

    @property
    def n_levels(self) -> int:
        return len(self.lowers)

    @property
    def dim(self) -> int:
        return int(self.points.shape[1])

    def nbytes(self) -> int:
        total = self.points.nbytes + self.perm.nbytes
        for lo, up, c in zip(self.lowers, self.uppers, self.counts):
            total += lo.nbytes + up.nbytes + c.nbytes
        return total

    def mbr_summary(self) -> bytes:
        """Root MBR summary broadcast by the central node (<1KB metadata)."""
        if not self.lowers:
            return b""
        return (self.lowers[0].tobytes() + self.uppers[0].tobytes()
                + np.int64(self.n_points).tobytes())

    def serialize(self) -> bytes:
        """Canonical byte image (migrated verbatim; CRC32'd in Algorithm 1)."""
        import io
        buf = io.BytesIO()
        np.savez(buf, points=self.points, perm=self.perm,
                 branching=np.int64(self.branching),
                 n_levels=np.int64(self.n_levels),
                 **{f"lo{k}": self.lowers[k] for k in range(self.n_levels)},
                 **{f"up{k}": self.uppers[k] for k in range(self.n_levels)},
                 **{f"ct{k}": self.counts[k] for k in range(self.n_levels)})
        return buf.getvalue()

    @staticmethod
    def deserialize(blob: bytes) -> "ARTree":
        import io
        z = np.load(io.BytesIO(blob))
        n_levels = int(z["n_levels"])
        return ARTree(
            lowers=[z[f"lo{k}"] for k in range(n_levels)],
            uppers=[z[f"up{k}"] for k in range(n_levels)],
            counts=[z[f"ct{k}"] for k in range(n_levels)],
            points=z["points"], perm=z["perm"],
            branching=int(z["branching"]),
        )


def build_artree(points: np.ndarray, branching: int = 16) -> ARTree:
    """Bulk-load a packed aR-tree from [N, D] float32 points."""
    points = np.asarray(points, dtype=np.float32)
    n, d = points.shape
    if n == 0:
        return ARTree([], [], [], points, np.zeros(0, np.int64), branching)
    # monotone space-filling sort key: sum of min-max normalized dims
    lo, hi = points.min(axis=0), points.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    key = ((points - lo) / span).sum(axis=1)
    perm = np.argsort(key, kind="stable").astype(np.int64)
    pts = points[perm]

    lowers: list[np.ndarray] = []
    uppers: list[np.ndarray] = []
    counts: list[np.ndarray] = []
    cur_lo, cur_up = pts, pts
    cur_ct = np.ones(n, dtype=np.int64)
    while cur_lo.shape[0] > 1:
        m = cur_lo.shape[0]
        n_parents = (m + branching - 1) // branching
        pad = n_parents * branching - m
        lo_p = np.concatenate([cur_lo, np.full((pad, d), np.inf, np.float32)])
        up_p = np.concatenate([cur_up, np.full((pad, d), -np.inf, np.float32)])
        ct_p = np.concatenate([cur_ct, np.zeros(pad, np.int64)])
        cur_lo = lo_p.reshape(n_parents, branching, d).min(axis=1)
        cur_up = up_p.reshape(n_parents, branching, d).max(axis=1)
        cur_ct = ct_p.reshape(n_parents, branching).sum(axis=1)
        lowers.append(cur_lo)
        uppers.append(cur_up)
        counts.append(cur_ct)
    lowers.reverse(); uppers.reverse(); counts.reverse()
    return ARTree(lowers, uppers, counts, pts, perm, branching)


def reload_artree(old: ARTree | None, points: np.ndarray) -> ARTree:
    """Bulk reload for the incremental update path.

    Packed level-order aR-trees have no cheap in-place insert (children
    of node i must stay exactly [i*B, (i+1)*B)), so index maintenance is
    a BULK RELOAD of the touched tree from its refreshed point set —
    R-tree folklore: bulk loading beats repeated insertion long before
    the update batch reaches the leaf count.  The builder is the same
    deterministic `build_artree`, so a reloaded tree is bit-identical to
    a from-scratch build on the same embedding matrix (the property the
    rebuild-equivalence test pins); `old` only carries the branching
    factor forward.
    """
    branching = old.branching if old is not None else 16
    return build_artree(points, branching=branching)


def query_dominating(tree: ARTree, q: np.ndarray, eps: float = 1e-5
                     ) -> tuple[np.ndarray, dict[str, int]]:
    """All ORIGINAL point indices z with q <= z element-wise.

    Host short-circuit traversal; returns (indices, stats) where stats counts
    nodes visited/pruned per level (feeds Prune(S_i) and PE-score labels).
    """
    n = tree.n_points
    stats = {"nodes_visited": 0, "nodes_pruned": 0, "leaves_tested": 0}
    if n == 0:
        return np.zeros(0, np.int64), stats
    q = np.asarray(q, dtype=np.float32)
    b = tree.branching
    alive = np.arange(tree.lowers[0].shape[0], dtype=np.int64) if tree.lowers \
        else np.zeros(0, np.int64)
    for lvl in range(tree.n_levels):
        up = tree.uppers[lvl][alive]
        ok = (q[None, :] <= up + eps).all(axis=1)
        stats["nodes_visited"] += int(alive.size)
        stats["nodes_pruned"] += int((~ok).sum())
        alive = alive[ok]
        if lvl + 1 < tree.n_levels:
            nxt = tree.lowers[lvl + 1].shape[0]
            child = (alive[:, None] * b + np.arange(b)[None, :]).ravel()
            alive = child[child < nxt]
        else:
            child = (alive[:, None] * b + np.arange(b)[None, :]).ravel()
            alive = child[child < n]
    if tree.n_levels == 0:  # single point, no internal levels
        alive = np.arange(n, dtype=np.int64)
    stats["leaves_tested"] = int(alive.size)
    ok = (q[None, :] <= tree.points[alive] + eps).all(axis=1)
    return tree.perm[alive[ok]], stats


def _tree_rows(tree: ARTree) -> np.ndarray:
    """All box rows of a tree, level-order root->leaf-parents, then the
    leaf points — the row layout of the batched device probe slab."""
    if tree.n_points == 0:
        return np.zeros((0, tree.points.shape[1]), np.float32)
    return np.concatenate(tree.uppers + [tree.points], axis=0)


def batched_query_dominating(trees: list[ARTree], queries: np.ndarray,
                             eps: float = 1e-5,
                             use_pallas: bool | None = None
                             ) -> tuple[list[list[np.ndarray]],
                                        dict[str, int]]:
    """Probe Q query embeddings against S packed aR-trees in ONE launch.

    The device probe path (DESIGN.md §3): every tree's internal-node
    upper bounds (all levels, root first) and its leaf points are
    concatenated into a single padded ``[S, R_max, D]`` slab with
    per-shard valid counts, one batched dominance launch
    (`repro.kernels.dominance.batched_dominance_mask`) evaluates
    ``ok[s, q, r]`` for every node and leaf at once, and survivorship is
    then propagated level-order as dense masked AND-reduces: a node is
    alive iff its packed parent is alive and its own box passes.

    Returns ``(hits, stats)``: ``hits[s][q]`` is the int64 array of
    ORIGINAL point indices dominated by ``queries[q]`` in ``trees[s]`` —
    identical in value and order to ``query_dominating(trees[s],
    queries[q])[0]`` — and ``stats`` aggregates the same counters the
    host traversal reports, plus ``device_launches`` (always 1 when any
    tree is non-empty).
    """
    queries = np.asarray(queries, dtype=np.float32)
    n_q = queries.shape[0]
    stats = {"nodes_visited": 0, "nodes_pruned": 0, "leaves_tested": 0,
             "device_launches": 0, "h2d_bytes": 0, "d2h_bytes": 0}
    hits: list[list[np.ndarray]] = [
        [np.zeros(0, np.int64) for _ in range(n_q)] for _ in trees]
    rows = [_tree_rows(t) for t in trees]
    counts = np.array([r.shape[0] for r in rows], np.int32)
    r_max = int(counts.max()) if counts.size else 0
    if r_max == 0:
        return hits, stats

    import jax.numpy as jnp

    from repro.kernels.dominance.ops import (ROW_BUCKET, SHARD_BUCKET,
                                             batched_dominance_mask, bucket)

    d = queries.shape[1]
    # bucket both slab dims to kernel-block multiples: the probed shard
    # set and max row count vary per query path, and an exact-shape slab
    # would retrace the jitted kernel on nearly every path.  Block
    # multiples bound the distinct compiled shapes while capping the
    # padded compute at one extra block per dim (pow2 rounding was
    # measurably slower on CPU).  Pad shards have count 0 and -inf
    # rows, so they can never produce a candidate.
    s_pad = bucket(len(trees), SHARD_BUCKET)
    r_pad = bucket(r_max, ROW_BUCKET)
    slab = np.full((s_pad, r_pad, d), -np.inf, np.float32)
    for s, r in enumerate(rows):
        slab[s, :r.shape[0]] = r
    counts = np.pad(counts, (0, s_pad - counts.size))
    stats["h2d_bytes"] = slab.nbytes + queries.nbytes + counts.nbytes
    ok_all = np.asarray(batched_dominance_mask(
        jnp.asarray(queries), jnp.asarray(slab), jnp.asarray(counts),
        eps=eps, use_pallas=use_pallas)).astype(bool)[:len(trees)]
    stats["device_launches"] = 1
    stats["d2h_bytes"] = s_pad * n_q * r_pad          # dense int8 readback

    for s, tree in enumerate(trees):
        n = tree.n_points
        if n == 0:
            continue
        b = tree.branching
        level_sizes = [u.shape[0] for u in tree.uppers]
        offsets = np.cumsum([0] + level_sizes)
        ok = ok_all[s]                                # [n_q, rows]
        if level_sizes:
            # root level: every node is a candidate, exactly as the host
            # traversal starts from the full root array; survivorship is
            # propagated for ALL queries at once (vectorized fallback —
            # the device path fuses this into the launch instead, see
            # repro/kernels/dominance/ops.fused_plan_descent)
            alive = np.ones((n_q, level_sizes[0]), bool)
            for lvl, m in enumerate(level_sizes):
                cand = alive
                alive = cand & ok[:, offsets[lvl]:offsets[lvl] + m]
                stats["nodes_visited"] += int(cand.sum())
                stats["nodes_pruned"] += int(cand.sum() - alive.sum())
                nxt = level_sizes[lvl + 1] if lvl + 1 < len(level_sizes) \
                    else n
                alive = np.repeat(alive, b, axis=1)[:, :nxt]
        else:                       # single point, no internal levels
            alive = np.ones((n_q, n), bool)
        stats["leaves_tested"] += int(alive.sum())
        final = alive & ok[:, offsets[-1]:offsets[-1] + n]
        for qi in range(n_q):
            hits[s][qi] = tree.perm[np.flatnonzero(final[qi])]
    return hits, stats


def query_stats(tree: ARTree, q: np.ndarray, eps: float = 1e-5) -> dict[str, float]:
    """Pruning statistics of one probe (pruning rate vs brute force)."""
    idx, stats = query_dominating(tree, q, eps)
    n = max(tree.n_points, 1)
    return {
        "n_candidates": float(idx.size),
        "pruning_rate": 1.0 - stats["leaves_tested"] / n,
        "selectivity": 1.0 - idx.size / n,
        **{k: float(v) for k, v in stats.items()},
    }
