"""Device-resident probe planes: cached shard slabs + whole-plan descent.

PR 2's batched device probe still re-packed every shard's aR-tree rows
into the ``[S, R, D]`` slab on the host for EVERY path of EVERY query,
shipped the dense ``ok[s, q, r]`` mask back, and walked survivorship in
per-query numpy loops.  A *probe plane* removes all three costs:

  * **resident slabs** — each shard tree's rows (`artree._tree_rows`
    layout: every internal level's upper bounds root-first, then the
    leaf points) are packed ONCE at index-build time into a padded
    device block (`TreePlane`), together with the packed-parent pointers
    the descent needs; the rows never cross the host boundary again;
  * **whole-plan assembly** — the planes a query plan probes are stacked
    (device-side, cached across queries) into one ``[S, R_pad, D_pad]``
    slab covering ALL path lengths of the plan: query rows are padded
    with ``-inf`` beyond their own width, which passes every box dim, so
    paths of different lengths share one launch;
  * **candidate-id readback** — one fused launch
    (`repro.kernels.dominance.ops.fused_plan_descent`) evaluates the
    dominance masks AND runs the level-order survivor propagation on
    device; only per-(shard, path) candidate row ids and counters cross
    back (the readback contract), never a dense mask.

Staleness: a plane records the *identity* of the ARTree it was packed
from, and `ClusterPlanes` re-validates on every access — a shard index
replaced by hot migration, failover, or a rebuild can never be served
from a stale plane (property-tested in tests/test_probeplane.py).

All padded shapes are rounded to the named buckets in
`repro.kernels.dominance.ops` so the jitted descent compiles at most
once per (shard-bucket, row-bucket) pair.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict, defaultdict

import numpy as np

from repro.core.artree import ARTree, _tree_rows

__all__ = ["TreePlane", "AssembledPlanes", "PlanProbeResult",
           "ClusterPlanes", "build_tree_plane", "plan_probe",
           "MegaBlock", "MegaAssembly", "MegaInFlight", "MegaProbeResult"]

_PLANE_TOKENS = itertools.count(1)
_MAX_ASSEMBLED = 4          # assembled-slab cache entries kept per cluster
_MAX_MEGA = 4               # megabatch leaf-assembly cache entries


@dataclasses.dataclass(frozen=True)
class TreePlane:
    """One shard tree packed for device residency.

    ``rows`` is the device array (row-bucketed, -inf pad); everything
    else is host metadata the assemble step stacks.  ``tree`` is kept
    solely as the staleness token — `ClusterPlanes` compares it by
    identity against the live index before every use.
    """

    tree: ARTree
    token: int                   # unique per build; keys assembled slabs
    rows: object                 # jnp [R_b, D] device rows
    n_rows: int                  # valid rows (internal levels + leaves)
    n_levels: int
    leaf_offset: int             # first leaf row
    parent: np.ndarray           # int32 [R_b]; self at roots and pads
    is_root: np.ndarray          # bool [R_b]
    internal: np.ndarray         # bool [R_b] valid internal-node rows
    leaf: np.ndarray             # bool [R_b] valid leaf rows

    @property
    def device_nbytes(self) -> int:
        return int(self.rows.size) * 4


def build_tree_plane(tree: ARTree, device=None) -> TreePlane:
    """Pack one non-empty aR-tree into its device-resident plane.

    ``device`` pins the packed rows to a specific jax device — the mesh
    transport homes each machine's planes on that machine's local device
    (`ClusterPlanes.device_of`); the default commits to the launch
    device exactly as before."""
    import jax.numpy as jnp

    from repro.kernels.dominance.ops import ROW_BUCKET, bucket

    rows = _tree_rows(tree)
    n_rows, d = rows.shape
    r_b = bucket(n_rows, ROW_BUCKET)
    padded = np.full((r_b, d), -np.inf, np.float32)
    padded[:n_rows] = rows

    level_sizes = [u.shape[0] for u in tree.uppers]
    offsets = np.cumsum([0] + level_sizes)
    b = tree.branching
    parent = np.arange(r_b, dtype=np.int32)       # self: roots + pad rows
    is_root = np.zeros(r_b, bool)
    if level_sizes:
        is_root[:level_sizes[0]] = True
        for k in range(1, len(level_sizes)):
            j = np.arange(level_sizes[k], dtype=np.int32)
            parent[offsets[k] + j] = offsets[k - 1] + j // b
        j = np.arange(tree.n_points, dtype=np.int32)
        parent[offsets[-1] + j] = offsets[-2] + j // b
    else:                       # single point: the leaf is its own root
        is_root[:tree.n_points] = True
    internal = np.zeros(r_b, bool)
    internal[:offsets[-1]] = True
    leaf = np.zeros(r_b, bool)
    leaf[offsets[-1]:n_rows] = True
    if device is not None:
        import jax
        dev_rows = jax.device_put(padded, device)
    else:
        dev_rows = jnp.asarray(padded)
    return TreePlane(tree=tree, token=next(_PLANE_TOKENS),
                     rows=dev_rows, n_rows=n_rows,
                     n_levels=tree.n_levels, leaf_offset=int(offsets[-1]),
                     parent=parent, is_root=is_root, internal=internal,
                     leaf=leaf)


@dataclasses.dataclass(frozen=True)
class AssembledPlanes:
    """A set of planes stacked into one launchable slab (device arrays).

    The shard axis is bucketed; pad planes have count 0, -inf rows and
    all-False role masks, so they can never produce a candidate.
    """

    keys: tuple                  # ((sid, length), ...) slab order
    slot: dict                   # (sid, length) -> shard-axis index
    lengths: np.ndarray          # int32 [S_b]; -1 on pad planes
    slab: object                 # jnp [S_b, R_b, D_pad]
    counts: object               # jnp int32 [S_b]
    parent: object               # jnp int32 [S_b, R_b]
    is_root: object              # jnp bool [S_b, R_b]
    internal: object             # jnp bool [S_b, R_b]
    leaf: object                 # jnp bool [S_b, R_b]
    leaf_offsets: np.ndarray     # int64 [S_b]
    perms: list                  # per real plane: tree.perm (host)
    d_pad: int
    n_iter: int                  # bucketed max tree depth
    assembled_bytes: int         # host->device bytes this assembly moved


def _assemble(planes: list[TreePlane], keys: list[tuple]) -> AssembledPlanes:
    import jax.numpy as jnp

    from repro.kernels.dominance.ops import (DEPTH_BUCKET, SHARD_BUCKET,
                                             bucket)

    s_b = bucket(len(planes), SHARD_BUCKET)
    r_b = max(int(p.rows.shape[0]) for p in planes)
    d_pad = max(int(p.rows.shape[1]) for p in planes)
    n_iter = bucket(max(p.n_levels for p in planes), DEPTH_BUCKET)

    moved = 0
    slabs = []
    for p in planes:
        rows = p.rows               # already resident: no host bytes move
        pr, pd = int(rows.shape[0]), int(rows.shape[1])
        if pr < r_b or pd < d_pad:  # device-side pad up to the common slab
            rows = jnp.pad(rows, ((0, r_b - pr), (0, d_pad - pd)),
                           constant_values=-jnp.inf)
        slabs.append(rows)
    pad_planes = s_b - len(planes)
    if pad_planes:
        slabs.append(jnp.full((pad_planes, r_b, d_pad), -jnp.inf,
                              jnp.float32))
    slab = jnp.concatenate(
        [jnp.stack(slabs[:len(planes)])] + slabs[len(planes):], axis=0) \
        if pad_planes else jnp.stack(slabs)

    def stack_meta(field: str, fill) -> np.ndarray:
        out = np.full((s_b, r_b), fill,
                      getattr(planes[0], field).dtype)
        for i, p in enumerate(planes):
            out[i, :p.parent.shape[0]] = getattr(p, field)
        return out

    parent = stack_meta("parent", 0)
    for i in range(s_b):            # pad rows/planes: self-parented
        tail = planes[i].parent.shape[0] if i < len(planes) else 0
        parent[i, tail:] = np.arange(tail, r_b, dtype=np.int32)
    is_root = stack_meta("is_root", False)
    internal = stack_meta("internal", False)
    leaf = stack_meta("leaf", False)
    counts = np.zeros(s_b, np.int32)
    counts[:len(planes)] = [p.n_rows for p in planes]
    lengths = np.full(s_b, -1, np.int32)
    lengths[:len(planes)] = [l for _, l in keys]
    moved += (parent.nbytes + is_root.nbytes + internal.nbytes
              + leaf.nbytes + counts.nbytes)
    return AssembledPlanes(
        keys=tuple(keys),
        slot={k: i for i, k in enumerate(keys)},
        lengths=lengths, slab=slab,
        counts=jnp.asarray(counts), parent=jnp.asarray(parent),
        is_root=jnp.asarray(is_root), internal=jnp.asarray(internal),
        leaf=jnp.asarray(leaf),
        leaf_offsets=np.array([p.leaf_offset for p in planes]
                              + [0] * pad_planes, np.int64),
        perms=[p.tree.perm for p in planes],
        d_pad=d_pad, n_iter=n_iter, assembled_bytes=moved)


@dataclasses.dataclass
class PlanProbeResult:
    """Readback of one whole-plan launch: candidate ids + counters only."""

    assembled: AssembledPlanes
    counts: np.ndarray           # int32 [S_b, Q_b]
    cand_rows: np.ndarray        # int32 [S_b, Q_b, C_max] slab row ids
    nodes_visited: np.ndarray    # int32 [S_b, Q_b]
    nodes_pruned: np.ndarray     # int32 [S_b, Q_b]
    leaves_tested: np.ndarray    # int32 [S_b, Q_b]
    h2d_bytes: int
    d2h_bytes: int

    def hits(self, sid: int, length: int, qrow: int) -> np.ndarray:
        """ORIGINAL point indices dominated by query row `qrow` in the
        (sid, length) tree — identical in value and order to the host
        `query_dominating` output."""
        s = self.assembled.slot[(sid, length)]
        k = int(self.counts[s, qrow])
        local = (self.cand_rows[s, qrow, :k].astype(np.int64)
                 - self.assembled.leaf_offsets[s])
        return self.assembled.perms[s][local]

    def counters(self, sid: int, length: int, qrow: int) -> dict[str, int]:
        s = self.assembled.slot[(sid, length)]
        return {"nodes_visited": int(self.nodes_visited[s, qrow]),
                "nodes_pruned": int(self.nodes_pruned[s, qrow]),
                "leaves_tested": int(self.leaves_tested[s, qrow])}


def plan_probe(assembled: AssembledPlanes,
               queries: list[tuple[np.ndarray, int]], eps: float = 1e-5,
               use_pallas: bool | None = None) -> PlanProbeResult:
    """Probe every (embedding, length) query row of a plan in ONE launch.

    Rows narrower than the slab width are padded with -inf (passes every
    box dim); pad rows past the real count hold +inf (match nothing) and
    carry pair_valid=False.  Readback is counts + the leading candidate
    id columns + counters — the dense mask never crosses back.
    """
    import jax.numpy as jnp

    from repro.kernels.dominance.ops import (QUERY_BUCKET, bucket,
                                             fused_plan_descent)

    n_q = len(queries)
    q_b = bucket(max(n_q, 1), QUERY_BUCKET)
    qmat = np.full((q_b, assembled.d_pad), np.inf, np.float32)
    q_len = np.full(q_b, -2, np.int32)          # never matches a plane
    for i, (emb, length) in enumerate(queries):
        emb = np.asarray(emb, np.float32).ravel()
        qmat[i, :emb.size] = emb
        qmat[i, emb.size:] = -np.inf
        q_len[i] = length
    pair_valid = assembled.lengths[:, None] == q_len[None, :]

    n_cand, order, nv, npr, lt = fused_plan_descent(
        jnp.asarray(qmat), assembled.slab, assembled.counts,
        assembled.parent, assembled.is_root, assembled.internal,
        assembled.leaf, jnp.asarray(pair_valid), eps=eps,
        n_iter=assembled.n_iter, use_pallas=use_pallas)

    counts = np.asarray(n_cand)
    c_max = int(counts.max()) if counts.size else 0
    cand_rows = np.asarray(order[:, :, :c_max])  # device slice, then ship
    nv, npr, lt = np.asarray(nv), np.asarray(npr), np.asarray(lt)
    return PlanProbeResult(
        assembled=assembled, counts=counts, cand_rows=cand_rows,
        nodes_visited=nv, nodes_pruned=npr, leaves_tested=lt,
        h2d_bytes=qmat.nbytes + pair_valid.nbytes,
        d2h_bytes=(counts.nbytes + cand_rows.nbytes + nv.nbytes
                   + npr.nbytes + lt.nbytes))


def pack_mask_bits(masks: list, n_bits: int) -> np.ndarray:
    """Pack per-(query, vertex) bool candidate masks into the shared
    uint32 bit operand of `megabatch_leaf_probe`.

    The row count tracks the batch's total query-vertex count, which
    varies with every batch composition — rows are therefore padded to
    MASK_ROW_BUCKET so the fused launch's compiled shape is reused
    across batch mixes (pad rows are all-zero bits and never referenced
    by any mask_rows index).  Width is ``n_bits`` packed to whole
    32-bit words; the uint32 view is the wire dtype KERNEL_CONTRACTS
    declares for the in-kernel mask gather.
    """
    from repro.kernels.dominance.ops import MASK_ROW_BUCKET, bucket

    w = -(-n_bits // 32)
    r_b = bucket(max(len(masks), 1), MASK_ROW_BUCKET)
    by = np.packbits(np.stack(masks), axis=1, bitorder="little")
    words = np.zeros((r_b, w * 4), np.uint8)
    words[:by.shape[0], :by.shape[1]] = by
    return words.view(np.uint32)


# --------------------------------------------------------------------------- #
# megabatch leaf assemblies (multi-query fused workload execution, PR 4)
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class MegaBlock:
    """One path length's leaf block of a megabatch assembly.

    The leaf slab is sliced device-side out of the resident `TreePlane`
    rows (zero slab bytes host->device when the planes are warm); the
    per-leaf global-vertex table `gverts` is the in-kernel mask-filter
    operand and crosses host->device once per cold assembly.
    """

    length: int
    sids: tuple                  # shard-axis order
    slot: dict                   # sid -> shard-axis index
    trees: tuple                 # packed-from ARTree identities (the
                                 # staleness signature MegaAssembly.stale
                                 # compares against the live index)
    leaves: object               # jnp [S_b, N_b, D] leaf points, -inf pad
    counts_dev: object           # jnp int32 [S_b] valid leaves
    gverts_dev: object           # jnp int32 [S_b, N_b, l+1]
    n_points: np.ndarray         # int64 [S_b]
    gverts_host: np.ndarray      # int32 [S_b, N_b, l+1] (consume-side copy)
    up_max: np.ndarray           # float32 [S_real, D] root-MBR upper bound
    n_b: int
    d: int


@dataclasses.dataclass(frozen=True)
class MegaAssembly:
    """Per-length megabatch leaf blocks + the staleness signature."""

    blocks: dict                 # length -> MegaBlock
    keys: frozenset              # {(sid, length)} for invalidation matching
    assembled_bytes: int

    def stale(self, live_trees: dict) -> bool:
        """True iff any packed tree was replaced behind the cache's back
        (``live_trees`` maps (sid, length) -> the live ARTree)."""
        for blk in self.blocks.values():
            for sid, tree in zip(blk.sids, blk.trees):
                if live_trees.get((sid, blk.length)) is not tree:
                    return True
        return False

    def stale_keys(self, live_trees: dict) -> set:
        """The exact ``{(sid, length)}`` lanes whose packed tree no
        longer matches the live index — so a consumer can fall back
        *per shard* (re-probe just those lanes on the host) instead of
        discarding the whole batch the way :meth:`stale` forces."""
        out = set()
        for blk in self.blocks.values():
            for sid, tree in zip(blk.sids, blk.trees):
                if live_trees.get((sid, blk.length)) is not tree:
                    out.add((sid, blk.length))
        return out


@dataclasses.dataclass
class MegaInFlight:
    """A dispatched (not yet read back) megabatch probe.

    ``finals`` stay device-resident until `mega_readback` gathers the
    candidate-bearing lanes; holding this object is what lets the
    workload loop overlap batch k+1's launch with batch k's join.
    """

    assembly: MegaAssembly
    lengths: tuple               # block order of finals/counts
    finals: tuple                # per length: jnp bool [S_b, Q_b, N_b]
    counts_dev: tuple            # per length: jnp int32 [S_b, Q_b]
    launches: int = 1


@dataclasses.dataclass
class MegaProbeResult:
    """Readback of a megabatch launch: per-lane counts + packed
    candidate bits for candidate-bearing lanes only (pre-filtered by the
    in-kernel mask operand — the dense mask never crosses back)."""

    assembly: MegaAssembly
    counts: dict                 # length -> int32 [S_b, Q_b]
    lane_of: dict                # (length, slot, qrow) -> packed row
    packed: np.ndarray | None    # uint8 [K, N_max // 8]
    d2h_bytes: int
    launches: int

    def candidates(self, length: int, sid: int, qrow: int) -> np.ndarray:
        """Ascending PACKED-LEAF ids surviving dominance + the query's
        label/degree masks for (sid, length, query row)."""
        blk = self.assembly.blocks[length]
        s = blk.slot[sid]
        if int(self.counts[length][s, qrow]) == 0:
            return np.zeros(0, np.int64)
        row = self.packed[self.lane_of[(length, s, qrow)]]
        bits = np.unpackbits(row, bitorder="little")[:blk.n_b]
        return np.flatnonzero(bits)


class ClusterPlanes:
    """Per-cluster plane cache: build -> resident -> invalidate.

    Planes are built at index-build time (`build_shard`), served resident
    across queries, and invalidated on hot migration / rebalancing /
    machine failure (`invalidate`) — with an identity re-check on every
    access as the backstop, so even an index swapped behind the cache's
    back (e.g. a direct `hot_migrate` call) is repacked before use.
    """

    def __init__(self) -> None:
        self._planes: dict[tuple[int, int], TreePlane] = {}
        self._assembled: OrderedDict[tuple, AssembledPlanes] = OrderedDict()
        self._mega: OrderedDict[tuple, MegaAssembly] = OrderedDict()
        # transport hook: sid -> jax device its plane is pinned to.
        # None (default) = launch device, the single-device behavior.
        self.device_of = None
        self.stats = {"plane_builds": 0, "invalidations": 0,
                      "assembles": 0, "assemble_reuses": 0, "probes": 0,
                      "mega_assembles": 0, "mega_assemble_reuses": 0,
                      "mega_probes": 0,
                      "h2d_bytes": 0, "d2h_bytes": 0, "gather_bytes": 0}

    def resident_bytes(self) -> int:
        """Total device bytes held: per-tree planes PLUS the assembled
        slab copies (each a padded stack of every included plane)."""
        return (sum(p.device_nbytes for p in self._planes.values())
                + sum(int(a.slab.size) * 4
                      for a in self._assembled.values())
                + sum(sum(int(b.leaves.size) * 4 + int(b.gverts_dev.size) * 4
                          for b in m.blocks.values())
                      for m in self._mega.values()))

    def plane(self, sid: int, length: int, tree: ARTree) -> TreePlane:
        """The resident plane for (sid, length); rebuilt iff stale."""
        key = (sid, length)
        cached = self._planes.get(key)
        if cached is not None and cached.tree is tree:
            return cached
        if cached is not None:      # index replaced behind our back
            self._drop(key)
        device = self.device_of(sid) if self.device_of else None
        plane = build_tree_plane(tree, device=device)
        self._planes[key] = plane
        self.stats["plane_builds"] += 1
        self.stats["h2d_bytes"] += plane.device_nbytes
        return plane

    def _gathered(self, plane: TreePlane) -> TreePlane:
        """The plane with rows on the LAUNCH device.

        Assembly stacks rows from many planes into one slab, which JAX
        requires to be co-located — with per-machine pinning active the
        remote-homed planes are pulled to the launch device here, each
        pull metered as `gather_bytes` (the mesh cross-device traffic),
        never as `h2d_bytes` (which feeds per-query telemetry and must
        stay bit-identical across backends)."""
        if self.device_of is None:
            return plane
        import jax
        launch = jax.devices()[0]
        if next(iter(plane.rows.devices())) == launch:
            return plane
        rows = jax.device_put(plane.rows, launch)
        self.stats["gather_bytes"] += plane.device_nbytes
        return dataclasses.replace(plane, rows=rows)

    def build_shard(self, sid: int, index) -> None:
        """Eagerly pack every non-empty tree of a freshly built index."""
        for length, tree in index.trees.items():
            if tree.n_points:
                self.plane(sid, length, tree)

    def invalidate(self, sid: int, length: int | None = None) -> None:
        """Drop planes (and assembled slabs) touching a shard.

        ``length=None`` drops every length of the shard (migration /
        failover replace the whole index); a specific length drops only
        that tree's plane — the streaming-update path uses this so a
        touched shard's UNCHANGED lengths keep their warm slabs (their
        tree objects survive the re-index by identity, so the resident
        rows are still exact).
        """
        for key in [k for k in self._planes
                    if k[0] == sid and (length is None or k[1] == length)]:
            self._drop(key)

    def tokens(self) -> dict[tuple[int, int], int]:
        """(sid, length) -> resident plane token.  A token is unique per
        pack, so an unchanged token across an update PROVES the slab
        never left the device (the zero-h2d claim tests/CI assert)."""
        return {k: p.token for k, p in self._planes.items()}

    def _drop(self, key: tuple[int, int]) -> None:
        self._planes.pop(key, None)
        self.stats["invalidations"] += 1
        for sig in [s for s, a in self._assembled.items()
                    if key in a.slot]:
            del self._assembled[sig]
        for sig in [s for s, m in self._mega.items() if key in m.keys]:
            del self._mega[sig]

    def assemble(self, entries: list[tuple[int, int, ARTree]]
                 ) -> AssembledPlanes:
        """Stack the planes for (sid, length, tree) entries; cached —
        a warm assembly moves zero slab bytes host->device."""
        planes = [self.plane(sid, l, tree) for sid, l, tree in entries]
        keys = [(sid, l) for sid, l, _ in entries]
        sig = tuple(p.token for p in planes)
        hit = self._assembled.get(sig)
        if hit is not None:
            self._assembled.move_to_end(sig)
            self.stats["assemble_reuses"] += 1
            return hit
        # cold assembly: remote-pinned planes gather to the launch device
        planes = [self._gathered(p) for p in planes]
        assembled = _assemble(planes, keys)
        self._assembled[sig] = assembled
        while len(self._assembled) > _MAX_ASSEMBLED:
            self._assembled.popitem(last=False)
        self.stats["assembles"] += 1
        self.stats["h2d_bytes"] += assembled.assembled_bytes
        return assembled

    def probe(self, entries: list[tuple[int, int, ARTree]],
              queries: list[tuple[np.ndarray, int]], eps: float = 1e-5,
              use_pallas: bool | None = None) -> PlanProbeResult:
        """assemble + plan_probe with cache statistics accounting."""
        assembled = self.assemble(entries)
        res = plan_probe(assembled, queries, eps=eps,
                         use_pallas=use_pallas)
        self.stats["probes"] += 1
        self.stats["h2d_bytes"] += res.h2d_bytes
        self.stats["d2h_bytes"] += res.d2h_bytes
        return res

    # ---------------------------------------------------------------- #
    # megabatch path: leaf-only per-length assemblies, two-stage probe
    # ---------------------------------------------------------------- #
    def mega_assemble(self, entries: list[tuple[int, int, ARTree]],
                      gverts_fn) -> MegaAssembly:
        """Per-length leaf blocks for a megabatch launch; cached.

        ``entries`` are (sid, length, live tree); ``gverts_fn(sid,
        length, tree)`` returns the int32 [n_points, length+1] global
        data-vertex ids of the tree's leaves in PACKED order (i.e.
        already permuted by ``tree.perm``) — only called on a cold
        assembly.  Leaf slabs are device-side slices of the resident
        planes, so a warm-plane cold assembly moves only the gverts
        tables host->device; a warm assembly moves nothing.
        """
        import jax.numpy as jnp

        from repro.kernels.dominance.ops import (ROW_BUCKET, SHARD_BUCKET,
                                                 bucket)

        planes = {(sid, l): self.plane(sid, l, tree)
                  for sid, l, tree in entries}
        sig = tuple(sorted((k, p.token) for k, p in planes.items()))
        hit = self._mega.get(sig)
        if hit is not None:
            self._mega.move_to_end(sig)
            self.stats["mega_assemble_reuses"] += 1
            return hit
        # cold assembly: remote-pinned planes gather to the launch device
        planes = {k: self._gathered(p) for k, p in planes.items()}

        moved = 0
        blocks: dict[int, MegaBlock] = {}
        by_length: dict[int, list] = defaultdict(list)
        for sid, l, tree in entries:
            by_length[l].append((sid, tree))
        for l, group in sorted(by_length.items()):
            group.sort(key=lambda e: e[0])
            s_b = bucket(len(group), SHARD_BUCKET)
            n_b = bucket(max(t.n_points for _, t in group), ROW_BUCKET)
            d = int(group[0][1].dim)
            leaf_slabs, gv_host = [], np.zeros((s_b, n_b, l + 1), np.int32)
            counts = np.zeros(s_b, np.int32)
            up_max = np.zeros((len(group), d), np.float32)
            for i, (sid, tree) in enumerate(group):
                p = planes[(sid, l)]
                rows = p.rows[p.leaf_offset:p.leaf_offset + tree.n_points]
                if tree.n_points < n_b:
                    rows = jnp.pad(rows, ((0, n_b - tree.n_points), (0, 0)),
                                   constant_values=-jnp.inf)
                leaf_slabs.append(rows)
                gv = np.asarray(gverts_fn(sid, l, tree), np.int32)
                gv_host[i, :gv.shape[0]] = gv
                counts[i] = tree.n_points
                up_max[i] = (tree.uppers[0].max(axis=0) if tree.uppers
                             else tree.points.max(axis=0))
            if s_b > len(group):
                leaf_slabs.append(jnp.full((s_b - len(group), n_b, d),
                                           -jnp.inf, jnp.float32))
                leaves = jnp.concatenate(
                    [jnp.stack(leaf_slabs[:len(group)]), leaf_slabs[-1]],
                    axis=0)
            else:
                leaves = jnp.stack(leaf_slabs)
            moved += gv_host.nbytes + counts.nbytes
            blocks[l] = MegaBlock(
                length=l,
                sids=tuple(sid for sid, _ in group),
                slot={sid: i for i, (sid, _) in enumerate(group)},
                trees=tuple(t for _, t in group),
                leaves=leaves, counts_dev=jnp.asarray(counts),
                gverts_dev=jnp.asarray(gv_host),
                n_points=counts.astype(np.int64), gverts_host=gv_host,
                up_max=up_max, n_b=n_b, d=d)
        assembly = MegaAssembly(
            blocks=blocks,
            keys=frozenset((sid, l) for sid, l, _ in entries),
            assembled_bytes=moved)
        self._mega[sig] = assembly
        while len(self._mega) > _MAX_MEGA:
            self._mega.popitem(last=False)
        self.stats["mega_assembles"] += 1
        self.stats["h2d_bytes"] += moved
        return assembly

    def mega_dispatch(self, assembly: MegaAssembly,
                      qmat: dict[int, np.ndarray],
                      mask_rows: dict[int, np.ndarray],
                      mask_bits: np.ndarray, eps: float = 1e-5,
                      use_pallas: bool | None = None) -> MegaInFlight:
        """Launch the fused multi-query probe WITHOUT blocking on it.

        ``qmat[l]`` stacks every (path, orientation) embedding row of
        length l across the batch (real rows first); ``mask_rows[l]``
        gives each row's packed-mask row per position; ``mask_bits`` is
        the batch's shared mask operand.  Returns a `MegaInFlight` whose
        device arrays materialize asynchronously — the caller reads them
        back later via `mega_readback`, overlapping this launch with
        host-side work (JAX async dispatch).
        """
        import jax.numpy as jnp

        from repro.kernels.dominance.ops import (megabatch_leaf_probe,
                                                 mega_query_bucket)

        lengths = tuple(sorted(l for l in qmat if l in assembly.blocks
                               and qmat[l].shape[0]))
        h2d = int(mask_bits.nbytes)
        blocks = []
        for l in lengths:
            blk = assembly.blocks[l]
            q = np.asarray(qmat[l], np.float32)
            mr = np.asarray(mask_rows[l], np.int32)
            q_b = mega_query_bucket(q.shape[0])
            if q_b > q.shape[0]:
                q = np.concatenate(
                    [q, np.full((q_b - q.shape[0], q.shape[1]), np.inf,
                                np.float32)])
                mr = np.concatenate(
                    [mr, np.zeros((q_b - mr.shape[0], mr.shape[1]),
                                  np.int32)])
            h2d += q.nbytes + mr.nbytes
            blocks.append((jnp.asarray(q), blk.leaves, blk.counts_dev,
                           blk.gverts_dev, jnp.asarray(mr)))
        if not blocks:
            return MegaInFlight(assembly=assembly, lengths=(), finals=(),
                                counts_dev=(), launches=0)
        out = megabatch_leaf_probe(blocks, jnp.asarray(mask_bits), eps=eps,
                                   use_pallas=use_pallas)
        self.stats["mega_probes"] += 1
        self.stats["h2d_bytes"] += h2d
        return MegaInFlight(
            assembly=assembly, lengths=lengths,
            finals=tuple(f for f, _ in out),
            counts_dev=tuple(c for _, c in out))

    def mega_readback(self, flight: MegaInFlight) -> MegaProbeResult:
        """Block on a dispatched megabatch probe and ship the readback:
        per-lane counts, then ONE gather launch packing only the
        candidate-bearing lanes (8 leaf rows per byte)."""
        import jax.numpy as jnp

        from repro.kernels.dominance.ops import (LANE_BUCKET, bucket,
                                                 gather_pack_lanes_jit)

        counts: dict[int, np.ndarray] = {}
        lane_of: dict[tuple, int] = {}
        sel_s, sel_q, sel_finals = [], [], []
        row = 0
        d2h = 0
        for l, cdev, fin in zip(flight.lengths, flight.counts_dev,
                                flight.finals):
            c = np.asarray(cdev)
            counts[l] = c
            d2h += c.nbytes
            ls, lq = np.nonzero(c)
            if not len(ls):          # no candidate-bearing lanes: the
                continue             # block ships nothing at all
            k_b = bucket(len(ls), LANE_BUCKET)
            s_pad = np.zeros(k_b, np.int32)
            q_pad = np.zeros(k_b, np.int32)
            s_pad[:len(ls)] = ls
            q_pad[:len(lq)] = lq
            sel_s.append(s_pad)
            sel_q.append(q_pad)
            sel_finals.append(fin)
            for s, q in zip(ls, lq):
                lane_of[(l, int(s), int(q))] = row
                row += 1
            row += k_b - len(ls)
        packed = None
        launches = flight.launches
        if lane_of:
            packed = np.asarray(gather_pack_lanes_jit(
                tuple(sel_finals),
                tuple(jnp.asarray(s) for s in sel_s),
                tuple(jnp.asarray(q) for q in sel_q)))
            launches += 1
            d2h += packed.nbytes
            self.stats["h2d_bytes"] += sum(s.nbytes + q.nbytes
                                           for s, q in zip(sel_s, sel_q))
        self.stats["d2h_bytes"] += d2h
        return MegaProbeResult(assembly=flight.assembly, counts=counts,
                               lane_of=lane_of, packed=packed,
                               d2h_bytes=d2h, launches=launches)
