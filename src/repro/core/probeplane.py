"""Device-resident probe planes: cached shard slabs + whole-plan descent.

PR 2's batched device probe still re-packed every shard's aR-tree rows
into the ``[S, R, D]`` slab on the host for EVERY path of EVERY query,
shipped the dense ``ok[s, q, r]`` mask back, and walked survivorship in
per-query numpy loops.  A *probe plane* removes all three costs:

  * **resident slabs** — each shard tree's rows (`artree._tree_rows`
    layout: every internal level's upper bounds root-first, then the
    leaf points) are packed ONCE at index-build time into a padded
    device block (`TreePlane`), together with the packed-parent pointers
    the descent needs; the rows never cross the host boundary again;
  * **whole-plan assembly** — the planes a query plan probes are stacked
    (device-side, cached across queries) into one ``[S, R_pad, D_pad]``
    slab covering ALL path lengths of the plan: query rows are padded
    with ``-inf`` beyond their own width, which passes every box dim, so
    paths of different lengths share one launch;
  * **candidate-id readback** — one fused launch
    (`repro.kernels.dominance.ops.fused_plan_descent`) evaluates the
    dominance masks AND runs the level-order survivor propagation on
    device; only per-(shard, path) candidate row ids and counters cross
    back (the readback contract), never a dense mask.

Staleness: a plane records the *identity* of the ARTree it was packed
from, and `ClusterPlanes` re-validates on every access — a shard index
replaced by hot migration, failover, or a rebuild can never be served
from a stale plane (property-tested in tests/test_probeplane.py).

All padded shapes are rounded to the named buckets in
`repro.kernels.dominance.ops` so the jitted descent compiles at most
once per (shard-bucket, row-bucket) pair.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict

import numpy as np

from repro.core.artree import ARTree, _tree_rows

__all__ = ["TreePlane", "AssembledPlanes", "PlanProbeResult",
           "ClusterPlanes", "build_tree_plane", "plan_probe"]

_PLANE_TOKENS = itertools.count(1)
_MAX_ASSEMBLED = 4          # assembled-slab cache entries kept per cluster


@dataclasses.dataclass(frozen=True)
class TreePlane:
    """One shard tree packed for device residency.

    ``rows`` is the device array (row-bucketed, -inf pad); everything
    else is host metadata the assemble step stacks.  ``tree`` is kept
    solely as the staleness token — `ClusterPlanes` compares it by
    identity against the live index before every use.
    """

    tree: ARTree
    token: int                   # unique per build; keys assembled slabs
    rows: object                 # jnp [R_b, D] device rows
    n_rows: int                  # valid rows (internal levels + leaves)
    n_levels: int
    leaf_offset: int             # first leaf row
    parent: np.ndarray           # int32 [R_b]; self at roots and pads
    is_root: np.ndarray          # bool [R_b]
    internal: np.ndarray         # bool [R_b] valid internal-node rows
    leaf: np.ndarray             # bool [R_b] valid leaf rows

    @property
    def device_nbytes(self) -> int:
        return int(self.rows.size) * 4


def build_tree_plane(tree: ARTree) -> TreePlane:
    """Pack one non-empty aR-tree into its device-resident plane."""
    import jax.numpy as jnp

    from repro.kernels.dominance.ops import ROW_BUCKET, bucket

    rows = _tree_rows(tree)
    n_rows, d = rows.shape
    r_b = bucket(n_rows, ROW_BUCKET)
    padded = np.full((r_b, d), -np.inf, np.float32)
    padded[:n_rows] = rows

    level_sizes = [u.shape[0] for u in tree.uppers]
    offsets = np.cumsum([0] + level_sizes)
    b = tree.branching
    parent = np.arange(r_b, dtype=np.int32)       # self: roots + pad rows
    is_root = np.zeros(r_b, bool)
    if level_sizes:
        is_root[:level_sizes[0]] = True
        for k in range(1, len(level_sizes)):
            j = np.arange(level_sizes[k], dtype=np.int32)
            parent[offsets[k] + j] = offsets[k - 1] + j // b
        j = np.arange(tree.n_points, dtype=np.int32)
        parent[offsets[-1] + j] = offsets[-2] + j // b
    else:                       # single point: the leaf is its own root
        is_root[:tree.n_points] = True
    internal = np.zeros(r_b, bool)
    internal[:offsets[-1]] = True
    leaf = np.zeros(r_b, bool)
    leaf[offsets[-1]:n_rows] = True
    return TreePlane(tree=tree, token=next(_PLANE_TOKENS),
                     rows=jnp.asarray(padded), n_rows=n_rows,
                     n_levels=tree.n_levels, leaf_offset=int(offsets[-1]),
                     parent=parent, is_root=is_root, internal=internal,
                     leaf=leaf)


@dataclasses.dataclass(frozen=True)
class AssembledPlanes:
    """A set of planes stacked into one launchable slab (device arrays).

    The shard axis is bucketed; pad planes have count 0, -inf rows and
    all-False role masks, so they can never produce a candidate.
    """

    keys: tuple                  # ((sid, length), ...) slab order
    slot: dict                   # (sid, length) -> shard-axis index
    lengths: np.ndarray          # int32 [S_b]; -1 on pad planes
    slab: object                 # jnp [S_b, R_b, D_pad]
    counts: object               # jnp int32 [S_b]
    parent: object               # jnp int32 [S_b, R_b]
    is_root: object              # jnp bool [S_b, R_b]
    internal: object             # jnp bool [S_b, R_b]
    leaf: object                 # jnp bool [S_b, R_b]
    leaf_offsets: np.ndarray     # int64 [S_b]
    perms: list                  # per real plane: tree.perm (host)
    d_pad: int
    n_iter: int                  # bucketed max tree depth
    assembled_bytes: int         # host->device bytes this assembly moved


def _assemble(planes: list[TreePlane], keys: list[tuple]) -> AssembledPlanes:
    import jax.numpy as jnp

    from repro.kernels.dominance.ops import (DEPTH_BUCKET, SHARD_BUCKET,
                                             bucket)

    s_b = bucket(len(planes), SHARD_BUCKET)
    r_b = max(int(p.rows.shape[0]) for p in planes)
    d_pad = max(int(p.rows.shape[1]) for p in planes)
    n_iter = bucket(max(p.n_levels for p in planes), DEPTH_BUCKET)

    moved = 0
    slabs = []
    for p in planes:
        rows = p.rows               # already resident: no host bytes move
        pr, pd = int(rows.shape[0]), int(rows.shape[1])
        if pr < r_b or pd < d_pad:  # device-side pad up to the common slab
            rows = jnp.pad(rows, ((0, r_b - pr), (0, d_pad - pd)),
                           constant_values=-jnp.inf)
        slabs.append(rows)
    pad_planes = s_b - len(planes)
    if pad_planes:
        slabs.append(jnp.full((pad_planes, r_b, d_pad), -jnp.inf,
                              jnp.float32))
    slab = jnp.concatenate(
        [jnp.stack(slabs[:len(planes)])] + slabs[len(planes):], axis=0) \
        if pad_planes else jnp.stack(slabs)

    def stack_meta(field: str, fill) -> np.ndarray:
        out = np.full((s_b, r_b), fill,
                      getattr(planes[0], field).dtype)
        for i, p in enumerate(planes):
            out[i, :p.parent.shape[0]] = getattr(p, field)
        return out

    parent = stack_meta("parent", 0)
    for i in range(s_b):            # pad rows/planes: self-parented
        tail = planes[i].parent.shape[0] if i < len(planes) else 0
        parent[i, tail:] = np.arange(tail, r_b, dtype=np.int32)
    is_root = stack_meta("is_root", False)
    internal = stack_meta("internal", False)
    leaf = stack_meta("leaf", False)
    counts = np.zeros(s_b, np.int32)
    counts[:len(planes)] = [p.n_rows for p in planes]
    lengths = np.full(s_b, -1, np.int32)
    lengths[:len(planes)] = [l for _, l in keys]
    moved += (parent.nbytes + is_root.nbytes + internal.nbytes
              + leaf.nbytes + counts.nbytes)
    return AssembledPlanes(
        keys=tuple(keys),
        slot={k: i for i, k in enumerate(keys)},
        lengths=lengths, slab=slab,
        counts=jnp.asarray(counts), parent=jnp.asarray(parent),
        is_root=jnp.asarray(is_root), internal=jnp.asarray(internal),
        leaf=jnp.asarray(leaf),
        leaf_offsets=np.array([p.leaf_offset for p in planes]
                              + [0] * pad_planes, np.int64),
        perms=[p.tree.perm for p in planes],
        d_pad=d_pad, n_iter=n_iter, assembled_bytes=moved)


@dataclasses.dataclass
class PlanProbeResult:
    """Readback of one whole-plan launch: candidate ids + counters only."""

    assembled: AssembledPlanes
    counts: np.ndarray           # int32 [S_b, Q_b]
    cand_rows: np.ndarray        # int32 [S_b, Q_b, C_max] slab row ids
    nodes_visited: np.ndarray    # int32 [S_b, Q_b]
    nodes_pruned: np.ndarray     # int32 [S_b, Q_b]
    leaves_tested: np.ndarray    # int32 [S_b, Q_b]
    h2d_bytes: int
    d2h_bytes: int

    def hits(self, sid: int, length: int, qrow: int) -> np.ndarray:
        """ORIGINAL point indices dominated by query row `qrow` in the
        (sid, length) tree — identical in value and order to the host
        `query_dominating` output."""
        s = self.assembled.slot[(sid, length)]
        k = int(self.counts[s, qrow])
        local = (self.cand_rows[s, qrow, :k].astype(np.int64)
                 - self.assembled.leaf_offsets[s])
        return self.assembled.perms[s][local]

    def counters(self, sid: int, length: int, qrow: int) -> dict[str, int]:
        s = self.assembled.slot[(sid, length)]
        return {"nodes_visited": int(self.nodes_visited[s, qrow]),
                "nodes_pruned": int(self.nodes_pruned[s, qrow]),
                "leaves_tested": int(self.leaves_tested[s, qrow])}


def plan_probe(assembled: AssembledPlanes,
               queries: list[tuple[np.ndarray, int]], eps: float = 1e-5,
               use_pallas: bool | None = None) -> PlanProbeResult:
    """Probe every (embedding, length) query row of a plan in ONE launch.

    Rows narrower than the slab width are padded with -inf (passes every
    box dim); pad rows past the real count hold +inf (match nothing) and
    carry pair_valid=False.  Readback is counts + the leading candidate
    id columns + counters — the dense mask never crosses back.
    """
    import jax.numpy as jnp

    from repro.kernels.dominance.ops import (QUERY_BUCKET, bucket,
                                             fused_plan_descent)

    n_q = len(queries)
    q_b = bucket(max(n_q, 1), QUERY_BUCKET)
    qmat = np.full((q_b, assembled.d_pad), np.inf, np.float32)
    q_len = np.full(q_b, -2, np.int32)          # never matches a plane
    for i, (emb, length) in enumerate(queries):
        emb = np.asarray(emb, np.float32).ravel()
        qmat[i, :emb.size] = emb
        qmat[i, emb.size:] = -np.inf
        q_len[i] = length
    pair_valid = assembled.lengths[:, None] == q_len[None, :]

    n_cand, order, nv, npr, lt = fused_plan_descent(
        jnp.asarray(qmat), assembled.slab, assembled.counts,
        assembled.parent, assembled.is_root, assembled.internal,
        assembled.leaf, jnp.asarray(pair_valid), eps=eps,
        n_iter=assembled.n_iter, use_pallas=use_pallas)

    counts = np.asarray(n_cand)
    c_max = int(counts.max()) if counts.size else 0
    cand_rows = np.asarray(order[:, :, :c_max])  # device slice, then ship
    nv, npr, lt = np.asarray(nv), np.asarray(npr), np.asarray(lt)
    return PlanProbeResult(
        assembled=assembled, counts=counts, cand_rows=cand_rows,
        nodes_visited=nv, nodes_pruned=npr, leaves_tested=lt,
        h2d_bytes=qmat.nbytes + pair_valid.nbytes,
        d2h_bytes=(counts.nbytes + cand_rows.nbytes + nv.nbytes
                   + npr.nbytes + lt.nbytes))


class ClusterPlanes:
    """Per-cluster plane cache: build -> resident -> invalidate.

    Planes are built at index-build time (`build_shard`), served resident
    across queries, and invalidated on hot migration / rebalancing /
    machine failure (`invalidate`) — with an identity re-check on every
    access as the backstop, so even an index swapped behind the cache's
    back (e.g. a direct `hot_migrate` call) is repacked before use.
    """

    def __init__(self) -> None:
        self._planes: dict[tuple[int, int], TreePlane] = {}
        self._assembled: OrderedDict[tuple, AssembledPlanes] = OrderedDict()
        self.stats = {"plane_builds": 0, "invalidations": 0,
                      "assembles": 0, "assemble_reuses": 0, "probes": 0,
                      "h2d_bytes": 0, "d2h_bytes": 0}

    def resident_bytes(self) -> int:
        """Total device bytes held: per-tree planes PLUS the assembled
        slab copies (each a padded stack of every included plane)."""
        return (sum(p.device_nbytes for p in self._planes.values())
                + sum(int(a.slab.size) * 4
                      for a in self._assembled.values()))

    def plane(self, sid: int, length: int, tree: ARTree) -> TreePlane:
        """The resident plane for (sid, length); rebuilt iff stale."""
        key = (sid, length)
        cached = self._planes.get(key)
        if cached is not None and cached.tree is tree:
            return cached
        if cached is not None:      # index replaced behind our back
            self._drop(key)
        plane = build_tree_plane(tree)
        self._planes[key] = plane
        self.stats["plane_builds"] += 1
        self.stats["h2d_bytes"] += plane.device_nbytes
        return plane

    def build_shard(self, sid: int, index) -> None:
        """Eagerly pack every non-empty tree of a freshly built index."""
        for length, tree in index.trees.items():
            if tree.n_points:
                self.plane(sid, length, tree)

    def invalidate(self, sid: int) -> None:
        """Drop every plane (and assembled slab) touching a shard."""
        for key in [k for k in self._planes if k[0] == sid]:
            self._drop(key)

    def _drop(self, key: tuple[int, int]) -> None:
        self._planes.pop(key, None)
        self.stats["invalidations"] += 1
        for sig in [s for s, a in self._assembled.items()
                    if key in a.slot]:
            del self._assembled[sig]

    def assemble(self, entries: list[tuple[int, int, ARTree]]
                 ) -> AssembledPlanes:
        """Stack the planes for (sid, length, tree) entries; cached —
        a warm assembly moves zero slab bytes host->device."""
        planes = [self.plane(sid, l, tree) for sid, l, tree in entries]
        keys = [(sid, l) for sid, l, _ in entries]
        sig = tuple(p.token for p in planes)
        hit = self._assembled.get(sig)
        if hit is not None:
            self._assembled.move_to_end(sig)
            self.stats["assemble_reuses"] += 1
            return hit
        assembled = _assemble(planes, keys)
        self._assembled[sig] = assembled
        while len(self._assembled) > _MAX_ASSEMBLED:
            self._assembled.popitem(last=False)
        self.stats["assembles"] += 1
        self.stats["h2d_bytes"] += assembled.assembled_bytes
        return assembled

    def probe(self, entries: list[tuple[int, int, ARTree]],
              queries: list[tuple[np.ndarray, int]], eps: float = 1e-5,
              use_pallas: bool | None = None) -> PlanProbeResult:
        """assemble + plan_probe with cache statistics accounting."""
        assembled = self.assemble(entries)
        res = plan_probe(assembled, queries, eps=eps,
                         use_pallas=use_pallas)
        self.stats["probes"] += 1
        self.stats["h2d_bytes"] += res.h2d_bytes
        self.stats["d2h_bytes"] += res.d2h_bytes
        return res
