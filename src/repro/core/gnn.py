"""Certified-monotone GNN encoder for GNN-PE path dominance embeddings.

GNN-PE (Ye/Lian/Chen, VLDB'24) trains a GNN so that the embedding o(p) of a
query path p_q *dominates* (element-wise <=) the embedding of every data path
p_z it matches, enabling index pruning with no false dismissals.  The
original paper trains a GAT and drives dominance violations to zero on
enumerated sub-star pairs; exactness then rests on the trained net.

We adapt this to a **certified monotone GNN** whose dominance guarantee holds
*by construction* for every true match (see DESIGN.md §3):

  o^(0)(v) = f_theta(label(v))                       (free, learned)
  o^(t)(v) = o^(t-1)(v)
           + sum_{u in N(v)} [ g_t(label(u)) + A_t · o^(t-1)(u) ]

with g_t >= 0 (softplus-parameterized) and A_t >= 0 element-wise.  Under any
subgraph isomorphism F: q -> G, star_q(v) is a sub-star of star_G(F(v)) with
equal center labels, so by induction over t:  o^(t)(v) <= o^(t)(F(v)).
A path embedding is the *per-position concatenation* of vertex embeddings, so
dominance transfers position-wise to whole paths.  Training (embedding.py)
maximizes pruning power: it pushes NON-matching pairs to violate dominance.

Everything is a plain pytree of jnp arrays — no flax.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "GNNConfig", "init_params", "vertex_embeddings", "path_embeddings",
    "label_embeddings", "encode_paths", "encode_graph",
]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    """Per-shard dominance-embedding GNN configuration.

    Attributes:
      n_labels: label vocabulary size (global — shared across shards so that
                cross-shard paths embed consistently).
      d_embed:  structural embedding dims per vertex (paper default d=2).
      d_label:  label-embedding dims per vertex (o_0 in the paper).
      n_hops:   monotone message-passing layers.
      max_degree: degree normalization cap for the degree feature.
    """

    n_labels: int
    d_embed: int = 2
    d_label: int = 2
    n_hops: int = 2
    max_degree: int = 64

    @property
    def d_vertex(self) -> int:
        return self.d_embed + self.d_label


def init_params(cfg: GNNConfig, key: jax.Array) -> dict[str, Any]:
    """Parameter pytree.

    raw_g / raw_a are unconstrained; the forward pass maps them through
    softplus to enforce non-negativity (the dominance certificate).
    """
    ks = jax.random.split(key, 2 + 2 * cfg.n_hops)
    params: dict[str, Any] = {
        # free center-label embedding table [n_labels, d_embed]
        "f_center": 0.5 + 0.1 * jax.random.normal(
            ks[0], (cfg.n_labels, cfg.d_embed), dtype=jnp.float32),
        # non-negative degree coefficient (degree is monotone under matching)
        "raw_deg": jnp.full((cfg.d_embed,), -2.0, dtype=jnp.float32),
    }
    for t in range(cfg.n_hops):
        params[f"raw_g{t}"] = -1.0 + 0.3 * jax.random.normal(
            ks[1 + 2 * t], (cfg.n_labels, cfg.d_embed), dtype=jnp.float32)
        params[f"raw_a{t}"] = -2.0 + 0.3 * jax.random.normal(
            ks[2 + 2 * t], (cfg.d_embed, cfg.d_embed), dtype=jnp.float32)
    return params


def _nonneg(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.softplus(x)


def vertex_embeddings(params: dict[str, Any], cfg: GNNConfig,
                      labels: jnp.ndarray, degrees: jnp.ndarray,
                      edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                      n_vertices: int | None = None) -> jnp.ndarray:
    """Monotone message passing -> [n, d_embed] certified embeddings.

    edge_src/edge_dst: symmetric directed edge list (both directions present).
    """
    n = n_vertices if n_vertices is not None else labels.shape[0]
    deg = jnp.minimum(degrees.astype(jnp.float32), cfg.max_degree)
    o = params["f_center"][labels] + deg[:, None] * _nonneg(params["raw_deg"])
    for t in range(cfg.n_hops):
        g = _nonneg(params[f"raw_g{t}"])[labels]            # [n, d]
        a = _nonneg(params[f"raw_a{t}"])                    # [d, d]
        msg = g[edge_src] + o[edge_src] @ a                 # [E, d]
        o = o + jax.ops.segment_sum(msg, edge_dst, num_segments=n)
    return o


def path_embeddings(vemb: jnp.ndarray, path_vertices: jnp.ndarray) -> jnp.ndarray:
    """Per-position concatenation: [P, l+1] ids -> [P, (l+1)*d].

    Query path position i aligns with data path position i (or reversed — the
    matcher probes both orientations), so dominance holds position-wise.
    """
    p, lp1 = path_vertices.shape
    return vemb[path_vertices].reshape(p, lp1 * vemb.shape[1])


def label_embeddings(labels: jnp.ndarray, path_vertices: jnp.ndarray,
                     n_labels: int, d_label: int = 2) -> jnp.ndarray:
    """o_0(p): per-position label projection, concatenated.

    Uses a fixed strictly-positive random projection of the one-hot label:
    equal labels => equal values (dominance holds with equality for true
    matches); different labels almost surely violate dominance in some dim,
    which is exactly the paper's label-based pruning.
    """
    rng = np.random.default_rng(0xC0FFEE)
    proj = jnp.asarray(rng.uniform(0.1, 1.0, size=(n_labels, d_label)),
                       dtype=jnp.float32)
    pl = proj[labels[path_vertices]]                 # [P, l+1, d_label]
    p, lp1 = path_vertices.shape
    return pl.reshape(p, lp1 * d_label)


def interleave_path_embedding(struct: jnp.ndarray, lab: jnp.ndarray,
                              lp1: int) -> jnp.ndarray:
    """Combine per-position structural + label dims into one vector.

    Layout: [pos0_struct, pos0_label, pos1_struct, pos1_label, ...] so a
    length-l path embeds into (l+1)*(d_embed+d_label) dims.
    """
    p = struct.shape[0]
    s = struct.reshape(p, lp1, -1)
    l = lab.reshape(p, lp1, -1)
    return jnp.concatenate([s, l], axis=2).reshape(p, -1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def encode_graph(params: dict[str, Any], cfg: GNNConfig,
                 labels: jnp.ndarray, degrees: jnp.ndarray,
                 edge_src: jnp.ndarray, edge_dst: jnp.ndarray) -> jnp.ndarray:
    """All vertex embeddings of a (shard) graph."""
    return vertex_embeddings(params, cfg, labels, degrees, edge_src, edge_dst)


@functools.partial(jax.jit, static_argnames=("cfg",))
def encode_paths(params: dict[str, Any], cfg: GNNConfig,
                 labels: jnp.ndarray, degrees: jnp.ndarray,
                 edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                 path_vertices: jnp.ndarray) -> jnp.ndarray:
    """Full path embedding o(p) (structural + label dims interleaved)."""
    vemb = vertex_embeddings(params, cfg, labels, degrees, edge_src, edge_dst)
    struct = path_embeddings(vemb, path_vertices)
    lab = label_embeddings(labels, path_vertices, cfg.n_labels, cfg.d_label)
    return interleave_path_embedding(struct, lab, path_vertices.shape[1])
