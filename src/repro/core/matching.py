"""Exact subgraph matching: dominance-index pruning + backtracking join.

Pipeline (single shard / single graph; the distributed orchestration lives in
repro/dist/cluster.py):

  1. decompose the query into simple paths covering all edges (paths.py);
  2. embed each query path and probe the shard's aR-tree for *dominating*
     data paths (both orientations) — candidates are a guaranteed superset
     of all true matches (no false dismissals);
  3. intersect per-position path candidates into per-query-vertex candidate
     sets (plus label + degree filters);
  4. ordered backtracking join with exact edge/label verification.

Step 4 only ever *confirms* candidates, so the end-to-end matcher is exact:
100% precision by verification, 100% recall by the dominance certificate.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core import gnn as gnn_lib
from repro.core.artree import ARTree, query_dominating
from repro.core.embedding import EmbeddedPaths, embed_query_paths
from repro.core.graph import LabeledGraph
from repro.core.paths import PathTable, paths_of_query

__all__ = ["MatchStats", "ShardIndex", "build_shard_index",
           "path_candidates", "batched_path_candidates",
           "vertex_candidates", "backtrack_join", "exact_match"]


@dataclasses.dataclass
class MatchStats:
    """Telemetry of one query execution (feeds PE-score + load metrics)."""

    n_matches: int = 0
    candidates_before: int = 0
    candidates_after: int = 0
    leaves_tested: int = 0
    nodes_pruned: int = 0
    filter_time_ms: float = 0.0
    join_time_ms: float = 0.0
    per_path: list[dict[str, Any]] = dataclasses.field(default_factory=list)

    @property
    def pruning_rate(self) -> float:
        if self.candidates_before == 0:
            return 0.0
        return 1.0 - self.candidates_after / self.candidates_before


@dataclasses.dataclass(frozen=True)
class ShardIndex:
    """Per-shard index: embedded path tables + one aR-tree per path length."""

    embedded: dict[int, EmbeddedPaths]
    trees: dict[int, ARTree]

    def nbytes(self) -> int:
        total = 0
        for ep in self.embedded.values():
            total += ep.vertices.nbytes + ep.embeddings.nbytes
        for t in self.trees.values():
            total += t.nbytes()
        return total


def build_shard_index(graph: LabeledGraph, params: dict[str, Any],
                      cfg: gnn_lib.GNNConfig, max_length: int = 2,
                      branching: int = 16,
                      max_paths_per_length: int | None = 200_000
                      ) -> ShardIndex:
    from repro.core.embedding import embed_shard_paths
    from repro.core.artree import build_artree

    embedded = embed_shard_paths(graph, params, cfg, max_length,
                                 max_paths_per_length)
    trees = {l: build_artree(ep.embeddings, branching)
             for l, ep in embedded.items()}
    return ShardIndex(embedded=embedded, trees=trees)


def _reverse_embedding(emb: np.ndarray, lp1: int) -> np.ndarray:
    """Reverse the per-position blocks of a path embedding [P, lp1*d]."""
    p, d_total = emb.shape
    d = d_total // lp1
    return emb.reshape(p, lp1, d)[:, ::-1, :].reshape(p, d_total)


def _scatter_hits(ep: EmbeddedPaths, idx_f: np.ndarray, idx_r: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Forward + reversed hit indices -> (cand_vertices, orient).

    Shared by the host and batched probe paths — their bit-identity
    contract depends on this scatter staying in lockstep.
    """
    verts = np.concatenate([ep.vertices[idx_f], ep.vertices[idx_r][:, ::-1]])
    orient = np.concatenate([np.zeros(idx_f.size, np.int8),
                             np.ones(idx_r.size, np.int8)])
    return verts, orient


def path_candidates(index: ShardIndex, q_emb: np.ndarray, length: int,
                    stats: MatchStats | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Probe one query path embedding -> (cand_vertices [C, l+1], orient [C]).

    orient[c] = 0 if the data path matches the query orientation as stored,
    1 if it matches reversed.  Both orientations are probed because a path
    and its reverse describe the same subgraph.
    """
    if length not in index.trees:
        return np.zeros((0, length + 1), np.int32), np.zeros(0, np.int8)
    tree = index.trees[length]
    ep = index.embedded[length]
    idx_f, st_f = query_dominating(tree, q_emb)
    q_rev = _reverse_embedding(q_emb[None, :], length + 1)[0]
    idx_r, st_r = query_dominating(tree, q_rev)
    if stats is not None:
        stats.leaves_tested += st_f["leaves_tested"] + st_r["leaves_tested"]
        stats.nodes_pruned += st_f["nodes_pruned"] + st_r["nodes_pruned"]
    return _scatter_hits(ep, idx_f, idx_r)


def batched_path_candidates(indexes: list[ShardIndex], q_emb: np.ndarray,
                            length: int, stats: MatchStats | None = None,
                            use_pallas: bool | None = None,
                            byte_stats: dict | None = None
                            ) -> list[tuple[np.ndarray, np.ndarray]]:
    """Probe one query path against MANY shard indexes in one launch.

    Gathers every shard's aR-tree of the given length into the padded
    ``[S, max_leaves, D]`` device slab (see
    `repro.core.artree.batched_query_dominating`), probes both
    orientations in the same launch, and scatters survivor rows back per
    shard.  Returns one ``(cand_vertices [C, l+1], orient [C])`` pair per
    input index — identical, element for element, to calling
    `path_candidates(indexes[s], q_emb, length)` per shard.

    `byte_stats` (optional dict) accumulates the launch's host<->device
    traffic under ``h2d_bytes``/``d2h_bytes`` — this path re-packs the
    slab per call; the resident-plane path (repro/core/probeplane.py)
    exists to amortize exactly that.
    """
    from repro.core.artree import batched_query_dominating

    trees, slots = [], []
    out: list[tuple[np.ndarray, np.ndarray]] = [
        (np.zeros((0, length + 1), np.int32), np.zeros(0, np.int8))
        for _ in indexes]
    for s, index in enumerate(indexes):
        if length in index.trees:
            trees.append(index.trees[length])
            slots.append(s)
    if not trees:
        return out
    q_rev = _reverse_embedding(q_emb[None, :], length + 1)[0]
    hits, bstats = batched_query_dominating(
        trees, np.stack([q_emb, q_rev]), use_pallas=use_pallas)
    if stats is not None:
        stats.leaves_tested += bstats["leaves_tested"]
        stats.nodes_pruned += bstats["nodes_pruned"]
    if byte_stats is not None:
        for k in ("h2d_bytes", "d2h_bytes"):
            byte_stats[k] = byte_stats.get(k, 0) + bstats[k]
    for s, (idx_f, idx_r) in zip(slots, hits):
        out[s] = _scatter_hits(indexes[s].embedded[length], idx_f, idx_r)
    return out


def vertex_candidates(query: LabeledGraph, data: LabeledGraph,
                      q_tables: list[PathTable],
                      cand_per_path: list[np.ndarray]) -> list[np.ndarray]:
    """Per-query-vertex candidate sets (bool masks over data vertices).

    Starts from the label + degree filter, then intersects the projection of
    every path's candidates at every position.  A probed path with ZERO
    candidate rows is a dominance proof that the query is unmatchable: its
    vertices' sets are emptied (the all-False projection) and the remaining
    paths are skipped, mirroring the cluster engine's `alive` early-exit, so
    the backtracking join sees an empty set and does no work.  A row entry
    of ``None`` means the path was NOT probed (e.g. omitted by a partial
    execution plan) and contributes no constraint.
    """
    n_q, n_d = query.n_vertices, data.n_vertices
    deg_q, deg_d = query.degrees, data.degrees
    cands = []
    for v in range(n_q):
        mask = (data.labels == query.labels[v]) & (deg_d >= deg_q[v])
        cands.append(mask)
    alive = all(c.any() for c in cands)
    for table, cand in zip(q_tables, cand_per_path):
        if not alive:
            break
        for r in range(table.n_paths):
            cv = cand[r] if isinstance(cand, list) else cand
            if cv is None:          # not probed: no dominance information
                continue
            # cand for row r: [C, l+1] data vertices aligned to query path row r
            qv = table.vertices[r]
            mask_any = np.zeros((qv.shape[0], n_d), dtype=bool)
            if cv.shape[0]:
                for i in range(qv.shape[0]):
                    mask_any[i, cv[:, i]] = True
            for i, qvi in enumerate(qv):
                cands[qvi] &= mask_any[i]
                if not cands[qvi].any():
                    alive = False
            if not alive:
                break
    return cands


# table-join guards: the vectorized frontier join materializes partial-
# mapping tables and an n^2/8-byte adjacency bitmap (32 MB at the cap);
# past any bound it falls back to the recursive verifier (same results,
# same order).  _JOIN_STEP_MAX_ELEMS bounds the [K, depth, C] broadcast
# temporaries of ONE extension step (~64 MB of bools) BEFORE they are
# built — the row cap alone would only trigger after the allocation.
_JOIN_BITMAP_MAX_N = 16_384
_JOIN_TABLE_MAX_ROWS = 1 << 18
_JOIN_STEP_MAX_ELEMS = 1 << 26


def _join_order(query: LabeledGraph, adj_q: list[set], sizes: list[int]
                ) -> list[int]:
    """Matching order: ascending candidate-set size under connected
    expansion (prefer vertices adjacent to already-placed ones)."""
    n_q = query.n_vertices
    order: list[int] = []
    placed: set[int] = set()
    while len(order) < n_q:
        frontier = [v for v in range(n_q) if v not in placed and
                    (not order or adj_q[v] & placed)]
        if not frontier:
            frontier = [v for v in range(n_q) if v not in placed]
        v = min(frontier, key=lambda x: sizes[x])
        order.append(v)
        placed.add(v)
    return order


def _backtrack_join_rec(query: LabeledGraph, data: LabeledGraph,
                        cand_lists: list[np.ndarray], order: list[int],
                        adj_q: list[set],
                        max_matches: int | None) -> list[tuple[int, ...]]:
    """Recursive DFS verifier (exact; the table join's fallback)."""
    n_q = query.n_vertices
    indptr, indices = data.indptr, data.indices
    matches: list[tuple[int, ...]] = []
    mapping = np.full(n_q, -1, dtype=np.int64)
    used: set[int] = set()

    def rec(depth: int) -> bool:
        if depth == n_q:
            matches.append(tuple(int(x) for x in mapping))
            return max_matches is not None and len(matches) >= max_matches
        v = order[depth]
        cl = cand_lists[v]
        for u in adj_q[v]:
            b = mapping[u]
            if b >= 0:
                cl = cl[np.isin(cl, indices[indptr[b]:indptr[b + 1]],
                                assume_unique=True)]
        for u_d in cl:
            u_d = int(u_d)
            if u_d in used:
                continue
            mapping[v] = u_d
            used.add(u_d)
            if rec(depth + 1):
                return True
            used.discard(u_d)
            mapping[v] = -1
        return False

    rec(0)
    return matches


def backtrack_join(query: LabeledGraph, data: LabeledGraph,
                   cands: list[np.ndarray], max_matches: int | None = None
                   ) -> list[tuple[int, ...]]:
    """Ordered backtracking with exact verification (injective, adjacency).

    Query vertices are matched in ascending candidate-set size, preferring
    vertices adjacent to already-matched ones (connected expansion).

    High-match queries made the per-node DFS the end-to-end hotspot once
    probing moved on device, so the default engine is a vectorized
    frontier-table join: partial mappings live in one [K, depth] array
    and every depth extends ALL of them at once with batched adjacency
    (bit-packed matrix) + injectivity tests.  Rows stay in DFS order
    (np.nonzero is row-major and candidate lists ascend), so matches are
    emitted in exactly the recursive verifier's order; the recursion
    remains as the fallback for early-exit (max_matches), huge graphs,
    and table blow-ups.
    """
    n_q = query.n_vertices
    adj_q = [set(query.neighbors(v).tolist()) for v in range(n_q)]
    sizes = [int(c.sum()) for c in cands]
    if any(s == 0 for s in sizes):
        return []
    order = _join_order(query, adj_q, sizes)
    cand_lists = [np.flatnonzero(c) for c in cands]
    if max_matches is not None or data.n_vertices > _JOIN_BITMAP_MAX_N:
        return _backtrack_join_rec(query, data, cand_lists, order, adj_q,
                                   max_matches)

    adj_bits = data.adjacency_bits()
    col_of = {v: j for j, v in enumerate(order)}
    rows = cand_lists[order[0]][:, None]              # [K, 1] partials
    for depth in range(1, n_q):
        v = order[depth]
        cl = cand_lists[v]
        if rows.shape[0] == 0 or cl.size == 0:
            return []
        if rows.shape[0] * cl.size * (depth + 1) > _JOIN_STEP_MAX_ELEMS:
            return _backtrack_join_rec(query, data, cand_lists, order,
                                       adj_q, max_matches)
        byte_idx, bit = cl >> 3, (cl & 7).astype(np.uint8)
        allowed = np.ones((rows.shape[0], cl.size), dtype=bool)
        for u in adj_q[v]:
            j = col_of.get(u)
            if j is not None and j < depth:
                mb = rows[:, j]
                allowed &= ((adj_bits[mb[:, None], byte_idx[None, :]]
                             >> bit[None, :]) & 1).astype(bool)
        # injectivity: a candidate may not repeat a row's mapped vertex
        allowed &= ~(rows[:, :, None] == cl[None, None, :]).any(axis=1)
        rk, ck = np.nonzero(allowed)                  # row-major: DFS order
        rows = np.concatenate([rows[rk], cl[ck][:, None]], axis=1)
        if rows.shape[0] > _JOIN_TABLE_MAX_ROWS:
            return _backtrack_join_rec(query, data, cand_lists, order,
                                       adj_q, max_matches)
    if rows.shape[0] == 0:
        return []
    out = np.empty((rows.shape[0], n_q), dtype=np.int64)
    out[:, order] = rows
    return [tuple(int(x) for x in r) for r in out]


def exact_match(query: LabeledGraph, data: LabeledGraph, index: ShardIndex,
                params: dict[str, Any], cfg: gnn_lib.GNNConfig,
                plan: list[tuple[int, int]] | None = None,
                max_matches: int | None = None,
                max_path_length: int = 2) -> tuple[list[tuple[int, ...]], MatchStats]:
    """End-to-end exact matching of `query` inside `data` via `index`.

    plan: optional ordered list of (table_idx, row_idx) path execution order
    (from repro/core/plan.py Algorithm 6); default order is as enumerated.
    Returns (matches, stats); matches are tuples m with m[q_vertex]=d_vertex.
    """
    stats = MatchStats()
    t0 = time.perf_counter()
    q_tables = paths_of_query(query, max_path_length)
    q_embs = [embed_query_paths(query, params, cfg, t) for t in q_tables]

    # per-path candidate arrays, executed in plan order
    exec_order: list[tuple[int, int]] = plan if plan is not None else [
        (ti, r) for ti, t in enumerate(q_tables) for r in range(t.n_paths)]
    cand_rows: dict[tuple[int, int], np.ndarray] = {}
    for ti, r in exec_order:
        table = q_tables[ti]
        verts, _ = path_candidates(index, q_embs[ti][r], table.length, stats)
        cand_rows[(ti, r)] = verts
        stats.per_path.append({
            "table": ti, "row": r, "length": table.length,
            "n_candidates": int(verts.shape[0]),
        })
    stats.filter_time_ms = (time.perf_counter() - t0) * 1e3

    # rows a partial plan never executed map to None ("not probed"), NOT
    # to an empty array ("probed, provably unmatchable")
    cand_per_path = [
        [cand_rows.get((ti, r)) for r in range(t.n_paths)]
        for ti, t in enumerate(q_tables)
    ]
    n_total = sum(index.embedded[l].n_paths for l in index.embedded)
    stats.candidates_before = max(n_total, 1) * max(len(exec_order), 1)
    stats.candidates_after = sum(v.shape[0] for v in cand_rows.values())

    t1 = time.perf_counter()
    cands = vertex_candidates(query, data, q_tables, cand_per_path)
    matches = backtrack_join(query, data, cands, max_matches)
    stats.join_time_ms = (time.perf_counter() - t1) * 1e3
    stats.n_matches = len(matches)
    return matches, stats
