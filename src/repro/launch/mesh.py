"""Production mesh definition (TPU v5e pod slices).

Functions, not module-level constants — importing this module never touches
jax device state.  Hardware constants for the roofline live here too.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes_of", "HW"]


def _near_square(n: int) -> int:
    """Largest divisor of n that is <= sqrt(n) (1 for primes)."""
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            best = d
        d += 1
    return best


def make_production_mesh(*, multi_pod: bool = False, scale: int = 16,
                         cpu_debug: bool = False):
    """16 x 16 ('data','model') single-pod; 2 x 16 x 16 + 'pod' multi-pod.

    `scale` shrinks the mesh for debug runs (scale=4 -> 4x4 / 2x4x4); the
    production value is 16.

    `cpu_debug=True` ignores `scale` and shapes the mesh to the devices
    actually present — the ``DRYRUN_DEVICES`` host-platform devices (or
    real CPU process ranks), factorized onto the same axis names so the
    sharding rules lower unchanged.  With 8 devices: single-pod 2x4,
    multi-pod 2x2x2; an odd count drops the 'pod' axis.
    """
    if cpu_debug:
        n = len(jax.devices())
        if multi_pod and n % 2 == 0 and n >= 4:
            half = n // 2
            a = _near_square(half)
            shape: tuple = (2, a, half // a)
            axes: tuple = ("pod", "data", "model")
        else:
            a = _near_square(n)
            shape = (a, n // a)
            axes = ("data", "model")
        return jax.make_mesh(shape, axes)
    shape = (2, scale, scale) if multi_pod else (scale, scale)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    """The data-parallel mesh axes for batch sharding."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# TPU v5e per-chip hardware model (roofline constants per the assignment)
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
    "hbm_bytes": 16 * 2 ** 30,
}
