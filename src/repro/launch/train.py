"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL training steps on the local device(s) with the reduced (smoke)
config by default, or lowers the full config against the production mesh
with ``--dry-run`` (delegating to repro.launch.dryrun).

Examples:
  python -m repro.launch.train --arch yi-6b --steps 50
  python -m repro.launch.train --arch gatedgcn --steps 50
  python -m repro.launch.train --arch bert4rec --steps 30
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_spec


def train_lm(spec, steps: int, batch: int, seq: int, seed: int = 0):
    from repro.data.loaders import token_batches
    from repro.models.transformer import init_params, lm_loss
    from repro.train.trainer import TrainConfig, Trainer

    cfg = spec.make_smoke_config()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    trainer = Trainer(lambda p, b: lm_loss(p, cfg, b[0], b[1]), params,
                      TrainConfig(n_steps=steps, lr=1e-3, log_every=10))
    batches = token_batches(batch, seq, cfg.vocab, seed)
    return trainer.fit(iter(batches))


def train_gnn(spec, steps: int, seed: int = 0):
    import dataclasses as dc

    from repro.data.loaders import graph_batch_arrays
    from repro.data.synthetic import nws_graph
    from repro.models.gnn_zoo import GNNBatch, gnn_loss, init_gnn
    from repro.train.trainer import TrainConfig, Trainer

    cfg = dc.replace(spec.make_smoke_config(), d_in=16, d_out=4)
    g = nws_graph(512, 6, 0.1, 8, seed)
    nodes, pos, src, dst, nm, em, tgt = graph_batch_arrays(g, 16, 4)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))

    def loss_fn(p, b):
        batch = GNNBatch(nodes=b[0], positions=b[1], edge_src=b[2],
                         edge_dst=b[3],
                         edge_feats=jnp.zeros((b[2].shape[0], 0),
                                              jnp.float32),
                         node_mask=b[4], edge_mask=b[5],
                         graph_ids=jnp.zeros(b[0].shape[0], jnp.int32))
        return gnn_loss(p, cfg, batch, b[6])

    trainer = Trainer(loss_fn, params,
                      TrainConfig(n_steps=steps, lr=1e-3, log_every=10))
    data = (nodes, pos, src, dst, nm, em, tgt)
    return trainer.fit(iter(lambda: data, None))


def train_recsys(spec, steps: int, batch: int, seed: int = 0):
    from repro.data.loaders import recsys_batches
    from repro.models.bert4rec import init_bert4rec, sampled_cloze_loss
    from repro.train.trainer import TrainConfig, Trainer

    cfg = spec.make_smoke_config()
    params = init_bert4rec(cfg, jax.random.PRNGKey(seed))

    def loss_fn(p, b):
        return sampled_cloze_loss(p, cfg, b[0], b[1], b[2], b[3])

    trainer = Trainer(loss_fn, params,
                      TrainConfig(n_steps=steps, lr=1e-3, log_every=10))
    batches = recsys_batches(cfg.n_items, batch, cfg.seq_len, 4, 64, seed)
    return trainer.fit(iter(batches))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_spec(args.arch)
    t0 = time.time()
    if spec.family == "lm":
        hist = train_lm(spec, args.steps, args.batch, args.seq, args.seed)
    elif spec.family == "gnn":
        hist = train_gnn(spec, args.steps, args.seed)
    elif spec.family == "recsys":
        hist = train_recsys(spec, args.steps, args.batch, args.seed)
    else:
        raise SystemExit("use examples/distributed_matching.py for gnnpe")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[{args.arch}] {args.steps} steps in {time.time()-t0:.1f}s  "
          f"loss {first:.4f} -> {last:.4f}")
    if not np.isfinite(last):
        raise SystemExit("non-finite loss")


if __name__ == "__main__":
    main()
