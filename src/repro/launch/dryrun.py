import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

# NOTE: the env var above MUST precede every other import (jax locks the
# device count at first init), which is why __future__ imports are absent.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: jit(step).lower(<ShapeDtypeStructs>).compile() on the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh, then records
memory_analysis(), cost_analysis() and the collective-byte census parsed
from the post-SPMD HLO — the inputs of the §Roofline analysis.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  DRYRUN_DEVICES=32 python -m repro.launch.dryrun --all --scale 4   # debug
"""

import argparse
import json
import re
import time
import traceback
from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_spec
from repro.dist.sharding import (GNN_RULES, LM_RULES, RECSYS_RULES,
                                 clear_rules, set_mesh, set_rules)
from repro.launch.mesh import dp_axes_of, make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes of every collective op in post-SPMD HLO (per device).

    Counts plain and ``-start`` forms once; ``-done`` is skipped.  Result
    bytes approximate the receive volume per device per op.
    """
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"= *(.*?) (" + "|".join(_COLLECTIVES) +
                      r")(?:-start)?\(", line)
        if not m:
            continue
        if re.search(r"(" + "|".join(_COLLECTIVES) + r")-done\(", line):
            continue
        type_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_re.findall(type_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _rules_for(family: str, dp: tuple) -> dict:
    base = {"lm": LM_RULES, "gnn": GNN_RULES, "recsys": RECSYS_RULES,
            "engine": GNN_RULES}[family]
    rules = dict(base)
    for k, v in rules.items():
        if v == ("pod", "data"):
            rules[k] = dp if len(dp) > 1 else dp[0]
    if family == "lm":
        rules["batch"] = dp if len(dp) > 1 else dp[0]
    return rules


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, scale: int = 16,
             verbose: bool = True) -> dict[str, Any]:
    spec = get_spec(arch_id)
    shape = spec.shapes[shape_id]
    rec: dict[str, Any] = {"arch": arch_id, "shape": shape_id,
                           "mesh": "multi" if multi_pod else "single"}
    if shape_id in spec.skip_shapes:
        rec["status"] = "skipped"
        rec["reason"] = spec.skip_shapes[shape_id]
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod, scale=scale)
    dp = dp_axes_of(mesh)
    set_rules(_rules_for(spec.family, dp))
    set_mesh(mesh)
    try:
        cfg = spec.make_config()
        cell = spec.build_cell(cfg, shape, dp)
        to_ns = lambda s: jax.tree.map(
            lambda x: NamedSharding(mesh, x) if isinstance(x, P) else x,
            s, is_leaf=lambda x: isinstance(x, P))
        with mesh:
            jitted = jax.jit(cell.step_fn,
                             in_shardings=to_ns(cell.in_shardings),
                             out_shardings=to_ns(cell.out_shardings),
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec.update({
            "status": "ok",
            "description": cell.description,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_device": float(cost.get("flops", -1.0)),
            "bytes_accessed_per_device": float(
                cost.get("bytes accessed", -1.0)),
            "memory": _mem_dict(mem),
            "collectives": collective_bytes(compiled.as_text()),
            "n_devices": mesh.devices.size,
        })
        if verbose:
            print(f"[{arch_id} x {shape_id} x {rec['mesh']}] OK "
                  f"compile={t_compile:.0f}s "
                  f"flops/dev={rec['flops_per_device']:.3g} "
                  f"coll={rec['collectives']['total']:.3g}B "
                  f"argbytes={rec['memory'].get('argument_size_in_bytes', 0):.3g}")
    except Exception as e:  # noqa: BLE001 — dry-run reports failures
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch_id} x {shape_id} x {rec['mesh']}] FAILED: "
                  f"{rec['error']}")
    finally:
        clear_rules()
    return rec


def _mem_dict(mem) -> dict[str, float]:
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        try:
            out[k] = float(getattr(mem, k))
        except Exception:  # noqa: BLE001
            pass
    if not out:
        out["repr"] = str(mem)[:500]
    return out


def validate_census(ranks: int = 1) -> int:
    """Diff the transport-census prediction against MeshTransport's
    measured bytes-on-wire on the 300v bench (<=10% relative error per
    channel + total).

    ``ranks == 1`` runs the loopback mesh in-process; ``ranks >= 2``
    launches real jax.distributed processes.  Returns a process exit
    code (0 = within gate, 1 = breach).
    """
    from repro.dist import meshrun
    if ranks <= 1:
        rec = meshrun.run_scenario("census")
    else:
        out = meshrun.launch(ranks, "census")
        if out.get("init_failed"):
            print("validate-census: ranks could not bootstrap "
                  "jax.distributed — skipping")
            return 0
        if not out.get("ok"):
            print("validate-census: launch failed: "
                  + str(out.get("detail", out)))
            return 1
        rec = out["result"]
    print(f"census vs measured (world={rec['world']}):")
    for ch, row in rec["channels"].items():
        err = row.get("rel_err", row.get("share_of_total", 0.0))
        print(f"  {ch:<10} predicted={row['predicted']:>12,} "
              f"measured={row['measured']:>12,}  err={err:7.2%}")
    tot = rec["total"]
    print(f"  {'TOTAL':<10} predicted={tot['predicted']:>12,} "
          f"measured={tot['measured']:>12,}  err={tot['rel_err']:7.2%}")
    verdict = "PASS" if rec["within_10pct"] else "BREACH"
    print(f"validate-census: worst channel error "
          f"{rec['worst_rel_err']:.2%} (gate 10%) — {verdict}")
    if not rec.get("ledger_identical", True):
        print("validate-census: BREACH — sim/mesh logical wire ledgers "
              "diverge")
        return 1
    return 0 if rec["within_10pct"] else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--scale", type=int, default=16,
                    help="mesh edge (16 = production; smaller for debug; "
                         "set DRYRUN_DEVICES to 2*scale^2)")
    ap.add_argument("--validate-census", action="store_true",
                    help="diff the collective-byte census prediction "
                         "against MeshTransport measured traffic on the "
                         "300v bench (<=10%% gate)")
    ap.add_argument("--ranks", type=int, default=1,
                    help="process ranks for --validate-census (1 = "
                         "in-process loopback mesh)")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    if args.validate_census:
        raise SystemExit(validate_census(args.ranks))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        for aid in ARCH_IDS:
            for sid in get_spec(aid).shapes:
                cells.append((aid, sid))
    else:
        aid = args.arch or "yi-6b"
        sids = [args.shape] if args.shape else list(get_spec(aid).shapes)
        cells = [(aid, s) for s in sids]

    results = []
    for aid, sid in cells:
        for mp in meshes:
            results.append(run_cell(aid, sid, mp, scale=args.scale))
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"/ {len(results)} cells")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
