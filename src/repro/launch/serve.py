"""Serving driver: batched decode with KV cache (smoke config, CPU).

  python -m repro.launch.serve --arch h2o-danube-1.8b --tokens 32 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_spec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    args = ap.parse_args()

    spec = get_spec(args.arch)
    if spec.family != "lm":
        raise SystemExit("serve driver is for LM archs")
    from repro.models.transformer import (decode_step, init_cache,
                                          init_params)

    cfg = spec.make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    t_max = args.prompt_len + args.tokens
    if cfg.sliding_window is not None:
        t_max = min(t_max, cfg.sliding_window)
    cache = init_cache(cfg, args.batch, t_max)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    step = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i))

    # prefill via sequential decode (smoke scale), then sample greedily
    tok = prompt[:, :1]
    t0 = time.time()
    out_tokens = []
    for i in range(args.prompt_len + args.tokens - 1):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        if i + 1 < args.prompt_len:
            tok = prompt[:, i + 1:i + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    n_gen = gen.shape[1] * args.batch
    print(f"[{args.arch}] generated {gen.shape} tokens in {dt:.2f}s "
          f"({n_gen / dt:.1f} tok/s, batch={args.batch})")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
