"""AW-ResNet: the adaptive-weight residual net behind dynamic cache values.

Structure (§5.3.1-2): input 4-d features -> 1 residual unit (32-d) -> output
4-d weights (softmax -> alpha, beta, gamma, delta sum to 1).

Algorithm 2: initial weights from warm-up query feature variance.
Algorithm 5: GPU-collaborative incremental training with reward gating —
the new model replaces the old only if Reward improves by >= 3%; otherwise
rollback.  Training trigger: 100 new feature sets (or hit-rate drop >= 5%).

On the TPU mesh the inference batch (100 paths/batch, §5.3.2-3) is a single
jitted matmul chain; in the simulator it runs on the CPU device.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import adam_init, adam_update

__all__ = ["AWResNet", "initial_weights_from_warmup", "incremental_train"]

D_IN, D_HID, D_OUT = 4, 32, 4
TRAIN_TRIGGER_SETS = 100
TRAIN_BUFFER_SETS = 500
REWARD_GATE = 0.03


def _init_params(key: jax.Array) -> dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = jnp.sqrt(2.0 / (D_IN + D_HID))
    s2 = jnp.sqrt(2.0 / (D_HID + D_HID))
    s3 = jnp.sqrt(2.0 / (D_HID + D_OUT))
    return {
        "w1": jax.random.normal(k1, (D_IN, D_HID)) * s1,
        "b1": jnp.zeros(D_HID),
        "w2": jax.random.normal(k2, (D_HID, D_HID)) * s2,
        "b2": jnp.zeros(D_HID),
        "w3": jax.random.normal(k3, (D_HID, D_OUT)) * s3,
        "b3": jnp.zeros(D_OUT),
    }


@jax.jit
def _forward(params: dict[str, Any], x: jnp.ndarray) -> jnp.ndarray:
    """[B, 4] features -> [B, 4] weights (rows sum to 1)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = h + jax.nn.relu(h @ params["w2"] + params["b2"])   # residual unit
    return jax.nn.softmax(h @ params["w3"] + params["b3"], axis=-1)


def initial_weights_from_warmup(warmup_features: np.ndarray) -> np.ndarray:
    """Algorithm 2: variance-ratio initial weights from [N, 4] warm-up feats."""
    f = np.asarray(warmup_features, dtype=np.float64)
    var = f.var(axis=0) if f.size else np.zeros(4)
    total = var.sum()
    if total == 0:
        contrib = np.full(4, 0.25)
    else:
        contrib = var / total
    w = 0.2 + 0.1 * contrib / max(contrib.max(), 1e-12)
    return w / w.sum()


class AWResNet:
    """Stateful wrapper: weights inference + incremental training + rollback."""

    def __init__(self, seed: int = 0,
                 warmup_features: np.ndarray | None = None) -> None:
        self.params = _init_params(jax.random.PRNGKey(seed))
        self.opt = adam_init(self.params)
        if warmup_features is not None and len(warmup_features):
            self._bias_toward(initial_weights_from_warmup(warmup_features))
        self.buffer: list[tuple[np.ndarray, float]] = []   # (feats4, hit)
        self.new_since_train = 0
        self.prev_hit_rate = 1.0
        self.prev_latency_ms = 1.0
        self.n_rollbacks = 0
        self.n_updates = 0

    def _bias_toward(self, w: np.ndarray) -> None:
        """Set output bias so the untrained net predicts Algorithm-2 weights."""
        self.params = dict(self.params)
        self.params["b3"] = jnp.log(jnp.asarray(w, jnp.float32) + 1e-9)

    # ---------------------------------------------------------------- #
    def weights(self, feats: np.ndarray) -> np.ndarray:
        """Batch inference: [B, 4] -> [B, 4] (alpha, beta, gamma, delta)."""
        x = jnp.asarray(np.atleast_2d(feats), jnp.float32)
        return np.asarray(_forward(self.params, x))

    def observe(self, feats4: np.ndarray, hit: float) -> None:
        self.buffer.append((np.asarray(feats4, np.float32), float(hit)))
        if len(self.buffer) > TRAIN_BUFFER_SETS:
            self.buffer.pop(0)
        self.new_since_train += 1

    def should_train(self, hit_rate: float) -> bool:
        return (self.new_since_train >= TRAIN_TRIGGER_SETS
                or hit_rate < self.prev_hit_rate - 0.05)

    # ---------------------------------------------------------------- #
    def train_once(self, hit_rate: float, latency_ms: float,
                   n_steps: int = 30, lr: float = 1e-2) -> bool:
        """Algorithm 5. Returns True if the new model was accepted."""
        if len(self.buffer) < 8:
            self.new_since_train = 0
            return False
        lam = 0.8 if (self.prev_hit_rate < 0.6
                      and self.prev_latency_ms <= 20.0) else 0.4
        feats = jnp.asarray(np.stack([f for f, _ in self.buffer]))
        hits = jnp.asarray(np.array([h for _, h in self.buffer], np.float32))

        def reward_of(params):
            # params-dependent part of Algorithm-5's Reward: how well the
            # fused value V(p) rank-correlates with observed hits (the
            # lam*H and latency terms are constants w.r.t. params and would
            # only blunt the 3% update gate).
            w = _forward(params, feats)                     # [N, 4]
            v = (w * feats).sum(axis=1)                      # fused value
            corr = jnp.mean(v * hits) - jnp.mean(v) * jnp.mean(hits)
            return lam * corr - (1 - lam) * (latency_ms
                                             / max(self.prev_latency_ms,
                                                   1e-6)) * 1e-4

        old_params = self.params
        old_reward = float(reward_of(old_params))
        params, opt = self.params, self.opt
        step = jax.jit(lambda p, o: _train_step(p, o, feats, hits, lam, lr))
        for _ in range(n_steps):
            params, opt = step(params, opt)
        new_reward = float(reward_of(params))
        self.new_since_train = 0
        self.prev_hit_rate = hit_rate
        self.prev_latency_ms = max(latency_ms, 1e-3)
        # model update decision: accept iff reward improves by >= 3%
        if new_reward - old_reward >= REWARD_GATE * max(abs(old_reward), 1e-3):
            self.params, self.opt = params, opt
            self.n_updates += 1
            return True
        self.n_rollbacks += 1
        return False


def _train_step(params, opt, feats, hits, lam, lr):
    def loss_fn(p):
        w = _forward(p, feats)
        v = (w * feats).sum(axis=1)
        # push fused value to rank-correlate with observed hits
        corr = jnp.mean(v * hits) - jnp.mean(v) * jnp.mean(hits)
        return -lam * corr + 1e-4 * sum(jnp.sum(jnp.square(x))
                                        for x in jax.tree.leaves(p))
    g = jax.grad(loss_fn)(params)
    return adam_update(params, g, opt, lr=lr)


def incremental_train(model: AWResNet, hit_rate: float,
                      latency_ms: float) -> bool:
    """Convenience trigger used by the cluster runtime."""
    if model.should_train(hit_rate):
        return model.train_once(hit_rate, latency_ms)
    return False
