"""Two-level cache hierarchy + adaptive tiered eviction (Algorithms 3 & 4).

Access priority (strict, §5.2):
  master cache -> master memory(index) -> slave cache -> slave memory.

Cache value (§5.3.2-3):
  V(p) = alpha·f1 + beta·f2 + gamma·f3·d̄(p) + delta·f4

Eviction (Algorithm 4): dynamic trigger T_up from (hit rate, latency);
tiered labels: protected (V >= 0.5·maxV and (Top-50 pattern or d̄ >= theta_d)),
normal (0.2..0.5·maxV, evicted ascending by V), evictable (< 0.2·maxV).

theta_d (§5.4-2): max(quantile95(degrees)/2, 10).

Baselines for benchmarks: LRUCache, LFUCache.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Hashable

import numpy as np

__all__ = ["dynamic_trigger", "protected_degree_threshold", "ValueCache",
           "TwoLevelCache", "LRUCache", "LFUCache", "AccessResult"]


def dynamic_trigger(hit_rate: float, latency_ms: float) -> float:
    """T_up per §5.3.2-4."""
    if hit_rate >= 0.8 and latency_ms <= 10.0:
        return 0.95
    if 0.6 <= hit_rate < 0.8 and 10.0 < latency_ms <= 20.0:
        return 0.90
    return 0.80


def protected_degree_threshold(degrees: np.ndarray) -> float:
    """theta_d = max(quantile95 / 2, 10) over valid vertex degrees."""
    d = np.asarray(degrees)
    d = d[d >= 0]
    if d.size == 0:
        return 10.0
    return max(float(np.quantile(d, 0.95)) / 2.0, 10.0)


@dataclasses.dataclass
class AccessResult:
    data: Any
    source: str          # master_cache|master_memory|slave_cache|slave_memory|not_found
    latency_ms: float
    cross_node: bool


# --------------------------------------------------------------------------- #
# single-level value cache (the building block for both levels)
# --------------------------------------------------------------------------- #
class ValueCache:
    """Capacity-bounded map with V(p)-driven tiered eviction (Algorithm 4)."""

    def __init__(self, capacity: int, theta_d: float = 10.0) -> None:
        self.capacity = max(int(capacity), 1)
        self.theta_d = theta_d
        self.store: dict[Hashable, Any] = {}
        self.value: dict[Hashable, float] = {}
        self.avg_deg: dict[Hashable, float] = {}
        self.freq: dict[Hashable, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -------------------------------------------------------------- #
    def get(self, key: Hashable, peek: bool = False) -> Any | None:
        """Lookup; peek=True reads without touching hit/miss/frequency
        state (the statistics only ever reflect authoritative accesses)."""
        if key in self.store:
            if not peek:
                self.hits += 1
                self.freq[key] = self.freq.get(key, 0) + 1
            return self.store[key]
        if not peek:
            self.misses += 1
        return None

    def put(self, key: Hashable, data: Any, value: float,
            avg_deg: float = 1.0, hit_rate: float = 1.0,
            latency_ms: float = 1.0) -> None:
        self.store[key] = data
        self.value[key] = float(value)
        self.avg_deg[key] = float(avg_deg)
        self.freq[key] = self.freq.get(key, 0)
        self.maybe_evict(hit_rate, latency_ms)

    def update_value(self, key: Hashable, value: float) -> None:
        if key in self.value:
            self.value[key] = float(value)

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def utilization(self) -> float:
        return len(self.store) / self.capacity

    # -------------------------------------------------------------- #
    def maybe_evict(self, hit_rate: float, latency_ms: float) -> int:
        """Algorithm 4. Returns number of evicted entries.

        ``self.store`` is the single source of truth throughout: tier
        classification, the normal-tier sweep, and the hard-capacity
        loop all iterate over store keys (reading V via ``value.get``
        with a 0.0 default).  Keying any loop on ``self.value`` instead
        used to spin forever / raise on an empty ``min()`` whenever the
        two maps diverged (a store key missing from value, or vice
        versa) — utilization is defined over the store, so only store
        drops can ever make progress.
        """
        t_up = dynamic_trigger(hit_rate, latency_ms)
        if self.utilization() <= t_up:
            return 0
        t_low = t_up - 0.1
        max_v = max((self.value.get(k, 0.0) for k in self.store),
                    default=0.0)
        top50 = set(sorted(self.freq, key=lambda k: -self.freq[k])[:50])
        normal, evictable = [], []
        for k in self.store:
            v = self.value.get(k, 0.0)
            if v >= 0.5 * max_v and (k in top50
                                     or self.avg_deg.get(k, 0.0) >= self.theta_d):
                continue                    # protected
            elif v >= 0.2 * max_v:
                normal.append(k)
            else:
                evictable.append(k)
        n_evicted = 0
        for k in evictable:
            self._drop(k)
            n_evicted += 1
        normal.sort(key=lambda k: self.value.get(k, 0.0))
        i = 0
        while self.utilization() > t_low and i < len(normal):
            self._drop(normal[i])
            n_evicted += 1
            i += 1
        # pathological: everything protected but still over hard capacity
        while len(self.store) > self.capacity:
            k = min(self.store, key=lambda k: self.value.get(k, 0.0))
            self._drop(k)
            n_evicted += 1
        self.evictions += n_evicted
        return n_evicted

    def _drop(self, key: Hashable) -> None:
        self.store.pop(key, None)
        self.value.pop(key, None)
        self.avg_deg.pop(key, None)
        self.freq.pop(key, None)


# --------------------------------------------------------------------------- #
# two-level master/slave hierarchy (Algorithm 3)
# --------------------------------------------------------------------------- #
# modeled access latencies (virtual ms) per storage tier
LAT_MASTER_CACHE = 0.05
LAT_MASTER_MEMORY = 0.2
LAT_SLAVE_CACHE = 0.5     # includes one network hop
LAT_SLAVE_MEMORY = 2.0


class TwoLevelCache:
    """Master (global Top-500) + per-slave (local Top-100) caches."""

    def __init__(self, n_slaves: int, master_capacity: int = 500,
                 slave_capacity: int = 100, theta_d: float = 10.0) -> None:
        self.master = ValueCache(master_capacity, theta_d)
        self.slaves = [ValueCache(slave_capacity, theta_d)
                       for _ in range(n_slaves)]
        # master memory index: key -> slave id owning the path data
        self.location: dict[Hashable, int] = {}
        self.cross_node_accesses = 0
        self.total_accesses = 0
        self.serves = 0             # accesses that returned data (any tier)
        self.degraded_admissions = 0   # results admitted during degraded serving

    def register(self, key: Hashable, slave_id: int) -> None:
        self.location[key] = slave_id

    # -------------------------------------------------------------- #
    def peek(self, key: Hashable,
             slave_data: dict[int, dict[Hashable, Any]],
             dead: "set[int] | frozenset[int]" = frozenset()) -> bool:
        """Read-only twin of `access`: True iff it would return data.

        Touches no LRU order and no hit/miss statistics — callers that
        only need to know whether a key is servable (e.g. megabatch
        dispatch deciding what to pack speculatively) must not perturb
        the cache state the authoritative access sequence will replay.
        Keep the tier order — including the dead-machine gate — in
        lockstep with `access` below: a divergence means dispatch skips
        packing for a query the consume step then cannot serve.
        """
        if self.master.get(key, peek=True) is not None:
            return True
        sid = self.location.get(key)
        if sid is None or sid in dead:
            return False
        if self.slaves[sid].get(key, peek=True) is not None:
            return True
        return key in slave_data.get(sid, {})

    def access(self, key: Hashable, slave_data: dict[int, dict[Hashable, Any]],
               dead: "set[int] | frozenset[int]" = frozenset()
               ) -> AccessResult:
        """Algorithm 3: strict priority access.

        ``dead`` holds unreachable slave ids: a key whose owning slave
        is dead cannot be fetched (neither its slave cache nor its
        memory tier exists anymore), so the lookup stops at the master
        memory index — the master cache (tier 1) still serves, since it
        lives on the master node.
        """
        self.total_accesses += 1
        # Step 1: master cache
        d = self.master.get(key)
        if d is not None:
            self.serves += 1
            return AccessResult(d, "master_cache", LAT_MASTER_CACHE, False)
        # Step 2: master memory index
        if key not in self.location:
            return AccessResult(None, "not_found", LAT_MASTER_MEMORY, False)
        sid = self.location[key]
        if sid in dead:             # owner unreachable: nothing to fetch
            return AccessResult(None, "not_found", LAT_MASTER_MEMORY, False)
        self.cross_node_accesses += 1
        # Step 3: slave cache
        d = self.slaves[sid].get(key)
        if d is not None:
            self.serves += 1
            return AccessResult(d, "slave_cache", LAT_SLAVE_CACHE, True)
        # Step 4: slave memory (full path storage)
        store = slave_data.get(sid, {})
        if key in store:
            self.serves += 1
            return AccessResult(store[key], "slave_memory", LAT_SLAVE_MEMORY,
                                True)
        return AccessResult(None, "not_found", LAT_SLAVE_MEMORY, True)

    def admit(self, key: Hashable, data: Any, value: float, avg_deg: float,
              slave_id: int, hit_rate: float, latency_ms: float,
              master_threshold: float = 0.0, degraded: bool = False) -> None:
        """Admission: slave cache always considers; master takes high-V paths.

        ``degraded`` marks results produced while at least one probed
        shard was served from a standby replica.  The *data* is still
        exact (standby images are bit-identical by construction), so the
        entry is admitted normally — the flag only feeds the
        ``degraded_admissions`` counter so operators can see how much of
        the cache was populated during a degraded window.
        """
        if degraded:
            self.degraded_admissions += 1
        self.slaves[slave_id].put(key, data, value, avg_deg, hit_rate,
                                  latency_ms)
        if value >= master_threshold:
            self.master.put(key, data, value, avg_deg, hit_rate, latency_ms)

    @property
    def hit_rate(self) -> float:
        """Fraction of accesses SERVED: data returned from ANY tier.

        A hit is an access the hierarchy satisfied without re-executing
        the query — master cache, slave cache, OR slave memory (tier 4
        of Algorithm 3 is still a serve: the path data exists and ships,
        it just pays the memory latency).  This matches the engine,
        whose `QueryTelemetry.cache_hits` flags every served lookup.
        Only `not_found` accesses count as misses.
        """
        t = self.total_accesses
        return self.serves / t if t else 0.0

    def drop_slave(self, slave_id: int) -> int:
        """Evict everything homed on one slave (the machine died).

        Clears the dead slave's ValueCache and removes every master
        memory-index entry pointing at it — entries that survive in the
        master cache keep serving (the master node is alive), but no
        lookup may ever route to the dead slave again.  Returns the
        number of keys whose home was dropped.
        """
        vc = self.slaves[slave_id]
        dropped = set(vc.store)
        for k in list(vc.store):
            vc._drop(k)
        homed = [k for k, s in self.location.items() if s == slave_id]
        for k in homed:
            del self.location[k]
        dropped.update(homed)
        return len(dropped)

    def purge(self, predicate) -> int:
        """Drop every key matching ``predicate`` from all tiers (both
        cache levels + the master memory index).  Used by the engine to
        retire result keys from superseded index epochs; returns the
        number of distinct keys removed."""
        stale = [k for k in self.location if predicate(k)]
        for k in stale:
            del self.location[k]
        removed = set(stale)
        for vc in (self.master, *self.slaves):
            for k in [k for k in vc.store if predicate(k)]:
                vc._drop(k)
                removed.add(k)
        return len(removed)


# --------------------------------------------------------------------------- #
# baselines
# --------------------------------------------------------------------------- #
class LRUCache:
    def __init__(self, capacity: int) -> None:
        self.capacity = max(int(capacity), 1)
        self.store: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable) -> Any | None:
        if key in self.store:
            self.store.move_to_end(key)
            self.hits += 1
            return self.store[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, data: Any, **_: Any) -> None:
        self.store[key] = data
        self.store.move_to_end(key)
        while len(self.store) > self.capacity:
            self.store.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


class LFUCache:
    def __init__(self, capacity: int) -> None:
        self.capacity = max(int(capacity), 1)
        self.store: dict[Hashable, Any] = {}
        self.freq: dict[Hashable, int] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable) -> Any | None:
        if key in self.store:
            self.freq[key] += 1
            self.hits += 1
            return self.store[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, data: Any, **_: Any) -> None:
        self.store[key] = data
        self.freq.setdefault(key, 0)
        while len(self.store) > self.capacity:
            k = min(self.freq, key=self.freq.get)
            self.store.pop(k, None)
            self.freq.pop(k, None)

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0
