"""Innovation 2 — four-dimensional path features with exponential decay.

Feature snapshot per cached path (all normalized to [0,1], §5.3.2-2):

  f1 normalized access frequency   freq(p)/max_freq        (1000-query window)
  f2 normalized co-occurrence      co_count(p)/max_co      (with Top-100 paths)
  f3 normalized recency            1 - (now-last)/window   (dynamic window)
  f4 path matching contribution    match_freq/total_freq

Decay: f_i(t) = clip(f_i(0) · e^{-t/tau}, 0, 1), tau = 300 s.

Dynamic statistical window (§5.4-1): 30 s (F >= 20 q/s), 60 s (5 < F < 20),
120 s (F <= 5).
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict, deque

__all__ = ["FeatureTracker", "TAU", "dynamic_window"]

TAU = 300.0
FREQ_WINDOW_QUERIES = 1000
TOP_K_COOCCUR = 100


def dynamic_window(queries_per_s: float) -> float:
    if queries_per_s >= 20:
        return 30.0
    if queries_per_s > 5:
        return 60.0
    return 120.0


@dataclasses.dataclass
class _PathStats:
    freq: int = 0
    co_count: int = 0
    last_time: float = 0.0
    first_time: float = 0.0
    match_freq: int = 0
    total_freq: int = 0
    avg_degree: float = 1.0


class FeatureTracker:
    """Sliding-window statistics for every observed path signature."""

    def __init__(self) -> None:
        self.stats: dict[object, _PathStats] = defaultdict(_PathStats)
        self.window: deque[tuple[float, tuple]] = deque()  # (time, sig-group)
        self.query_times: deque[float] = deque()
        self.now: float = 0.0

    # ---------------------------------------------------------------- #
    # recording
    # ---------------------------------------------------------------- #
    def record_query(self, t_s: float, sigs: list[object],
                     matched: dict[object, bool],
                     avg_degree: dict[object, float] | None = None) -> None:
        """One query accessed paths `sigs`; matched[sig]=True if the path
        contributed to final matches (feeds f4)."""
        self.now = max(self.now, t_s)
        self.query_times.append(t_s)
        while self.query_times and self.query_times[0] < t_s - 300.0:
            self.query_times.popleft()
        group = tuple(sigs)
        self.window.append((t_s, group))
        while len(self.window) > FREQ_WINDOW_QUERIES:
            self.window.popleft()
        for s in sigs:
            st = self.stats[s]
            if st.freq == 0:
                st.first_time = t_s
            st.freq += 1
            st.last_time = t_s
            st.total_freq += 1
            if matched.get(s, False):
                st.match_freq += 1
            if avg_degree and s in avg_degree:
                st.avg_degree = avg_degree[s]
        # co-occurrence with current top-100 signatures
        top = self.top_signatures(TOP_K_COOCCUR)
        top_set = set(top)
        for s in sigs:
            if top_set & (set(sigs) - {s}):
                self.stats[s].co_count += 1

    def top_signatures(self, k: int) -> list[object]:
        return sorted(self.stats, key=lambda s: -self.stats[s].freq)[:k]

    def queries_per_s(self) -> float:
        if len(self.query_times) < 2:
            return 0.0
        span = self.query_times[-1] - self.query_times[0]
        return len(self.query_times) / max(span, 1e-6)

    # ---------------------------------------------------------------- #
    # feature extraction
    # ---------------------------------------------------------------- #
    def features(self, sig: object) -> tuple[float, float, float, float]:
        """(f1, f2, f3, f4) with decay, normalized to [0,1]."""
        st = self.stats[sig]
        max_freq = max((x.freq for x in self.stats.values()), default=1)
        max_co = max((x.co_count for x in self.stats.values()), default=1)
        win = dynamic_window(self.queries_per_s())
        f1 = st.freq / max(max_freq, 1)
        f2 = st.co_count / max(max_co, 1)
        f3 = max(0.0, 1.0 - (self.now - st.last_time) / win)
        f4 = st.match_freq / st.total_freq if st.total_freq > 0 else 0.0
        age = self.now - st.first_time
        decay = math.exp(-age / TAU)
        return (min(max(f1 * decay, 0.0), 1.0),
                min(max(f2 * decay, 0.0), 1.0),
                min(max(f3, 0.0), 1.0),          # recency is already time-aware
                min(max(f4 * decay, 0.0), 1.0))

    def avg_degree(self, sig: object) -> float:
        return self.stats[sig].avg_degree
