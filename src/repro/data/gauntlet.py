"""Workload gauntlet (ISSUE 6): standing scenario matrix + three oracles.

The gauntlet is the correctness-tooling layer every perf PR stands on: a
matrix of (topology x query-shape x regime) cells, each verified against
three independent oracles:

  1. EXACTNESS — engine matches equal an independent brute-force
     reference matcher on the global graph (no index, no dominance
     pruning; pure label-filtered DFS).  This re-derives GNN-PE's
     no-false-dismissal guarantee from scratch per cell.
  2. MODE IDENTITY — matches (including order) and deterministic
     per-query counters (comm bytes, cross-shard rows, root-MBR skips,
     paths executed/skipped) are bit-identical across probe_mode
     host / device / plane and megabatch `query_batch`.
  3. INVARIANCE — answers stay equal to the (re-derived) brute-force
     reference after a forced hot migration and after an
     `apply_updates` delta batch mutates the graph.

Cells are deterministic per seed; `default_matrix` builds the standing
matrix used by tests/test_gauntlet.py and benchmarks/bench_gauntlet.py.
A dense cell whose shape is structurally absent from a topology (e.g. a
triangle in a bipartite graph) automatically degrades to the match-free
regime — that degradation is itself an adversarial cell.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.graph import GraphDelta, LabeledGraph
from repro.data.synthetic import (SHAPE_NAMES, bipartite_graph,
                                  community_graph, near_clique_graph,
                                  nws_graph, shape_query,
                                  skewed_label_graph)

__all__ = ["CellSpec", "CellReport", "TOPOLOGY_BUILDERS", "build_topology",
           "brute_force_matches", "default_matrix", "Gauntlet",
           "MODE_COUNTERS"]

# deterministic per-query counters that must agree across probe modes
MODE_COUNTERS = ("n_matches", "comm_bytes", "cross_shard_rows",
                 "shards_skipped", "paths_executed", "paths_skipped")

# scale=1.0 is the test-tier size; the benchmark tier passes scale>=2
TOPOLOGY_BUILDERS: dict[str, Callable[[int, float], LabeledGraph]] = {
    "community": lambda seed, scale: community_graph(
        int(160 * scale), 4, 0.12, 0.004, 12, seed=seed),
    "bipartite": lambda seed, scale: bipartite_graph(
        int(80 * scale), int(80 * scale), 4, 12, seed=seed),
    "nearclique": lambda seed, scale: near_clique_graph(
        int(140 * scale), 10, 0.85, 2.5, 12, seed=seed),
    "skewlabel": lambda seed, scale: skewed_label_graph(
        int(160 * scale), 5, 10, skew=1.3, seed=seed),
    "nws": lambda seed, scale: nws_graph(
        int(150 * scale), 6, 0.1, 8, seed=seed),
}


def build_topology(name: str, seed: int = 0, scale: float = 1.0
                   ) -> LabeledGraph:
    return TOPOLOGY_BUILDERS[name](seed, scale)


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One gauntlet cell: (topology x shape x regime)."""

    topology: str
    shape: str
    regime: str                  # "dense" | "free"
    query_seed: int = 1
    size: int | None = None     # shape size override (None = default)

    @property
    def name(self) -> str:
        return f"{self.topology}/{self.shape}/{self.regime}"


@dataclasses.dataclass
class CellReport:
    """Outcome of one cell's three-oracle verification."""

    cell: str
    family: str
    n_matches: int
    oracle_exact: bool = False
    oracle_modes: bool = False
    oracle_invariance: bool = False
    counters: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return (self.oracle_exact and self.oracle_modes
                and self.oracle_invariance)


# --------------------------------------------------------------------------- #
# oracle 1 reference: independent brute-force matcher
# --------------------------------------------------------------------------- #
def brute_force_matches(data: LabeledGraph, query: LabeledGraph,
                        limit: int | None = None) -> set[tuple[int, ...]]:
    """All injective label-preserving monomorphisms query -> data.

    Deliberately independent of repro.core.matching: a plain recursive
    DFS over label-filtered candidates with explicit edge verification
    and NO pruning index — the ground truth the dominance pipeline's
    no-false-dismissal claim is checked against.
    """
    n_q = query.n_vertices
    cand = [np.flatnonzero(data.labels == query.labels[v])
            for v in range(n_q)]
    adj_q = [query.neighbors(v).astype(np.int64) for v in range(n_q)]
    # static order: rarest label first, then ids (deterministic)
    order = sorted(range(n_q), key=lambda v: (cand[v].size, v))
    out: set[tuple[int, ...]] = set()
    mapping = np.full(n_q, -1, np.int64)

    def ok_edges(v: int, u_d: int) -> bool:
        nbrs = data.neighbors(u_d)
        for u in adj_q[v]:
            b = mapping[u]
            if b >= 0 and b not in nbrs:
                return False
        return True

    def rec(depth: int) -> bool:
        if depth == n_q:
            out.add(tuple(int(x) for x in mapping))
            return limit is not None and len(out) >= limit
        v = order[depth]
        for u_d in cand[v]:
            u_d = int(u_d)
            if (mapping == u_d).any() or not ok_edges(v, u_d):
                continue
            mapping[v] = u_d
            if rec(depth + 1):
                return True
            mapping[v] = -1
        return False

    rec(0)
    return out


# --------------------------------------------------------------------------- #
# standing matrix
# --------------------------------------------------------------------------- #
def default_matrix(topologies: dict[str, LabeledGraph],
                   shapes: tuple[str, ...] = SHAPE_NAMES,
                   regimes: tuple[str, ...] = ("dense", "free"),
                   query_seed: int = 1) -> list[CellSpec]:
    """Enumerate cells; a dense cell degrades to free when the shape is
    structurally absent from the topology (checked by trying to mine it
    with a few template seeds)."""
    cells: list[CellSpec] = []
    for tname, graph in topologies.items():
        for shape in shapes:
            # bipartite graphs have no odd cycles: use an even cycle
            size = 6 if (shape == "cycle" and tname == "bipartite") else None
            for regime in regimes:
                spec = CellSpec(tname, shape, regime,
                                query_seed=query_seed, size=size)
                if regime == "dense":
                    for s in range(query_seed, query_seed + 3):
                        try:
                            shape_query(graph, shape, "dense", size=size,
                                        seed=s)
                            spec = CellSpec(tname, shape, "dense",
                                            query_seed=s, size=size)
                            break
                        except ValueError:
                            spec = None
                    if spec is None:
                        continue        # the free cell still covers it
                cells.append(spec)
    return cells


# --------------------------------------------------------------------------- #
# harness
# --------------------------------------------------------------------------- #
class Gauntlet:
    """One topology's oracle harness: an engine + the three oracles.

    The engine is built once and deliberately MUTATES across cells
    (oracle 3 migrates shards and applies graph deltas), so later cells
    run against an engine with migration/update history — exactly the
    state a long-lived serving deployment accumulates.  Exactness is
    always checked against a fresh brute force on the engine's CURRENT
    graph, so the dense/free regime promise is only asserted for the
    pristine graph (tests do that separately via `brute_force_matches`
    on the generator output).
    """

    def __init__(self, graph: LabeledGraph, seed: int = 0,
                 n_machines: int = 2, shards_per_machine: int = 2,
                 gnn_train_steps: int = 8, max_path_length: int = 2):
        from repro.dist.cluster import DistributedGNNPE
        self.graph = graph
        self.eng = DistributedGNNPE.build(
            graph, n_machines, shards_per_machine=shards_per_machine,
            gnn_train_steps=gnn_train_steps, seed=seed,
            max_path_length=max_path_length)
        self.eng.use_cache = False      # raw cross-mode comparisons
        self._n_machines = n_machines
        self._invariance_clock = 0

    # -- oracle helpers ------------------------------------------------ #
    @staticmethod
    def counters(tel) -> dict:
        return {f: getattr(tel, f) for f in MODE_COUNTERS}

    def check_exact(self, query: LabeledGraph) -> list[tuple]:
        """Oracle 1: engine (host probe) vs brute force."""
        matches, _ = self.eng.query(query, probe_mode="host")
        ref = brute_force_matches(self.eng.graph, query)
        assert set(matches) == ref, (
            f"exactness violated: engine {len(matches)} vs "
            f"brute force {len(ref)}")
        assert len(matches) == len(set(matches)), "duplicate matches"
        return matches

    def check_modes(self, query: LabeledGraph,
                    batch_fill: list[LabeledGraph] | None = None) -> dict:
        """Oracle 2: bit-identity across host/device/plane/megabatch."""
        runs = {m: self.eng.query(query, probe_mode=m)
                for m in ("host", "device", "plane")}
        batch = [query] + list(batch_fill or [])
        mega = self.eng.query_batch(batch)
        runs["megabatch"] = mega[0]
        ref_matches, ref_tel = runs["host"]
        ref_counters = self.counters(ref_tel)
        for mode, (matches, tel) in runs.items():
            assert matches == ref_matches, (
                f"{mode}: matches diverge from host "
                f"({len(matches)} vs {len(ref_matches)})")
            got = self.counters(tel)
            assert got == ref_counters, (
                f"{mode}: counters diverge: {got} vs {ref_counters}")
        return ref_counters

    def check_invariance(self, query: LabeledGraph, seed: int = 0
                         ) -> int:
        """Oracle 3: a forced hot migration, then an `apply_updates`
        delta, must both leave every probe mode equal to a fresh brute
        force on the (current) graph."""
        from repro.dist.migration import hot_migrate
        eng = self.eng
        rng = np.random.default_rng(seed * 313 + self._invariance_clock)
        self._invariance_clock += 1

        # a) rebalancing epoch: migrate one shard to another machine
        sid = sorted(eng.shards)[
            int(rng.integers(len(eng.shards)))]
        src = eng.routing[sid]
        tgt = (src + 1) % self._n_machines
        res = hot_migrate(eng.shards, [(sid, src, tgt)], eng.routing,
                          rng=rng)
        assert res.crc_ok
        ref = brute_force_matches(eng.graph, query)
        for mode in ("host", "plane"):
            matches, _ = eng.query(query, probe_mode=mode)
            assert set(matches) == ref, f"post-migration {mode} diverged"

        # b) streaming delta: insert 2 fresh edges, delete 1 existing
        n = eng.graph.n_vertices
        adds = []
        while len(adds) < 2:
            u, v = (int(x) for x in rng.integers(0, n, size=2))
            if (u != v and not eng.graph.has_edge(u, v)
                    and u not in eng.retired_ids
                    and v not in eng.retired_ids):
                adds.append((u, v))
        del_e = eng.graph.edge_list[
            int(rng.integers(eng.graph.n_edges))]
        delta = GraphDelta.make(add_edges=adds, del_edges=[del_e])
        eng.apply_updates(delta, refit_pe=False)
        ref = brute_force_matches(eng.graph, query)
        for mode in ("host", "plane"):
            matches, _ = eng.query(query, probe_mode=mode)
            assert set(matches) == ref, f"post-update {mode} diverged"
        return len(ref)

    # -- cell driver --------------------------------------------------- #
    def make_query(self, spec: CellSpec) -> LabeledGraph:
        return shape_query(self.graph, spec.shape, spec.regime,
                           size=spec.size, seed=spec.query_seed)

    def run_cell(self, spec: CellSpec, invariance: bool = True
                 ) -> CellReport:
        """All three oracles on one cell; raises AssertionError with the
        cell name on any violation."""
        query = self.make_query(spec)
        rep = CellReport(cell=spec.name, family=spec.topology,
                         n_matches=0)
        try:
            matches = self.check_exact(query)
            rep.n_matches = len(matches)
            rep.oracle_exact = True
            rep.counters = self.check_modes(query)
            rep.oracle_modes = True
            if invariance:
                self.check_invariance(query, seed=spec.query_seed)
            rep.oracle_invariance = True
        except AssertionError as ex:
            raise AssertionError(f"[{spec.name}] {ex}") from ex
        return rep
