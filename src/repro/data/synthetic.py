"""Synthetic labeled graphs + GNN-PE query workload generator.

The paper evaluates on DBLP / Youtube / US-Patents and Newman-Watts-
Strogatz synthetic graphs; none are available offline, so the framework
generates NWS and power-law labeled graphs with matched statistics
(avg degree, label count) and the paper's query generator: random-walk
sampling with average-degree constraint avg_deg(q) in [3, 7] (§4.3-2).
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import LabeledGraph

__all__ = ["nws_graph", "power_law_graph", "community_graph",
           "bipartite_graph", "near_clique_graph", "skewed_label_graph",
           "random_walk_query", "shape_query", "SHAPE_NAMES",
           "is_connected", "make_workload", "DATASET_PRESETS",
           "make_dataset"]

# (n_vertices, avg_degree, n_labels) matched to the paper's datasets, scaled.
DATASET_PRESETS = {
    "dblp-s": (2000, 6, 8),
    "youtube-s": (3000, 5, 12),
    "uspatents-s": (4000, 4, 10),
    "nws-s": (2500, 6, 8),
}


def nws_graph(n: int, k: int, p: float, n_labels: int,
              seed: int = 0, label_skew: float = 0.0) -> LabeledGraph:
    """Newman-Watts-Strogatz: ring lattice (k nearest) + random shortcuts.

    label_skew > 0 draws labels from a Zipf(1+skew) distribution instead of
    balanced runs — rare labels then carry strong pruning signal (the
    PE-score benchmark regime).
    """
    rng = np.random.default_rng(seed)
    base = []
    half = max(k // 2, 1)
    for d in range(1, half + 1):
        u = np.arange(n)
        base.append(np.stack([u, (u + d) % n], axis=1))
    edges = np.concatenate(base, axis=0)
    n_short = int(p * edges.shape[0])
    extra = rng.integers(0, n, size=(n_short, 2))
    edges = np.concatenate([edges, extra], axis=0)
    if label_skew > 0:
        labels = np.minimum(rng.zipf(1.0 + label_skew, size=n) - 1,
                            n_labels - 1)
    else:
        # labels with locality (runs of identical labels -> affine shards)
        run = max(n // (n_labels * 8), 1)
        labels = (np.arange(n) // run) % n_labels
    return LabeledGraph.from_edges(n, edges, labels.astype(np.int32))


def power_law_graph(n: int, avg_deg: float, n_labels: int,
                    seed: int = 0, exponent: float = 2.2) -> LabeledGraph:
    """Chung-Lu style power-law graph with degree-correlated labels."""
    rng = np.random.default_rng(seed)
    w = (1.0 - rng.random(n)) ** (-1.0 / (exponent - 1.0))
    w *= avg_deg * n / w.sum()
    m = int(avg_deg * n / 2)
    p = w / w.sum()
    src = rng.choice(n, size=2 * m, p=p)
    dst = rng.choice(n, size=2 * m, p=p)
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    return LabeledGraph.from_edges(n, np.stack([src, dst], 1), labels)


# --------------------------------------------------------------------------- #
# gauntlet topologies (ISSUE 6): adversarial scenario generators beyond the
# label-uniform small-world seed.  All are deterministic per seed and take a
# `connected=True` promise enforced by deterministic bridge edges.
# --------------------------------------------------------------------------- #
def _components(n: int, edges: np.ndarray) -> np.ndarray:
    """Union-find component label per vertex (deterministic)."""
    parent = np.arange(n, dtype=np.int64)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = int(parent[x])
        return x

    for u, v in np.asarray(edges, np.int64).reshape(-1, 2):
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(v) for v in range(n)], dtype=np.int64)


def _bridge_components(n: int, edges: np.ndarray,
                       side: np.ndarray | None = None) -> np.ndarray:
    """Edges + deterministic bridges making the graph connected.

    Every non-main component is bridged to the (lowest-root) main
    component.  With `side` (bipartite left/right bool array) the bridge
    endpoint inside the main component is chosen on the OPPOSITE side of
    the attaching vertex, so bipartiteness survives.
    """
    comp = _components(n, edges)
    roots = np.unique(comp)
    if roots.size <= 1:
        return edges
    main = int(roots[0])
    main_verts = np.flatnonzero(comp == main)
    bridges = []
    for r in roots[1:]:
        u = int(np.flatnonzero(comp == r)[0])
        if side is not None:
            opp = main_verts[side[main_verts] != side[u]]
            v = int(opp[0]) if opp.size else int(main_verts[0])
        else:
            v = int(main_verts[0])
        bridges.append((u, v))
    return np.concatenate([edges.reshape(-1, 2),
                           np.asarray(bridges, edges.dtype)])


def is_connected(graph: LabeledGraph) -> bool:
    if graph.n_vertices == 0:
        return True
    return np.unique(
        _components(graph.n_vertices, graph.edge_list)).size == 1


def community_graph(n: int, n_communities: int, p_in: float, p_out: float,
                    n_labels: int, seed: int = 0,
                    connected: bool = True) -> LabeledGraph:
    """Planted-partition graph with community-correlated labels.

    Vertices are community-major (contiguous id blocks), so a locality-
    aware partitioner can recover the communities; labels are drawn from
    a per-community window of the label space, which concentrates label
    mass per shard (the regime where root-MBR skips and plan ranking
    actually differ between shards).
    """
    rng = np.random.default_rng(seed)
    comm = (np.arange(n) * n_communities) // max(n, 1)
    blocks = []
    for c in range(n_communities):
        vs = np.flatnonzero(comm == c)
        if vs.size >= 2:
            iu, iv = np.triu_indices(vs.size, k=1)
            keep = rng.random(iu.size) < p_in
            blocks.append(np.stack([vs[iu[keep]], vs[iv[keep]]], axis=1))
    n_inter = rng.binomial(max(n * (n_communities - 1), 1), p_out)
    if n_inter:
        u = rng.integers(0, n, size=n_inter)
        v = rng.integers(0, n, size=n_inter)
        cross = comm[u] != comm[v]
        blocks.append(np.stack([u[cross], v[cross]], axis=1))
    edges = (np.concatenate(blocks) if blocks
             else np.zeros((0, 2), np.int64))
    if connected:
        edges = _bridge_components(n, edges)
    win = max(n_labels // n_communities, 2)
    labels = (comm * win + rng.integers(0, win, size=n)) % n_labels
    return LabeledGraph.from_edges(n, edges, labels.astype(np.int32))


def bipartite_graph(n_left: int, n_right: int, avg_deg: float,
                    n_labels: int, seed: int = 0,
                    connected: bool = True) -> LabeledGraph:
    """Random bipartite graph; labels are side-disjoint (left labels from
    the lower half of the label space, right from the upper half), so any
    odd cycle — and any query edge between two same-side labels — is
    structurally match-free."""
    rng = np.random.default_rng(seed)
    n = n_left + n_right
    m = int(avg_deg * n / 2)
    u = rng.integers(0, n_left, size=m)
    v = n_left + rng.integers(0, n_right, size=m)
    edges = np.stack([u, v], axis=1)
    side = np.zeros(n, bool)
    side[n_left:] = True
    if connected:
        edges = _bridge_components(n, edges, side=side)
    half = max(n_labels // 2, 1)
    labels = np.where(side, half + rng.integers(0, max(n_labels - half, 1),
                                                size=n),
                      rng.integers(0, half, size=n))
    return LabeledGraph.from_edges(n, edges, labels.astype(np.int32))


def near_clique_graph(n: int, core_size: int, p_core: float,
                      avg_deg_out: float, n_labels: int, seed: int = 0,
                      connected: bool = True) -> LabeledGraph:
    """Dense near-clique core + sparse periphery: the match-DENSE regime
    (distributed enumeration papers' worst case — combinatorially many
    embeddings concentrated in one region)."""
    rng = np.random.default_rng(seed)
    core_size = min(core_size, n)
    iu, iv = np.triu_indices(core_size, k=1)
    keep = rng.random(iu.size) < p_core
    core_edges = np.stack([iu[keep], iv[keep]], axis=1)
    blocks = [core_edges]
    n_out = n - core_size
    if n_out > 0:
        m = int(avg_deg_out * n_out)
        u = core_size + rng.integers(0, n_out, size=m)
        v = rng.integers(0, n, size=m)
        blocks.append(np.stack([u, v], axis=1))
    edges = np.concatenate(blocks)
    if connected:
        edges = _bridge_components(n, edges)
    labels = rng.integers(0, n_labels, size=n)
    return LabeledGraph.from_edges(n, edges, labels.astype(np.int32))


def skewed_label_graph(n: int, avg_deg: float, n_labels: int,
                       skew: float = 1.2, seed: int = 0,
                       connected: bool = True) -> LabeledGraph:
    """Erdős–Rényi-style random graph with Zipf(1+skew) labels: a few
    labels dominate while the tail is rare — rare-label paths prune
    hard, the main signal PE-score plan ranking can exploit."""
    rng = np.random.default_rng(seed)
    m = int(avg_deg * n / 2)
    edges = rng.integers(0, n, size=(m, 2))
    if connected:
        edges = _bridge_components(n, edges)
    labels = np.minimum(rng.zipf(1.0 + skew, size=n) - 1, n_labels - 1)
    return LabeledGraph.from_edges(n, edges, labels.astype(np.int32))


def random_walk_query(graph: LabeledGraph, n_vertices: int,
                      seed: int = 0, avg_deg_range: tuple[float, float] = (3, 7),
                      max_tries: int = 50) -> LabeledGraph:
    """GNN-PE query generation: random-walk sample + avg-degree constraint.

    Returns the induced subgraph on the walk's vertex set (relabeled 0..k-1,
    labels inherited) — guaranteed to have >= 1 match in `graph` (itself).
    """
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        v = int(rng.integers(graph.n_vertices))
        visited = {v}
        cur = v
        steps = 0
        while len(visited) < n_vertices and steps < 20 * n_vertices:
            nbrs = graph.neighbors(cur)
            if nbrs.size == 0:
                break
            cur = int(rng.choice(nbrs))
            visited.add(cur)
            steps += 1
        if len(visited) < 2:
            continue
        sub, _ = graph.induced_subgraph(np.array(sorted(visited)))
        if sub.n_edges == 0:
            continue
        ad = sub.avg_degree()
        if avg_deg_range[0] <= ad <= avg_deg_range[1] or sub.n_vertices <= 4:
            return sub
    # fallback: one edge
    e = graph.edge_list[int(rng.integers(graph.n_edges))]
    sub, _ = graph.induced_subgraph(e)
    return sub


# --------------------------------------------------------------------------- #
# gauntlet query shapes (ISSUE 6): structured patterns beyond random-walk
# paths, with a controllable match-dense / match-free regime.
#
#   * dense: the shape is MINED from the data graph (an embedding is found
#     and labels are inherited from it), so >= 1 match is guaranteed by
#     construction — the witness mapping itself.
#   * free: labels are rewritten under a ZERO-match certificate, tried in
#     order of adversarial value: (1) an absent label PAIR on one query
#     edge (candidates survive the label filter; the probe/join must
#     prove emptiness), (2) a degree certificate (some label's max data
#     degree < a query vertex degree), (3) an absent label id (the
#     initial masks empty out), (4) a brute-force-verified random
#     relabeling.  ValueError if no certificate can be established.
# --------------------------------------------------------------------------- #
SHAPE_NAMES = ("triangle_tail", "cycle", "star", "pattern8")


def _shape_edges(shape: str, size: int, seed: int = 0
                 ) -> tuple[int, np.ndarray]:
    """(n_vertices, edges) template of a query shape.

    Sizes: triangle_tail = 3 + tail (size >= 4), cycle = ring of `size`,
    star = center + size-1 leaves, pattern8 = random connected pattern of
    `size` (>= 8) vertices: a seeded random spanning tree + 2 extra edges.
    """
    if shape == "triangle_tail":
        if size < 4:
            raise ValueError("triangle_tail needs size >= 4")
        edges = [(0, 1), (1, 2), (0, 2)]
        edges += [(2 if i == 3 else i - 1, i) for i in range(3, size)]
    elif shape == "cycle":
        if size < 3:
            raise ValueError("cycle needs size >= 3")
        edges = [(i, (i + 1) % size) for i in range(size)]
    elif shape == "star":
        if size < 3:
            raise ValueError("star needs size >= 3")
        edges = [(0, i) for i in range(1, size)]
    elif shape == "pattern8":
        if size < 8:
            raise ValueError("pattern8 needs size >= 8")
        rng = np.random.default_rng(seed ^ 0x8A77)
        edges = [(int(rng.integers(0, i)), i) for i in range(1, size)]
        present = set(edges)
        tries = 0
        while len(edges) < size + 1 and tries < 100:
            u, v = sorted(int(x) for x in rng.integers(0, size, size=2))
            if u != v and (u, v) not in present:
                edges.append((u, v))
                present.add((u, v))
            tries += 1
    else:
        raise ValueError(f"unknown shape {shape!r}")
    return size, np.asarray(edges, np.int32)


def _mine_embedding(graph: LabeledGraph, k: int, edges: np.ndarray,
                    rng: np.random.Generator,
                    max_nodes: int = 200_000) -> np.ndarray | None:
    """Find one label-free monomorphism image of the shape in `graph`.

    Randomized connected-expansion DFS with a bounded node budget;
    returns int64 [k] data vertices (shape vertex i -> image[i]) or None.
    """
    adj = [set() for _ in range(k)]
    for u, v in edges:
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    sdeg = np.array([len(a) for a in adj])
    order = [int(np.argmax(sdeg))]
    placed = {order[0]}
    while len(order) < k:
        frontier = [v for v in range(k) if v not in placed and
                    adj[v] & placed]
        if not frontier:
            frontier = [v for v in range(k) if v not in placed]
        v = max(frontier, key=lambda x: len(adj[x] & placed))
        order.append(v)
        placed.add(v)
    mapping = np.full(k, -1, np.int64)
    deg_d = graph.degrees
    budget = [max_nodes]

    def rec(depth: int) -> bool:
        if depth == k:
            return True
        if budget[0] <= 0:
            return False
        v = order[depth]
        back = [u for u in adj[v] if mapping[u] >= 0]
        if back:
            cand = graph.neighbors(int(mapping[back[0]]))
            for u in back[1:]:
                cand = cand[np.isin(cand,
                                    graph.neighbors(int(mapping[u])))]
        else:
            cand = np.arange(graph.n_vertices, dtype=np.int32)
        cand = cand[deg_d[cand] >= sdeg[v]]
        cand = cand[~np.isin(cand, mapping[mapping >= 0])]
        for u_d in rng.permutation(cand):
            budget[0] -= 1
            if budget[0] <= 0:
                return False
            mapping[v] = int(u_d)
            if rec(depth + 1):
                return True
            mapping[v] = -1
        return False

    return mapping if rec(0) else None


def _free_labels(graph: LabeledGraph, k: int, edges: np.ndarray,
                 rng: np.random.Generator, verify_tries: int = 32
                 ) -> np.ndarray:
    """Labels giving the shape a CERTIFIED zero-match regime (see above)."""
    n_labels = graph.n_labels
    present = np.flatnonzero(np.bincount(graph.labels,
                                         minlength=n_labels) > 0)
    labels = present[rng.integers(0, present.size, size=k)].astype(np.int32)
    qdeg = np.zeros(k, np.int64)
    for u, v in edges:
        qdeg[u] += 1
        qdeg[v] += 1
    # 1. absent label pair on a query edge (most adversarial: the label
    #    filter passes, the system must prove emptiness downstream)
    el = np.sort(graph.labels[graph.edge_list], axis=1)
    pair_keys = set((el[:, 0] * n_labels + el[:, 1]).tolist())
    absent_pairs = [(a, b) for a in present for b in present if a <= b
                    and a * n_labels + b not in pair_keys]
    if absent_pairs:
        a, b = absent_pairs[int(rng.integers(len(absent_pairs)))]
        eu, ev = edges[int(rng.integers(edges.shape[0]))]
        labels[eu], labels[ev] = a, b
        return labels
    # 2. degree certificate: a label whose max data degree cannot host
    #    the query's max-degree vertex
    deg_d = graph.degrees
    v_star = int(np.argmax(qdeg))
    for lab in present:
        sel = deg_d[graph.labels == lab]
        if sel.size and int(sel.max()) < int(qdeg[v_star]):
            labels[v_star] = lab
            return labels
    # 3. absent label id (in range, used by zero data vertices)
    absent = np.setdiff1d(np.arange(n_labels), present)
    if absent.size:
        labels[0] = absent[0]
        return labels
    # 4. verified fallback: random relabelings checked with the matcher
    from repro.core.matching import backtrack_join
    for _ in range(verify_tries):
        cand_labels = present[rng.integers(0, present.size,
                                           size=k)].astype(np.int32)
        q = LabeledGraph.from_edges(k, edges, cand_labels)
        masks = [(graph.labels == q.labels[v]) & (deg_d >= q.degrees[v])
                 for v in range(k)]
        if not backtrack_join(q, graph, masks, max_matches=1):
            return cand_labels
    raise ValueError("could not certify a match-free labeling")


def shape_query(graph: LabeledGraph, shape: str, regime: str = "dense",
                size: int | None = None, seed: int = 0) -> LabeledGraph:
    """Generate a structured query of `shape` against `graph`.

    regime="dense" guarantees >= 1 embedding (mined witness; raises
    ValueError when the shape does not occur in the graph — e.g. a
    triangle in a bipartite graph); regime="free" guarantees 0 matches
    via a certificate (see `_free_labels`).
    """
    if shape not in SHAPE_NAMES:
        raise ValueError(f"unknown shape {shape!r}; one of {SHAPE_NAMES}")
    if regime not in ("dense", "free"):
        raise ValueError(f"unknown regime {regime!r}")
    defaults = {"triangle_tail": 5, "cycle": 5, "star": 5, "pattern8": 8}
    k, edges = _shape_edges(shape, size or defaults[shape], seed=seed)
    rng = np.random.default_rng(seed * 9173 + 7)
    if regime == "dense":
        mapping = _mine_embedding(graph, k, edges, rng)
        if mapping is None:
            raise ValueError(
                f"shape {shape!r} (size {k}) has no embedding in the "
                f"graph — use regime='free' for this cell")
        return LabeledGraph.from_edges(k, edges, graph.labels[mapping])
    return LabeledGraph.from_edges(k, edges,
                                   _free_labels(graph, k, edges, rng))


def make_workload(graph: LabeledGraph, n_queries: int, size_range=(3, 6),
                  seed: int = 0, hot_fraction: float = 0.3,
                  n_hot: int = 5) -> list[LabeledGraph]:
    """Query stream with a hot set (repeated queries) — exercises caching
    and produces realistic load skew for the balancer."""
    rng = np.random.default_rng(seed)
    hot = [random_walk_query(graph, int(rng.integers(*size_range)),
                             seed=seed * 1000 + i) for i in range(n_hot)]
    out = []
    for i in range(n_queries):
        if rng.random() < hot_fraction and hot:
            out.append(hot[int(rng.integers(len(hot)))])
        else:
            out.append(random_walk_query(
                graph, int(rng.integers(*size_range)),
                seed=seed * 7777 + 13 * i))
    return out


def make_dataset(name: str, seed: int = 0) -> LabeledGraph:
    n, avg_deg, n_labels = DATASET_PRESETS[name]
    if name.startswith("nws"):
        return nws_graph(n, avg_deg, 0.1, n_labels, seed)
    return power_law_graph(n, avg_deg, n_labels, seed)
