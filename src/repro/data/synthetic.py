"""Synthetic labeled graphs + GNN-PE query workload generator.

The paper evaluates on DBLP / Youtube / US-Patents and Newman-Watts-
Strogatz synthetic graphs; none are available offline, so the framework
generates NWS and power-law labeled graphs with matched statistics
(avg degree, label count) and the paper's query generator: random-walk
sampling with average-degree constraint avg_deg(q) in [3, 7] (§4.3-2).
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import LabeledGraph

__all__ = ["nws_graph", "power_law_graph", "random_walk_query",
           "make_workload", "DATASET_PRESETS", "make_dataset"]

# (n_vertices, avg_degree, n_labels) matched to the paper's datasets, scaled.
DATASET_PRESETS = {
    "dblp-s": (2000, 6, 8),
    "youtube-s": (3000, 5, 12),
    "uspatents-s": (4000, 4, 10),
    "nws-s": (2500, 6, 8),
}


def nws_graph(n: int, k: int, p: float, n_labels: int,
              seed: int = 0, label_skew: float = 0.0) -> LabeledGraph:
    """Newman-Watts-Strogatz: ring lattice (k nearest) + random shortcuts.

    label_skew > 0 draws labels from a Zipf(1+skew) distribution instead of
    balanced runs — rare labels then carry strong pruning signal (the
    PE-score benchmark regime).
    """
    rng = np.random.default_rng(seed)
    base = []
    half = max(k // 2, 1)
    for d in range(1, half + 1):
        u = np.arange(n)
        base.append(np.stack([u, (u + d) % n], axis=1))
    edges = np.concatenate(base, axis=0)
    n_short = int(p * edges.shape[0])
    extra = rng.integers(0, n, size=(n_short, 2))
    edges = np.concatenate([edges, extra], axis=0)
    if label_skew > 0:
        labels = np.minimum(rng.zipf(1.0 + label_skew, size=n) - 1,
                            n_labels - 1)
    else:
        # labels with locality (runs of identical labels -> affine shards)
        run = max(n // (n_labels * 8), 1)
        labels = (np.arange(n) // run) % n_labels
    return LabeledGraph.from_edges(n, edges, labels.astype(np.int32))


def power_law_graph(n: int, avg_deg: float, n_labels: int,
                    seed: int = 0, exponent: float = 2.2) -> LabeledGraph:
    """Chung-Lu style power-law graph with degree-correlated labels."""
    rng = np.random.default_rng(seed)
    w = (1.0 - rng.random(n)) ** (-1.0 / (exponent - 1.0))
    w *= avg_deg * n / w.sum()
    m = int(avg_deg * n / 2)
    p = w / w.sum()
    src = rng.choice(n, size=2 * m, p=p)
    dst = rng.choice(n, size=2 * m, p=p)
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    return LabeledGraph.from_edges(n, np.stack([src, dst], 1), labels)


def random_walk_query(graph: LabeledGraph, n_vertices: int,
                      seed: int = 0, avg_deg_range: tuple[float, float] = (3, 7),
                      max_tries: int = 50) -> LabeledGraph:
    """GNN-PE query generation: random-walk sample + avg-degree constraint.

    Returns the induced subgraph on the walk's vertex set (relabeled 0..k-1,
    labels inherited) — guaranteed to have >= 1 match in `graph` (itself).
    """
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        v = int(rng.integers(graph.n_vertices))
        visited = {v}
        cur = v
        steps = 0
        while len(visited) < n_vertices and steps < 20 * n_vertices:
            nbrs = graph.neighbors(cur)
            if nbrs.size == 0:
                break
            cur = int(rng.choice(nbrs))
            visited.add(cur)
            steps += 1
        if len(visited) < 2:
            continue
        sub, _ = graph.induced_subgraph(np.array(sorted(visited)))
        if sub.n_edges == 0:
            continue
        ad = sub.avg_degree()
        if avg_deg_range[0] <= ad <= avg_deg_range[1] or sub.n_vertices <= 4:
            return sub
    # fallback: one edge
    e = graph.edge_list[int(rng.integers(graph.n_edges))]
    sub, _ = graph.induced_subgraph(e)
    return sub


def make_workload(graph: LabeledGraph, n_queries: int, size_range=(3, 6),
                  seed: int = 0, hot_fraction: float = 0.3,
                  n_hot: int = 5) -> list[LabeledGraph]:
    """Query stream with a hot set (repeated queries) — exercises caching
    and produces realistic load skew for the balancer."""
    rng = np.random.default_rng(seed)
    hot = [random_walk_query(graph, int(rng.integers(*size_range)),
                             seed=seed * 1000 + i) for i in range(n_hot)]
    out = []
    for i in range(n_queries):
        if rng.random() < hot_fraction and hot:
            out.append(hot[int(rng.integers(len(hot)))])
        else:
            out.append(random_walk_query(
                graph, int(rng.integers(*size_range)),
                seed=seed * 7777 + 13 * i))
    return out


def make_dataset(name: str, seed: int = 0) -> LabeledGraph:
    n, avg_deg, n_labels = DATASET_PRESETS[name]
    if name.startswith("nws"):
        return nws_graph(n, avg_deg, 0.1, n_labels, seed)
    return power_law_graph(n, avg_deg, n_labels, seed)
