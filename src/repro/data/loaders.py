"""Batch loaders: token streams, graph batches, neighbor sampling, recsys.

The neighbor sampler is a REAL fanout sampler over CSR (GraphSAGE-style,
layer fanouts e.g. [15, 10]) — the minibatch_lg shape's data path.  All
loaders yield fixed (padded) shapes so jitted steps never recompile.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.graph import LabeledGraph

__all__ = ["token_batches", "NeighborSampler", "graph_batch_arrays",
           "recsys_batches", "synthetic_token_stream"]


def synthetic_token_stream(vocab: int, seed: int = 0):
    """Deterministic synthetic LM corpus: mixture of Zipf unigrams and
    repeated n-gram motifs (so models actually learn structure)."""
    rng = np.random.default_rng(seed)
    motifs = [rng.integers(2, vocab, size=rng.integers(3, 8))
              for _ in range(64)]
    while True:
        if rng.random() < 0.5:
            m = motifs[rng.integers(len(motifs))]
            yield from m.tolist()
        else:
            z = rng.zipf(1.5)
            yield int(min(z, vocab - 1))


def token_batches(batch: int, seq: int, vocab: int, seed: int = 0
                  ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """(tokens, labels) [B, S] int32 batches from the synthetic stream."""
    stream = synthetic_token_stream(vocab, seed)
    need = batch * (seq + 1)
    while True:
        flat = np.fromiter((next(stream) for _ in range(need)),
                           dtype=np.int32, count=need)
        arr = flat.reshape(batch, seq + 1)
        yield arr[:, :-1].copy(), arr[:, 1:].copy()


@dataclasses.dataclass
class NeighborSampler:
    """Layer-wise fanout sampling from CSR adjacency (GraphSAGE).

    sample(seeds) -> (nodes, edge_src, edge_dst, n_valid_nodes,
    n_valid_edges) with FIXED padded sizes: seeds + sum-of-fanout bounds.
    Edge (src, dst) means "src is a sampled in-neighbor of dst" — messages
    flow src -> dst, matching the GNN zoo convention.
    """

    indptr: np.ndarray
    indices: np.ndarray
    fanouts: tuple[int, ...] = (15, 10)
    seed: int = 0

    def __post_init__(self) -> None:
        self.rng = np.random.default_rng(self.seed)

    def padded_sizes(self, n_seeds: int) -> tuple[int, int]:
        n, e = n_seeds, 0
        layer = n_seeds
        for f in self.fanouts:
            e += layer * f
            layer = layer * f
            n += layer
        return n, e

    def sample(self, seeds: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int, int]:
        n_pad, e_pad = self.padded_sizes(seeds.shape[0])
        nodes = list(seeds.astype(np.int64))
        node_pos = {int(v): i for i, v in enumerate(seeds)}
        src_list: list[int] = []
        dst_list: list[int] = []
        frontier = list(seeds.astype(np.int64))
        for f in self.fanouts:
            nxt: list[int] = []
            for v in frontier:
                beg, end = self.indptr[v], self.indptr[v + 1]
                nbrs = self.indices[beg:end]
                if nbrs.size == 0:
                    continue
                take = self.rng.choice(nbrs, size=min(f, nbrs.size),
                                       replace=False)
                for u in take:
                    u = int(u)
                    if u not in node_pos:
                        node_pos[u] = len(nodes)
                        nodes.append(u)
                        nxt.append(u)
                    src_list.append(node_pos[u])
                    dst_list.append(node_pos[int(v)])
            frontier = nxt
        n_valid, e_valid = len(nodes), len(src_list)
        nodes_arr = np.zeros(n_pad, dtype=np.int64)
        nodes_arr[:n_valid] = nodes
        src = np.zeros(e_pad, dtype=np.int32)
        dst = np.zeros(e_pad, dtype=np.int32)
        src[:e_valid] = src_list
        dst[:e_valid] = dst_list
        return nodes_arr, src, dst, n_valid, e_valid


def graph_batch_arrays(graph: LabeledGraph, d_feat: int, d_out: int,
                       n_pad: int | None = None, e_pad: int | None = None,
                       seed: int = 0):
    """Full-graph training arrays (features = label one-hot + noise)."""
    rng = np.random.default_rng(seed)
    n = graph.n_vertices
    e = graph.indices.shape[0]
    n_pad = n_pad or n
    e_pad = e_pad or e
    nodes = np.zeros((n_pad, d_feat), np.float32)
    onehot = np.eye(max(graph.n_labels, 1), dtype=np.float32)[graph.labels]
    nodes[:n, :min(d_feat, onehot.shape[1])] = \
        onehot[:, :min(d_feat, onehot.shape[1])]
    nodes[:n] += 0.01 * rng.normal(size=(n, d_feat))
    positions = np.zeros((n_pad, 3), np.float32)
    positions[:n] = rng.normal(size=(n, 3))
    src = np.zeros(e_pad, np.int32)
    dst = np.zeros(e_pad, np.int32)
    src[:e] = np.repeat(np.arange(n), np.diff(graph.indptr))
    dst[:e] = graph.indices
    nmask = np.zeros(n_pad, bool)
    nmask[:n] = True
    emask = np.zeros(e_pad, bool)
    emask[:e] = True
    targets = np.zeros((n_pad, d_out), np.float32)
    targets[np.arange(n), graph.labels % d_out] = 1.0
    return nodes, positions, src, dst, nmask, emask, targets


def recsys_batches(n_items: int, batch: int, seq: int, n_masked: int,
                   n_neg: int, seed: int = 0):
    """BERT4Rec cloze batches over synthetic session data (Zipf items)."""
    rng = np.random.default_rng(seed)
    while True:
        items = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
        items = np.clip(items, 1, n_items - 1).astype(np.int32)
        mask_pos = np.stack([
            rng.choice(seq, size=n_masked, replace=False)
            for _ in range(batch)]).astype(np.int32)
        labels = np.take_along_axis(items, mask_pos, axis=1)
        masked = items.copy()
        np.put_along_axis(masked, mask_pos, 0, axis=1)
        negatives = rng.integers(1, n_items, size=n_neg).astype(np.int32)
        yield masked, mask_pos, labels, negatives
