"""GNN architecture zoo: EGNN, GatedGCN, NequIP, MeshGraphNet.

All four are built on the same message-passing primitive — edge gather ->
message MLP -> `jax.ops.segment_sum` scatter (JAX has no CSR SpMM; the
segment-sum formulation IS the system's sparse layer, mirrored by the
Pallas kernel in repro/kernels/segment).

Regimes (kernel_taxonomy §GNN):
  * GatedGCN / MeshGraphNet — edge-featured MPNN (SpMM-like);
  * EGNN — cheap E(n) equivariance (scalar distances, coordinate updates);
  * NequIP — E(3) tensor-product equivariance: real spherical harmonics
    (l <= 2) x radial Bessel basis, Gaunt-coefficient tensor products
    (the unique invariant coupling, CG up to per-channel normalization),
    gate nonlinearity.  The Gaunt tensor is computed once by exact
    Gauss-Legendre quadrature (products of l<=2 SH are band-limited).

Batch layout: GraphsTuple-style flat arrays with masks (static shapes for
jit/pjit): nodes [N, F], edges (src/dst [E]), positions [N, 3] for the
equivariant models, graph_ids [N] for batched small graphs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain
from repro.models.common import init_dense

__all__ = ["GNNBatch", "GNNConfigZoo", "init_gnn", "apply_gnn", "gnn_loss",
           "real_sph_harm_l2", "gaunt_tensor"]


@dataclasses.dataclass(frozen=True)
class GNNBatch:
    """Flat padded graph batch.

    nodes:     [N, F] float input features.
    positions: [N, 3] float (equivariant models; zeros otherwise).
    edge_src:  [E] int32.
    edge_dst:  [E] int32.
    edge_feats:[E, Fe] float (zeros if unused).
    node_mask: [N] bool.
    edge_mask: [E] bool.
    graph_ids: [N] int32 (for batched molecule graphs; zeros = single graph).
    n_graphs:  int (static).
    """

    nodes: jnp.ndarray
    positions: jnp.ndarray
    edge_src: jnp.ndarray
    edge_dst: jnp.ndarray
    edge_feats: jnp.ndarray
    node_mask: jnp.ndarray
    edge_mask: jnp.ndarray
    graph_ids: jnp.ndarray
    n_graphs: int = 1


@dataclasses.dataclass(frozen=True)
class GNNConfigZoo:
    arch: str                    # egnn | gatedgcn | nequip | meshgraphnet
    n_layers: int
    d_hidden: int
    d_in: int
    d_edge_in: int = 0
    d_out: int = 1
    # nequip-specific
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    # meshgraphnet-specific
    mlp_layers: int = 2
    dtype: Any = jnp.float32


# --------------------------------------------------------------------------- #
# shared helpers
# --------------------------------------------------------------------------- #
def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": init_dense(ks[i], (dims[i], dims[i + 1]), dtype),
             "b": jnp.zeros(dims[i + 1], dtype)} for i in range(len(dims) - 1)]


def _mlp_apply(layers, x, act=jax.nn.silu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(layers) or final_act:
            x = act(x)
    return x


def _scatter_sum(msgs: jnp.ndarray, dst: jnp.ndarray, n: int,
                 mask: jnp.ndarray) -> jnp.ndarray:
    msgs = jnp.where(mask[:, None], msgs, 0.0)
    return jax.ops.segment_sum(msgs, dst, num_segments=n)


# --------------------------------------------------------------------------- #
# EGNN  [arXiv:2102.09844]
# --------------------------------------------------------------------------- #
def _init_egnn(key, cfg: GNNConfigZoo):
    ks = jax.random.split(key, 2 + 4 * cfg.n_layers)
    d = cfg.d_hidden
    p = {"embed": _mlp_init(ks[0], [cfg.d_in, d], cfg.dtype),
         "out": _mlp_init(ks[1], [d, d, cfg.d_out], cfg.dtype),
         "layers": []}
    for i in range(cfg.n_layers):
        p["layers"].append({
            "phi_e": _mlp_init(ks[2 + 4 * i], [2 * d + 1 + cfg.d_edge_in,
                                               d, d], cfg.dtype),
            "phi_x": _mlp_init(ks[3 + 4 * i], [d, d, 1], cfg.dtype),
            "phi_h": _mlp_init(ks[4 + 4 * i], [2 * d, d, d], cfg.dtype),
            "phi_inf": _mlp_init(ks[5 + 4 * i], [d, 1], cfg.dtype),
        })
    return p


def _apply_egnn(params, cfg: GNNConfigZoo, batch: GNNBatch):
    n = batch.nodes.shape[0]
    src, dst = batch.edge_src, batch.edge_dst
    h = _mlp_apply(params["embed"], batch.nodes)
    x = batch.positions
    d = cfg.d_hidden
    for lp in params["layers"]:
        rel = x[src] - x[dst]
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        # first phi_e layer decomposed node-wise (matmul-before-gather):
        # W [2d+1+fe, d] rows split into src / dst / scalar blocks — the
        # node-side products run on N rows instead of E.
        w0 = lp["phi_e"][0]
        pre_src = h @ w0["w"][:d]
        pre_dst = h @ w0["w"][d:2 * d]
        z = pre_src[src] + pre_dst[dst] + d2 @ w0["w"][2 * d:2 * d + 1] \
            + w0["b"]
        if cfg.d_edge_in:
            z = z + batch.edge_feats @ w0["w"][2 * d + 1:]
        m = _mlp_apply(lp["phi_e"][1:], jax.nn.silu(z), final_act=True)
        gate = jax.nn.sigmoid(_mlp_apply(lp["phi_inf"], m))
        m = m * gate
        # coordinate update (E(n)-equivariant): x_i += mean_j rel * phi_x(m)
        coef = _mlp_apply(lp["phi_x"], m)
        upd = _scatter_sum(rel * coef, dst, n, batch.edge_mask)
        deg = _scatter_sum(jnp.ones_like(d2), dst, n, batch.edge_mask)
        x = x + upd / jnp.maximum(deg, 1.0)
        agg = _scatter_sum(m, dst, n, batch.edge_mask)
        h = h + _mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], -1))
    return _mlp_apply(params["out"], h), x


# --------------------------------------------------------------------------- #
# GatedGCN  [arXiv:2003.00982 / 1711.07553]
# --------------------------------------------------------------------------- #
def _init_gatedgcn(key, cfg: GNNConfigZoo):
    ks = jax.random.split(key, 3 + 5 * cfg.n_layers)
    d = cfg.d_hidden
    p = {"embed": _mlp_init(ks[0], [cfg.d_in, d], cfg.dtype),
         "embed_e": _mlp_init(ks[1], [max(cfg.d_edge_in, 1), d], cfg.dtype),
         "out": _mlp_init(ks[2], [d, d, cfg.d_out], cfg.dtype),
         "layers": []}
    for i in range(cfg.n_layers):
        b = 3 + 5 * i
        p["layers"].append({
            "U": init_dense(ks[b], (d, d), cfg.dtype),
            "V": init_dense(ks[b + 1], (d, d), cfg.dtype),
            "A": init_dense(ks[b + 2], (d, d), cfg.dtype),
            "B": init_dense(ks[b + 3], (d, d), cfg.dtype),
            "C": init_dense(ks[b + 4], (d, d), cfg.dtype),
            "ln_h": jnp.ones(d, cfg.dtype),
            "ln_e": jnp.ones(d, cfg.dtype),
        })
    return p


def _layernorm(x, g):
    m = x.mean(-1, keepdims=True)
    v = ((x - m) ** 2).mean(-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-6) * g


def _apply_gatedgcn(params, cfg: GNNConfigZoo, batch: GNNBatch):
    n = batch.nodes.shape[0]
    src, dst = batch.edge_src, batch.edge_dst
    h = _mlp_apply(params["embed"], batch.nodes)
    ef = batch.edge_feats if cfg.d_edge_in else \
        jnp.ones((src.shape[0], 1), cfg.dtype)
    e = _mlp_apply(params["embed_e"], ef)
    for lp in params["layers"]:
        # matmul-before-gather: the node-side projections run in the NODE
        # domain (N rows) and are then gathered to edges — identical math,
        # E/N x fewer dot FLOPs (~12x on ogb_products).  See EXPERIMENTS
        # §Perf hillclimb 1.
        h_a = h @ lp["A"]
        h_b = h @ lp["B"]
        h_v = h @ lp["V"]
        e_new = h_a[src] + h_b[dst] + e @ lp["C"]
        eta = jax.nn.sigmoid(e_new)
        msg = eta * h_v[src]
        num = _scatter_sum(msg, dst, n, batch.edge_mask)
        den = _scatter_sum(eta, dst, n, batch.edge_mask)
        h_new = h @ lp["U"] + num / (den + 1e-6)
        h = h + jax.nn.relu(_layernorm(h_new, lp["ln_h"]))
        e = e + jax.nn.relu(_layernorm(e_new, lp["ln_e"]))
    return _mlp_apply(params["out"], h), batch.positions


# --------------------------------------------------------------------------- #
# NequIP  [arXiv:2101.03164] — E(3) tensor-product message passing
# --------------------------------------------------------------------------- #
@functools.lru_cache(maxsize=1)
def _sph_quadrature(n_theta: int = 12, n_phi: int = 24):
    """Gauss-Legendre x uniform-phi sphere quadrature (exact to band 2l)."""
    x, w = np.polynomial.legendre.leggauss(n_theta)     # x = cos(theta)
    phi = 2 * np.pi * (np.arange(n_phi) + 0.5) / n_phi
    ct, ph = np.meshgrid(x, phi, indexing="ij")
    st = np.sqrt(1 - ct ** 2)
    pts = np.stack([st * np.cos(ph), st * np.sin(ph), ct], -1).reshape(-1, 3)
    wts = (np.repeat(w, n_phi) * (2 * np.pi / n_phi)).reshape(-1)
    return pts, wts


def real_sph_harm_l2(r: np.ndarray | jnp.ndarray) -> jnp.ndarray:
    """Real spherical harmonics l=0,1,2 of unit vectors r [.., 3] -> [.., 9].

    Component order: (l=0) 1; (l=1) y, z, x; (l=2) xy, yz, 3z^2-1, xz,
    x^2-y^2 — the standard e3nn ordering, orthonormalized on the sphere.
    """
    xp = jnp if isinstance(r, jnp.ndarray) else np
    x, y, z = r[..., 0], r[..., 1], r[..., 2]
    c0 = 0.5 * np.sqrt(1 / np.pi)
    c1 = np.sqrt(3 / (4 * np.pi))
    out = [
        xp.full(x.shape, c0) if xp is np else jnp.full(x.shape, c0),
        c1 * y, c1 * z, c1 * x,
        0.5 * np.sqrt(15 / np.pi) * x * y,
        0.5 * np.sqrt(15 / np.pi) * y * z,
        0.25 * np.sqrt(5 / np.pi) * (3 * z * z - 1.0),
        0.5 * np.sqrt(15 / np.pi) * x * z,
        0.25 * np.sqrt(15 / np.pi) * (x * x - y * y),
    ]
    return xp.stack(out, axis=-1)


@functools.lru_cache(maxsize=1)
def gaunt_tensor() -> np.ndarray:
    """G[a, b, c] = ∫ Y_a Y_b Y_c dΩ over the 9 real SH (l <= 2).

    The unique (up to normalization) E(3)-invariant 3-tensor coupling —
    the CG coefficients of the real basis up to per-(l1,l2,l3) scale.
    """
    pts, wts = _sph_quadrature()
    ysh = np.asarray(real_sph_harm_l2(pts))            # [Q, 9]
    g = np.einsum("qa,qb,qc,q->abc", ysh, ysh, ysh, wts)
    g[np.abs(g) < 1e-10] = 0.0
    return g.astype(np.float32)


def _bessel_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """NequIP radial basis: sin(n pi r / rc) / r with cosine cutoff."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rr = jnp.maximum(r, 1e-6)[..., None]
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * rr / cutoff) / rr
    fc = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r / cutoff, 0, 1)) + 1.0)
    return basis * fc[..., None]


def _init_nequip(key, cfg: GNNConfigZoo):
    ks = jax.random.split(key, 3 + 3 * cfg.n_layers)
    c = cfg.d_hidden                    # channels per irrep component
    p = {"embed": _mlp_init(ks[0], [cfg.d_in, c], cfg.dtype),
         "out": _mlp_init(ks[1], [c, c, cfg.d_out], cfg.dtype),
         "layers": []}
    for i in range(cfg.n_layers):
        b = 2 + 3 * i
        p["layers"].append({
            # radial net: rbf -> per-l path weights (shared across the m
            # components of each irrep — the NequIP radial-weight structure;
            # per-component weights would break equivariance)
            "radial": _mlp_init(ks[b], [cfg.n_rbf, 2 * c, 3 * c], cfg.dtype),
            # channel mixing per l (shared across the m components of each
            # irrep — anything finer breaks rotation equivariance)
            "mix": init_dense(ks[b + 1], (3, c, c), cfg.dtype, scale=0.3),
            "gate": _mlp_init(ks[b + 2], [c, c], cfg.dtype),
        })
    return p


def _apply_nequip(params, cfg: GNNConfigZoo, batch: GNNBatch):
    """Features: [N, 9, C] (9 = SH components l<=2, C channels)."""
    n = batch.nodes.shape[0]
    src, dst = batch.edge_src, batch.edge_dst
    c = cfg.d_hidden
    g = jnp.asarray(gaunt_tensor())                     # [9, 9, 9]
    scalars = _mlp_apply(params["embed"], batch.nodes)  # [N, C]
    feats = jnp.zeros((n, 9, c), cfg.dtype).at[:, 0, :].set(scalars)

    rel = batch.positions[src] - batch.positions[dst]
    r = jnp.sqrt(jnp.sum(rel * rel, -1) + 1e-12)
    rhat = rel / r[:, None]
    ysh = real_sph_harm_l2(rhat)                        # [E, 9]
    # degenerate edges (self-loops / zero padding, r ~ 0) have no direction:
    # Y(0) carries a non-rotating constant in the l=2 channel that silently
    # breaks equivariance — zero those messages entirely.
    ok = (r > 1e-5)[:, None]      # note: r >= 1e-6 by the eps under the sqrt
    ysh = ysh * ok
    rbf = _bessel_rbf(r, cfg.n_rbf, cfg.cutoff) * ok    # [E, n_rbf]

    l_of = jnp.asarray([0, 1, 1, 1, 2, 2, 2, 2, 2])
    for lp in params["layers"]:
        w = _mlp_apply(lp["radial"], rbf).reshape(-1, 3, c)[:, l_of, :]
        # tensor product: msg[e, c_out_sh, ch] =
        #   sum_{a,b} G[a, b, c_out] * feat_src[e, a, ch] * (w*Y)[e, b, ch]
        edge_sh = ysh[:, :, None] * w                   # [E, 9, C]
        fsrc = feats[src]                               # [E, 9, C]
        msg = jnp.einsum("abc,eah,ebh->ech", g, fsrc, edge_sh)
        agg = _scatter_sum(msg.reshape(-1, 9 * c), dst, n,
                           batch.edge_mask).reshape(n, 9, c)
        mix = lp["mix"][l_of]                           # [9, C, C], per-l
        upd = jnp.einsum("sji,nsj->nsi", mix, agg)
        # gate nonlinearity: scalars pass through silu; l>0 gated by scalars
        gate = jax.nn.sigmoid(_mlp_apply(lp["gate"], upd[:, 0, :]))
        upd = upd.at[:, 0, :].set(jax.nn.silu(upd[:, 0, :]))
        upd = upd.at[:, 1:, :].multiply(gate[:, None, :])
        feats = feats + upd
    return _mlp_apply(params["out"], feats[:, 0, :]), batch.positions


# --------------------------------------------------------------------------- #
# MeshGraphNet  [arXiv:2010.03409]
# --------------------------------------------------------------------------- #
def _init_mgn(key, cfg: GNNConfigZoo):
    ks = jax.random.split(key, 3 + 2 * cfg.n_layers)
    d = cfg.d_hidden
    hidden = [d] * cfg.mlp_layers
    p = {"enc_n": _mlp_init(ks[0], [cfg.d_in] + hidden, cfg.dtype),
         "enc_e": _mlp_init(ks[1], [max(cfg.d_edge_in, 1) + 4] + hidden,
                            cfg.dtype),
         "dec": _mlp_init(ks[2], hidden + [cfg.d_out], cfg.dtype),
         "layers": []}
    for i in range(cfg.n_layers):
        p["layers"].append({
            "edge_mlp": _mlp_init(ks[3 + 2 * i], [3 * d] + hidden, cfg.dtype),
            "node_mlp": _mlp_init(ks[4 + 2 * i], [2 * d] + hidden, cfg.dtype),
        })
    return p


def _apply_mgn(params, cfg: GNNConfigZoo, batch: GNNBatch):
    n = batch.nodes.shape[0]
    src, dst = batch.edge_src, batch.edge_dst
    rel = batch.positions[src] - batch.positions[dst]
    rn = jnp.sqrt(jnp.sum(rel * rel, -1, keepdims=True) + 1e-12)
    ef = batch.edge_feats if cfg.d_edge_in else \
        jnp.ones((src.shape[0], 1), cfg.dtype)
    h = _mlp_apply(params["enc_n"], batch.nodes, final_act=True)
    e = _mlp_apply(params["enc_e"], jnp.concatenate([ef, rel, rn], -1),
                   final_act=True)
    d = cfg.d_hidden
    for lp in params["layers"]:
        # first edge_mlp layer decomposed: src/dst blocks run node-side
        w0 = lp["edge_mlp"][0]
        pre_s = h @ w0["w"][d:2 * d]
        pre_d = h @ w0["w"][2 * d:]
        z = e @ w0["w"][:d] + pre_s[src] + pre_d[dst] + w0["b"]
        e = e + _mlp_apply(lp["edge_mlp"][1:], jax.nn.silu(z),
                           final_act=True)
        agg = _scatter_sum(e, dst, n, batch.edge_mask)
        h = h + _mlp_apply(lp["node_mlp"], jnp.concatenate([h, agg], -1),
                           final_act=True)
    return _mlp_apply(params["dec"], h), batch.positions


# --------------------------------------------------------------------------- #
# dispatcher
# --------------------------------------------------------------------------- #
_INIT = {"egnn": _init_egnn, "gatedgcn": _init_gatedgcn,
         "nequip": _init_nequip, "meshgraphnet": _init_mgn}
_APPLY = {"egnn": _apply_egnn, "gatedgcn": _apply_gatedgcn,
          "nequip": _apply_nequip, "meshgraphnet": _apply_mgn}


def init_gnn(cfg: GNNConfigZoo, key: jax.Array) -> dict[str, Any]:
    return _INIT[cfg.arch](key, cfg)


def apply_gnn(params: dict[str, Any], cfg: GNNConfigZoo, batch: GNNBatch
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """-> (node outputs [N, d_out], final positions [N, 3])."""
    nodes = constrain(batch.nodes, "nodes", None)
    batch = dataclasses.replace(
        batch, nodes=nodes,
        edge_src=constrain(batch.edge_src, "edges"),
        edge_dst=constrain(batch.edge_dst, "edges"))
    out, pos = _APPLY[cfg.arch](params, cfg, batch)
    return constrain(out, "nodes", None), pos


def gnn_loss(params: dict[str, Any], cfg: GNNConfigZoo, batch: GNNBatch,
             targets: jnp.ndarray) -> jnp.ndarray:
    """Masked MSE on node outputs (regression form; classification uses CE
    in the task head — benchmarks use MSE throughout for uniformity)."""
    out, _ = apply_gnn(params, cfg, batch)
    err = ((out - targets) ** 2).mean(-1)
    m = batch.node_mask.astype(jnp.float32)
    return (err * m).sum() / jnp.maximum(m.sum(), 1.0)
