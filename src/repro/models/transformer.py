"""Unified decoder LM: GQA / sliding-window / MLA attention, dense / MoE FFN.

One model covers the five assigned LM architectures via LMConfig:
  yi-6b            GQA (32H/4KV), RoPE, SwiGLU
  h2o-danube-1.8b  GQA (32H/8KV) + sliding-window attention
  glm4-9b          GQA (32H/2KV), RoPE
  qwen2-moe-a2.7b  GQA + MoE (4 shared + 60 routed top-4)
  deepseek-v3-671b MLA + MoE (1 shared + 256 routed top-8, sigmoid router,
                   3 leading dense layers) + optional MTP head

Structure: scan-over-layers (homogeneous stacks; DeepSeek uses two stacks —
dense-FFN prefix, then MoE), remat per layer, logical-axis sharding
annotations throughout (repro.dist.sharding).

Public entry points:
  init_params(cfg, key)                              parameter pytree
  lm_loss(params, cfg, tokens, labels)               training loss
  prefill(params, cfg, tokens)                       logits (inference)
  init_cache(cfg, batch, t_max)                      KV cache pytree
  decode_step(params, cfg, cache, tokens, pos)       one-token serve step
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import moe as moe_lib
from repro.models.common import (apply_rope, blockwise_attention,
                                 causal_mask_bias, cross_entropy_loss,
                                 dense_attention, init_dense, rms_norm,
                                 swiglu)

__all__ = ["MLAConfig", "LMConfig", "init_params", "lm_loss", "prefill",
           "init_cache", "decode_step"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    mla: MLAConfig | None = None
    moe: moe_lib.MoEConfig | None = None
    mtp: bool = False
    mtp_weight: float = 0.3
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    remat: bool = True
    blockwise_from: int = 8192       # use online-softmax attention at S >= this
    block_kv: int = 1024
    # unroll=True replaces scan-over-layers with a Python loop: XLA's
    # cost_analysis counts a scan body ONCE, so roofline calibration lowers
    # small unrolled depths and extrapolates (benchmarks/flops_calib.py).
    unroll: bool = False

    @property
    def qk_dim(self) -> int:
        if self.mla is not None:
            return self.mla.nope_head_dim + self.mla.rope_head_dim
        return self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (drives 6·N·D roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        n = 2 * v * d                                   # embed + head
        if self.mla is None:
            attn = d * (self.n_heads * self.head_dim) * 2 \
                + d * (self.n_kv_heads * self.head_dim) * 2
        else:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * self.qk_dim
                    + d * (m.kv_lora_rank + m.rope_head_dim)
                    + m.kv_lora_rank * self.n_heads
                    * (m.nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        dense_ffn = 3 * d * self.d_ff
        n += self.n_layers * attn + self.n_layers * 2 * d
        if self.moe is None:
            n += self.n_layers * dense_ffn
        else:
            mo = self.moe
            expert = 3 * d * mo.d_ff_expert
            moe_layers = self.n_layers - mo.first_dense
            n += mo.first_dense * dense_ffn
            n += moe_layers * (mo.n_experts * expert
                               + mo.n_shared * expert + d * mo.n_experts)
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        expert = 3 * self.d_model * mo.d_ff_expert
        moe_layers = self.n_layers - mo.first_dense
        total = self.param_count()
        inactive = moe_layers * (mo.n_experts - mo.top_k) * expert
        return int(total - inactive)


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def _init_attn(key: jax.Array, cfg: LMConfig) -> dict[str, Any]:
    dt = cfg.dtype
    d = cfg.d_model
    if cfg.mla is None:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "wq": init_dense(k1, (d, cfg.n_heads * cfg.head_dim), dt),
            "wk": init_dense(k2, (d, cfg.n_kv_heads * cfg.head_dim), dt),
            "wv": init_dense(k3, (d, cfg.n_kv_heads * cfg.head_dim), dt),
            "wo": init_dense(k4, (cfg.n_heads * cfg.head_dim, d), dt),
        }
    m = cfg.mla
    ks = jax.random.split(key, 7)
    return {
        "w_dq": init_dense(ks[0], (d, m.q_lora_rank), dt),
        "q_norm": jnp.ones(m.q_lora_rank, dt),
        "w_uq": init_dense(ks[1], (m.q_lora_rank,
                                   cfg.n_heads * cfg.qk_dim), dt),
        "w_dkv": init_dense(ks[2], (d, m.kv_lora_rank), dt),
        "kv_norm": jnp.ones(m.kv_lora_rank, dt),
        "w_kpe": init_dense(ks[3], (d, m.rope_head_dim), dt),
        "w_uk": init_dense(ks[4], (m.kv_lora_rank,
                                   cfg.n_heads * m.nope_head_dim), dt),
        "w_uv": init_dense(ks[5], (m.kv_lora_rank,
                                   cfg.n_heads * m.v_head_dim), dt),
        "wo": init_dense(ks[6], (cfg.n_heads * m.v_head_dim, d), dt),
    }


def _init_layer(key: jax.Array, cfg: LMConfig, use_moe: bool
                ) -> dict[str, Any]:
    dt = cfg.dtype
    d = cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "ln1": jnp.ones(d, dt),
        "ln2": jnp.ones(d, dt),
        "attn": _init_attn(k1, cfg),
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe_params(k2, d, cfg.moe, dt)
    else:
        p["ffn"] = {
            "w_gate": init_dense(k3, (d, cfg.d_ff), dt),
            "w_up": init_dense(k4, (d, cfg.d_ff), dt),
            "w_down": init_dense(k5, (cfg.d_ff, d), dt),
        }
    return p


def init_params(cfg: LMConfig, key: jax.Array) -> dict[str, Any]:
    ks = jax.random.split(key, 6)
    dt = cfg.dtype
    first_dense = cfg.moe.first_dense if cfg.moe is not None else cfg.n_layers
    n_dense = min(first_dense, cfg.n_layers)
    n_moe = cfg.n_layers - n_dense
    params: dict[str, Any] = {
        "embed": init_dense(ks[0], (cfg.vocab, cfg.d_model), dt),
        "final_norm": jnp.ones(cfg.d_model, dt),
        "w_out": init_dense(ks[1], (cfg.d_model, cfg.vocab), dt),
    }
    if n_dense:
        lk = jax.random.split(ks[2], n_dense)
        params["dense_stack"] = jax.vmap(
            lambda k: _init_layer(k, cfg, use_moe=False))(lk)
    if n_moe:
        lk = jax.random.split(ks[3], n_moe)
        params["moe_stack"] = jax.vmap(
            lambda k: _init_layer(k, cfg, use_moe=True))(lk)
    if cfg.mtp:
        params["mtp_layer"] = _init_layer(ks[4], cfg, use_moe=False)
        params["mtp_proj"] = init_dense(ks[5], (2 * cfg.d_model, cfg.d_model),
                                        dt)
        params["mtp_norm"] = jnp.ones(cfg.d_model, dt)
    return params


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
def _kv_heads_shardable(cfg: LMConfig) -> bool:
    from repro.dist.sharding import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return True
    return cfg.n_kv_heads % mesh.shape["model"] == 0


def _gqa_attention(h: jnp.ndarray, ap: dict[str, Any], cfg: LMConfig,
                   positions: jnp.ndarray) -> jnp.ndarray:
    b, s, _ = h.shape
    q = (h @ ap["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (h @ ap["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ ap["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    # K/V head-axis sharding only when the KV heads divide the axis —
    # otherwise GSPMD falls into involuntary full-remat f32 copies every
    # layer (EXPERIMENTS §Perf hillclimb 2: -11% HBM bytes, -34% ICI).
    kv_ok = _kv_heads_shardable(cfg)
    k = constrain(k, "batch", "seq", "heads" if kv_ok else None, None)
    v = constrain(v, "batch", "seq", "heads" if kv_ok else None, None)
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    if s >= cfg.blockwise_from:
        out = blockwise_attention(q, k, v, scale, 0, cfg.sliding_window,
                                  cfg.block_kv, unroll=cfg.unroll)
    else:
        bias = causal_mask_bias(s, s, 0, cfg.sliding_window)
        out = dense_attention(q, k, v, bias, scale)
    out = constrain(out, "batch", "seq", "heads", None)
    return out.reshape(b, s, -1) @ ap["wo"]


def _mla_attention(h: jnp.ndarray, ap: dict[str, Any], cfg: LMConfig,
                   positions: jnp.ndarray) -> jnp.ndarray:
    """Training/prefill MLA (non-absorbed)."""
    m = cfg.mla
    b, s, _ = h.shape
    hh = cfg.n_heads
    cq = rms_norm(h @ ap["w_dq"], ap["q_norm"], cfg.norm_eps)
    q = (cq @ ap["w_uq"]).reshape(b, s, hh, cfg.qk_dim)
    q_nope, q_pe = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    ckv = rms_norm(h @ ap["w_dkv"], ap["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope((h @ ap["w_kpe"])[:, :, None, :], positions,
                      cfg.rope_theta)                      # [B,S,1,rope]
    k_nope = (ckv @ ap["w_uk"]).reshape(b, s, hh, m.nope_head_dim)
    v = (ckv @ ap["w_uv"]).reshape(b, s, hh, m.v_head_dim)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_pe, (b, s, hh, m.rope_head_dim))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)
    scale = 1.0 / jnp.sqrt(cfg.qk_dim).astype(jnp.float32)
    if s >= cfg.blockwise_from:
        out = blockwise_attention(q, k, v, scale, 0, None, cfg.block_kv,
                                  unroll=cfg.unroll)
    else:
        bias = causal_mask_bias(s, s)
        out = dense_attention(q, k, v, bias, scale)
    out = constrain(out, "batch", "seq", "heads", None)
    return out.reshape(b, s, -1) @ ap["wo"]


# --------------------------------------------------------------------------- #
# layer + full forward
# --------------------------------------------------------------------------- #
def _layer_fwd(x: jnp.ndarray, lp: dict[str, Any], cfg: LMConfig,
               positions: jnp.ndarray, use_moe: bool
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn = _mla_attention(h, lp["attn"], cfg, positions) if cfg.mla \
        else _gqa_attention(h, lp["attn"], cfg, positions)
    x = constrain(x + attn, "batch", "seq", "embed")
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if use_moe:
        f, aux = moe_lib.moe_ffn(h, lp["moe"], cfg.moe)
    else:
        f = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                   lp["ffn"]["w_down"])
        aux = jnp.zeros((), jnp.float32)
    return constrain(x + f, "batch", "seq", "embed"), aux


def _run_stack(x: jnp.ndarray, stack: dict[str, Any], cfg: LMConfig,
               positions: jnp.ndarray, use_moe: bool
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    body = functools.partial(_layer_fwd, cfg=cfg, positions=positions,
                             use_moe=use_moe)
    if cfg.remat:
        body = jax.checkpoint(body)

    if cfg.unroll:
        aux = jnp.zeros((), jnp.float32)
        n_layers = jax.tree.leaves(stack)[0].shape[0]
        for i in range(n_layers):
            lp = jax.tree.map(lambda p: p[i], stack)
            x, a = body(x, lp)
            aux = aux + a
        return x, aux

    def scan_fn(carry, lp):
        x, aux = carry
        x, a = body(x, lp)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                               stack)
    return x, aux


def _backbone(params: dict[str, Any], cfg: LMConfig, tokens: jnp.ndarray
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"][tokens].astype(cfg.dtype)
    x = constrain(x, "batch", "seq", "embed")
    aux = jnp.zeros((), jnp.float32)
    if "dense_stack" in params:
        x, a = _run_stack(x, params["dense_stack"], cfg, positions, False)
        aux += a
    if "moe_stack" in params:
        x, a = _run_stack(x, params["moe_stack"], cfg, positions, True)
        aux += a
    return x, aux


def prefill(params: dict[str, Any], cfg: LMConfig,
            tokens: jnp.ndarray) -> jnp.ndarray:
    """[B, S] -> logits [B, S, V] (also the training forward)."""
    x, _ = _backbone(params, cfg, tokens)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x @ params["w_out"], "batch", "seq", "vocab")


def lm_loss(params: dict[str, Any], cfg: LMConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray) -> jnp.ndarray:
    x, aux = _backbone(params, cfg, tokens)
    xn = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain(xn @ params["w_out"], "batch", "seq", "vocab")
    loss = cross_entropy_loss(logits, labels) + aux
    if cfg.mtp:
        # MTP: predict t+2 from (h_t, embed(token_{t+1})) through one layer
        b, s = tokens.shape
        emb_next = params["embed"][labels].astype(cfg.dtype)
        merged = jnp.concatenate(
            [rms_norm(x, params["mtp_norm"], cfg.norm_eps), emb_next],
            axis=-1) @ params["mtp_proj"]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        h_mtp, _ = _layer_fwd(merged, params["mtp_layer"], cfg, positions,
                              use_moe=False)
        logits2 = rms_norm(h_mtp, params["final_norm"],
                           cfg.norm_eps) @ params["w_out"]
        # labels shifted one beyond: token_{t+2} = labels shifted left by 1
        l2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
        mask = jnp.ones_like(l2, jnp.float32).at[:, -1].set(0.0)
        loss = loss + cfg.mtp_weight * cross_entropy_loss(logits2, l2, mask)
    return loss


# --------------------------------------------------------------------------- #
# decode (serving)
# --------------------------------------------------------------------------- #
def init_cache(cfg: LMConfig, batch: int, t_max: int) -> dict[str, Any]:
    """KV cache pytree.  GQA: (k, v); MLA: compressed (ckv, kpe).

    `t_max` should be min(seq_len, sliding_window) for SWA models — the
    cache is a ring buffer indexed by pos % t_max with per-slot positions.
    """
    l = cfg.n_layers
    dt = cfg.dtype
    if cfg.mla is None:
        shape = (l, batch, t_max, cfg.n_kv_heads, cfg.head_dim)
        cache = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    else:
        m = cfg.mla
        cache = {
            "ckv": jnp.zeros((l, batch, t_max, m.kv_lora_rank), dt),
            "kpe": jnp.zeros((l, batch, t_max, m.rope_head_dim), dt),
        }
    cache["slot_pos"] = jnp.full((t_max,), -1, jnp.int32)
    return cache


def _decode_layer_gqa(x, lp, kc, vc, slot_pos, pos, slot, cfg):
    b = x.shape[0]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    ppos = jnp.full((b, 1), pos, jnp.int32)
    q = (h @ lp["attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = (h @ lp["attn"]["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ lp["attn"]["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, ppos, cfg.rope_theta)
    k = apply_rope(k, ppos, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
    kc = constrain(kc, "batch", "kv_len", None, None)
    vc = constrain(vc, "batch", "kv_len", None, None)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if cfg.sliding_window is not None:
        valid &= (pos - slot_pos) < cfg.sliding_window
    bias = jnp.where(valid, 0.0, -jnp.inf).astype(jnp.float32)[None, :]
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    out = dense_attention(q, kc, vc, bias, scale)
    x = x + out.reshape(b, 1, -1) @ lp["attn"]["wo"]
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        f, _ = moe_lib.moe_ffn(h, lp["moe"], cfg.moe)
    else:
        f = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                   lp["ffn"]["w_down"])
    return x + f, kc, vc


def _decode_layer_mla(x, lp, ckv_c, kpe_c, slot_pos, pos, slot, cfg):
    """Absorbed MLA decode: scores via compressed cache, no K/V expansion."""
    m = cfg.mla
    b = x.shape[0]
    hh = cfg.n_heads
    ap = lp["attn"]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    ppos = jnp.full((b, 1), pos, jnp.int32)
    cq = rms_norm(h @ ap["w_dq"], ap["q_norm"], cfg.norm_eps)
    q = (cq @ ap["w_uq"]).reshape(b, 1, hh, cfg.qk_dim)
    q_nope, q_pe = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_pe = apply_rope(q_pe, ppos, cfg.rope_theta)
    ckv = rms_norm(h @ ap["w_dkv"], ap["kv_norm"], cfg.norm_eps)[:, :, :]
    kpe = apply_rope((h @ ap["w_kpe"])[:, :, None, :], ppos,
                     cfg.rope_theta)[:, :, 0, :]
    ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv, (0, slot, 0))
    kpe_c = jax.lax.dynamic_update_slice(kpe_c, kpe, (0, slot, 0))
    ckv_c = constrain(ckv_c, "batch", "kv_len", None)
    kpe_c = constrain(kpe_c, "batch", "kv_len", None)
    # absorb W_UK into q:  q_c[b,h,c] = sum_d q_nope[b,h,d] * w_uk[c,h,d]
    w_uk = ap["w_uk"].reshape(m.kv_lora_rank, hh, m.nope_head_dim)
    q_c = jnp.einsum("bhd,chd->bhc", q_nope[:, 0], w_uk)
    scores = (jnp.einsum("bhc,btc->bht", q_c, ckv_c)
              + jnp.einsum("bhr,btr->bht", q_pe[:, 0], kpe_c)
              ).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(cfg.qk_dim).astype(jnp.float32)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    scores = scores * scale + jnp.where(valid, 0.0, -jnp.inf)[None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bht,btc->bhc", probs, ckv_c)
    w_uv = ap["w_uv"].reshape(m.kv_lora_rank, hh, m.v_head_dim)
    v_ctx = jnp.einsum("bhc,chd->bhd", ctx, w_uv)
    x = x + (v_ctx.reshape(b, 1 * hh * m.v_head_dim)[:, None, :]
             @ ap["wo"])
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        f, _ = moe_lib.moe_ffn(h, lp["moe"], cfg.moe)
    else:
        f = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                   lp["ffn"]["w_down"])
    return x + f, ckv_c, kpe_c


def decode_step(params: dict[str, Any], cfg: LMConfig, cache: dict[str, Any],
                tokens: jnp.ndarray, pos: jnp.ndarray
                ) -> tuple[jnp.ndarray, dict[str, Any]]:
    """One serve step: tokens [B, 1] at absolute position `pos` (scalar).

    Returns (logits [B, 1, V], updated cache).  Ring-buffer slot = pos % t_max
    handles both full caches (t_max = seq_len) and SWA-bounded caches.
    """
    x = params["embed"][tokens].astype(cfg.dtype)
    first_dense = cfg.moe.first_dense if cfg.moe is not None else cfg.n_layers
    n_dense = min(first_dense, cfg.n_layers)
    if cfg.mla is None:
        t_max = cache["k"].shape[2]
    else:
        t_max = cache["ckv"].shape[2]
    slot = (pos % t_max).astype(jnp.int32)
    # mark the current slot valid BEFORE the layers run (it holds this step's
    # key); layers read slot_pos for masking.
    slot_pos = cache["slot_pos"].at[slot].set(pos.astype(jnp.int32))

    def run(x, stack, cache_sl, use_moe, kind):
        fn = _decode_layer_mla if kind == "mla" else _decode_layer_gqa

        def scan_fn(x, xs):
            lp, c1, c2 = xs
            x, n1, n2 = fn(x, lp, c1, c2, slot_pos, pos, slot, cfg)
            return x, (n1, n2)

        if cfg.unroll:
            n_layers = jax.tree.leaves(stack)[0].shape[0]
            outs1, outs2 = [], []
            for i in range(n_layers):
                lp = jax.tree.map(lambda p: p[i], stack)
                x, n1, n2 = fn(x, lp, cache_sl[0][i], cache_sl[1][i],
                               slot_pos, pos, slot, cfg)
                outs1.append(n1), outs2.append(n2)
            return x, (jnp.stack(outs1), jnp.stack(outs2))
        return jax.lax.scan(scan_fn, x, (stack, *cache_sl))

    kind = "mla" if cfg.mla is not None else "gqa"
    c_names = ("ckv", "kpe") if kind == "mla" else ("k", "v")
    new1, new2 = [], []
    off = 0
    if "dense_stack" in params:
        nl = n_dense
        sl = tuple(cache[n][off:off + nl] for n in c_names)
        x, (u1, u2) = run(x, params["dense_stack"], sl, False, kind)
        new1.append(u1), new2.append(u2)
        off += nl
    if "moe_stack" in params:
        nl = cfg.n_layers - n_dense
        sl = tuple(cache[n][off:off + nl] for n in c_names)
        x, (u1, u2) = run(x, params["moe_stack"], sl, True, kind)
        new1.append(u1), new2.append(u2)
    new_cache = {
        c_names[0]: jnp.concatenate(new1, axis=0),
        c_names[1]: jnp.concatenate(new2, axis=0),
        "slot_pos": slot_pos,
    }
    xn = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain(xn @ params["w_out"], "batch", "seq", "vocab")
    return logits, new_cache
