"""BERT4Rec [arXiv:1904.06690]: bidirectional transformer over item sequences.

Config (assigned): embed_dim=64, n_blocks=2, n_heads=2, seq_len=200.
The item table (n_items up to 10^6 — the retrieval_cand shape scores 1M
candidates) is the huge sparse-embedding hot path; it is row-sharded over
'rows' ('model' axis).  Masked-item (cloze) training per the paper.

Shapes:
  train_batch     masked-LM training step, batch 65,536;
  serve_p99       online scoring, batch 512 (predict last position);
  serve_bulk      offline scoring, batch 262,144;
  retrieval_cand  one user state x 1,000,000 candidates, batched dot.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.dist.sharding import shard_map as _shard_map
from repro.models.common import cross_entropy_loss, init_dense
from repro.models.embedding_bag import init_table

__all__ = ["Bert4RecConfig", "init_bert4rec", "encode", "cloze_loss",
           "serve_scores", "retrieval_scores"]


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff: int = 256
    mask_id: int = 0          # item 0 reserved as [MASK]
    dtype: Any = jnp.bfloat16


def init_bert4rec(cfg: Bert4RecConfig, key: jax.Array) -> dict[str, Any]:
    ks = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    d = cfg.embed_dim
    p: dict[str, Any] = {
        "items": init_table(ks[0], cfg.n_items, d, cfg.dtype),
        "pos": init_dense(ks[1], (cfg.seq_len, d), cfg.dtype),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        b = 2 + 6 * i
        p["blocks"].append({
            "wq": init_dense(ks[b], (d, d), cfg.dtype),
            "wk": init_dense(ks[b + 1], (d, d), cfg.dtype),
            "wv": init_dense(ks[b + 2], (d, d), cfg.dtype),
            "wo": init_dense(ks[b + 3], (d, d), cfg.dtype),
            "w1": init_dense(ks[b + 4], (d, cfg.d_ff), cfg.dtype),
            "w2": init_dense(ks[b + 5], (cfg.d_ff, d), cfg.dtype),
            "ln1": jnp.ones(d, cfg.dtype),
            "ln2": jnp.ones(d, cfg.dtype),
        })
    return p


def _ln(x, g):
    m = x.mean(-1, keepdims=True)
    v = ((x - m) ** 2).mean(-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-6) * g


def encode(params: dict[str, Any], cfg: Bert4RecConfig,
           items: jnp.ndarray) -> jnp.ndarray:
    """items [B, S] -> hidden [B, S, d] (bidirectional attention)."""
    b, s = items.shape
    table = constrain(params["items"], "rows", None)
    x = table[items].astype(cfg.dtype) + params["pos"][None, :s]
    x = constrain(x, "batch", "seq", "embed")
    h_dim = cfg.embed_dim // cfg.n_heads
    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"])
        q = (h @ blk["wq"]).reshape(b, s, cfg.n_heads, h_dim)
        k = (h @ blk["wk"]).reshape(b, s, cfg.n_heads, h_dim)
        v = (h @ blk["wv"]).reshape(b, s, cfg.n_heads, h_dim)
        scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
        probs = jax.nn.softmax(scores / jnp.sqrt(h_dim), axis=-1)
        o = jnp.einsum("bhst,bthd->bshd", probs.astype(cfg.dtype), v)
        x = x + o.reshape(b, s, -1) @ blk["wo"]
        h = _ln(x, blk["ln2"])
        x = x + jax.nn.gelu(h @ blk["w1"]) @ blk["w2"]
        x = constrain(x, "batch", "seq", "embed")
    return x


def cloze_loss(params: dict[str, Any], cfg: Bert4RecConfig,
               items: jnp.ndarray, labels: jnp.ndarray,
               mask: jnp.ndarray) -> jnp.ndarray:
    """Masked-item prediction: items have [MASK]=0 at masked positions."""
    h = encode(params, cfg, items)
    logits = constrain(
        h @ params["items"].T.astype(cfg.dtype), "batch", "seq", "rows")
    return cross_entropy_loss(logits, labels, mask.astype(jnp.float32))


def sampled_cloze_loss(params: dict[str, Any], cfg: Bert4RecConfig,
                       items: jnp.ndarray, mask_pos: jnp.ndarray,
                       labels: jnp.ndarray,
                       negatives: jnp.ndarray) -> jnp.ndarray:
    """Sampled-softmax cloze loss for 10^6-item vocabularies.

    Full [B, S, n_items] logits at 65k batch would be petabytes; instead we
    score only the masked positions against (positive + shared negatives)
    — the industry-standard sampled softmax (see DESIGN.md §4).

    items [B, S] (with [MASK] at mask_pos), mask_pos [B, M], labels [B, M],
    negatives [N_neg] shared across the batch.
    """
    h = encode(params, cfg, items)                     # [B, S, d]
    hm = jnp.take_along_axis(h, mask_pos[:, :, None], axis=1)  # [B, M, d]
    table = params["items"]
    pos_e = table[labels].astype(cfg.dtype)            # [B, M, d]
    neg_e = table[negatives].astype(cfg.dtype)         # [N, d]
    logit_pos = jnp.sum(hm * pos_e, axis=-1,
                        keepdims=True).astype(jnp.float32)    # [B, M, 1]
    logit_neg = jnp.einsum("bmd,nd->bmn", hm, neg_e).astype(jnp.float32)
    logits = jnp.concatenate([logit_pos, logit_neg], axis=-1)
    nll = jax.nn.logsumexp(logits, axis=-1) - logits[..., 0]
    return nll.mean()


def bulk_topk_scores(params: dict[str, Any], cfg: Bert4RecConfig,
                     items: jnp.ndarray, k: int = 100,
                     chunk: int = 65_536) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Offline scoring: top-k items per user without materializing [B, V].

    Distributed top-k: each 'model' shard scores its v/16 table rows and
    reduces to a LOCAL top-k; one all-gather of [B, k] finalists replaces
    per-chunk all-gathers of full score blocks (~300x less ICI traffic —
    see EXPERIMENTS §Perf).  Single-device fallback scans chunks.
    [B, S] -> (scores [B, k], ids [B, k]).
    """
    from repro.dist.sharding import current_mesh
    h = encode(params, cfg, items)[:, -1]              # [B, d]
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and cfg.n_items % mesh.shape["model"] == 0:
        return _bulk_topk_shardmap(params, cfg, h, k, chunk, mesh)
    v = cfg.n_items
    n_chunks = (v + chunk - 1) // chunk
    v_pad = n_chunks * chunk
    table = params["items"]
    pad = jnp.zeros((v_pad - v, table.shape[1]), table.dtype)
    tbl = jnp.concatenate([table, pad]).reshape(n_chunks, chunk, -1)

    def step(carry, xs):
        best_v, best_i = carry
        tchunk, cidx = xs
        scores = (h @ tchunk.T.astype(cfg.dtype)).astype(jnp.float32)
        base = cidx * chunk
        ids = base + jnp.arange(chunk, dtype=jnp.int32)
        scores = jnp.where(ids[None, :] < v, scores, -jnp.inf)
        allv = jnp.concatenate([best_v, scores], axis=1)
        alli = jnp.concatenate([best_i,
                                jnp.broadcast_to(ids, scores.shape)], axis=1)
        nv, sel = jax.lax.top_k(allv, k)
        ni = jnp.take_along_axis(alli, sel, axis=1)
        return (nv, ni), None

    b = items.shape[0]
    init = (jnp.full((b, k), -jnp.inf, jnp.float32),
            jnp.zeros((b, k), jnp.int32))
    # unroll=True: 16 static chunks, no loop overhead on TPU — and XLA's
    # cost_analysis then counts every chunk (scan bodies are counted once).
    (bv, bi), _ = jax.lax.scan(step, init,
                               (tbl, jnp.arange(n_chunks, dtype=jnp.int32)),
                               unroll=True)
    return bv, bi


def _bulk_topk_shardmap(params: dict[str, Any], cfg: Bert4RecConfig,
                        h: jnp.ndarray, k: int, chunk: int, mesh
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    from jax.sharding import PartitionSpec as P

    n_model = mesh.shape["model"]
    v_loc = cfg.n_items // n_model
    dp = tuple(a for a in mesh.axis_names if a != "model")

    def local_fn(h_loc, tbl_loc):
        # h_loc [b_loc, d] (replicated along model); tbl_loc [v_loc, d]
        base = jax.lax.axis_index("model") * v_loc
        n_chunks = max(v_loc // chunk, 1)
        csz = v_loc // n_chunks
        tbl = tbl_loc.reshape(n_chunks, csz, -1)

        def step(carry, xs):
            bv, bi = carry
            tc, ci = xs
            scores = (h_loc @ tc.T.astype(cfg.dtype)).astype(jnp.float32)
            ids = base + ci * csz + jnp.arange(csz, dtype=jnp.int32)
            allv = jnp.concatenate([bv, scores], axis=1)
            alli = jnp.concatenate(
                [bi, jnp.broadcast_to(ids, scores.shape)], axis=1)
            nv, sel = jax.lax.top_k(allv, k)
            return (nv, jnp.take_along_axis(alli, sel, axis=1)), None

        b_loc = h_loc.shape[0]
        init = (jnp.full((b_loc, k), -jnp.inf, jnp.float32),
                jnp.zeros((b_loc, k), jnp.int32))
        (bv, bi), _ = jax.lax.scan(
            step, init, (tbl, jnp.arange(n_chunks, dtype=jnp.int32)),
            unroll=True)
        # merge the n_model local top-k lists: tiny all-gather of finalists
        allv = jax.lax.all_gather(bv, "model", axis=1, tiled=True)
        alli = jax.lax.all_gather(bi, "model", axis=1, tiled=True)
        nv, sel = jax.lax.top_k(allv, k)
        return nv, jnp.take_along_axis(alli, sel, axis=1)

    return _shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None), P("model", None)),
        out_specs=(P(dp, None), P(dp, None)),
        check_vma=False,
    )(h, params["items"])


def serve_scores(params: dict[str, Any], cfg: Bert4RecConfig,
                 items: jnp.ndarray) -> jnp.ndarray:
    """Next-item scores at the last position: [B, S] -> [B, n_items]."""
    h = encode(params, cfg, items)[:, -1]
    return constrain(h @ params["items"].T.astype(cfg.dtype),
                     "batch", "rows")


def retrieval_scores(params: dict[str, Any], cfg: Bert4RecConfig,
                     items: jnp.ndarray,
                     candidates: jnp.ndarray) -> jnp.ndarray:
    """Score one (or few) user(s) against an explicit candidate set.

    items [B, S], candidates [C] -> [B, C].  Batched dot, not a loop.
    """
    h = encode(params, cfg, items)[:, -1]                  # [B, d]
    cand = constrain(params["items"][candidates], "cands", None)
    return constrain(h @ cand.T.astype(cfg.dtype), "batch", "cands")
