"""EmbeddingBag for JAX: ragged multi-hot gather + segment-reduce.

JAX has no native nn.EmbeddingBag and no CSR sparse — this module IS the
system's sparse embedding layer (kernel_taxonomy §RecSys).  Bags are given
as (indices [NNZ], offsets [B+1]) pairs (torch layout) or as padded
[B, max_per_bag] index matrices with a padding id.

Tables are row-sharded over the 'rows' logical axis ('model' mesh axis);
the gather keeps indices replicated and rows local, the combine is a
segment-sum — GSPMD emits one all-reduce over 'model'.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

__all__ = ["embedding_bag_padded", "embedding_bag_ragged", "init_table"]


def init_table(key: jax.Array, n_rows: int, dim: int,
               dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(dim)
    t = jax.random.normal(key, (n_rows, dim), jnp.float32) * scale
    return t.astype(dtype)


def embedding_bag_padded(table: jnp.ndarray, idx: jnp.ndarray,
                         pad_id: int, mode: str = "sum") -> jnp.ndarray:
    """idx: [B, K] with pad_id marking empty slots -> [B, dim]."""
    table = constrain(table, "rows", None)
    valid = (idx != pad_id)
    safe = jnp.where(valid, idx, 0)
    emb = table[safe]                                  # [B, K, dim]
    emb = emb * valid[..., None].astype(emb.dtype)
    if mode == "sum":
        return emb.sum(axis=1)
    if mode == "mean":
        return emb.sum(axis=1) / jnp.maximum(
            valid.sum(axis=1, keepdims=True).astype(emb.dtype), 1.0)
    if mode == "max":
        neg = jnp.where(valid[..., None], emb, -jnp.inf)
        out = neg.max(axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown mode {mode}")


def embedding_bag_ragged(table: jnp.ndarray, indices: jnp.ndarray,
                         offsets: jnp.ndarray, n_bags: int,
                         mode: str = "sum") -> jnp.ndarray:
    """torch-layout bags: indices [NNZ], offsets [B+1] -> [B, dim].

    Implemented as gather + jax.ops.segment_sum over bag ids.
    """
    table = constrain(table, "rows", None)
    nnz = indices.shape[0]
    bag_of = jnp.searchsorted(offsets, jnp.arange(nnz), side="right") - 1
    emb = table[indices]                               # [NNZ, dim]
    s = jax.ops.segment_sum(emb, bag_of, num_segments=n_bags)
    if mode == "sum":
        return s
    counts = jax.ops.segment_sum(jnp.ones(nnz, emb.dtype), bag_of,
                                 num_segments=n_bags)
    if mode == "mean":
        return s / jnp.maximum(counts[:, None], 1.0)
    raise ValueError(f"unknown mode {mode}")
