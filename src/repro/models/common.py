"""Shared model blocks: RMSNorm, RoPE, SwiGLU, blockwise attention.

Everything is pure-functional (params as pytrees) and dtype-polymorphic:
compute in `cfg.dtype` (bf16 on TPU), accumulate softmax/norms in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

__all__ = ["rms_norm", "rope_freqs", "apply_rope", "swiglu",
           "dense_attention", "blockwise_attention", "causal_mask_bias",
           "init_dense", "cross_entropy_loss"]


def init_dense(key: jax.Array, shape: tuple[int, ...],
               dtype=jnp.float32, scale: float | None = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * s).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def rope_freqs(head_dim: int, max_pos: int, theta: float = 10000.0
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(cos, sin) tables [max_pos, head_dim//2], fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                      dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(t, inv)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding computed on the fly (no table — works at 500k pos).

    x: [B, S, H, D]; positions: [B, S] int32.
    """
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[:, :, None] * inv[None, None, :]
    c = jnp.cos(ang)[:, :, None, :]              # [B, S, 1, D/2]
    s = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rot = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return rot.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = constrain(x @ w_gate, "batch", "seq", "d_ff")
    u = constrain(x @ w_up, "batch", "seq", "d_ff")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return constrain(h @ w_down, "batch", "seq", "embed")


def causal_mask_bias(s_q: int, s_kv: int, q_offset: jnp.ndarray | int = 0,
                     window: int | None = None) -> jnp.ndarray:
    """[s_q, s_kv] additive bias: 0 where attendable, -inf elsewhere."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_kv)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= (qi - kj) < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def dense_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    bias: jnp.ndarray | None, scale: float) -> jnp.ndarray:
    """Grouped-query attention.  q: [B,S,H,D], k/v: [B,T,Kv,D] -> [B,S,H,D].

    H must be a multiple of Kv; head groups share one KV head.
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    dv = v.shape[3]                               # may differ from d (MLA)
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores *= scale
    if bias is not None:
        scores = scores + bias                    # [s, t] broadcast
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dv)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        scale: float, q_offset: int = 0,
                        window: int | None = None,
                        block_kv: int = 1024,
                        unroll: bool = False) -> jnp.ndarray:
    """Online-softmax attention, scanned over KV blocks (flash-style).

    Bounds the score working set to [B,Kv,G,S,block_kv] — the jnp reference
    of the Pallas flash kernel, and the long-sequence XLA path.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    dv = v.shape[3]                               # may differ from d (MLA)
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    n_blocks = (t + block_kv - 1) // block_kv
    t_pad = n_blocks * block_kv
    k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blocks, block_kv, kv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, block_kv, kv, dv).transpose(1, 0, 2, 3, 4)

    qi = jnp.arange(s) + q_offset                 # absolute query positions

    def step(carry, xs):
        m, l, acc, blk = carry[0], carry[1], carry[2], carry[3]
        kblk, vblk = xs
        kj = blk * block_kv + jnp.arange(block_kv)
        ok = (kj[None, :] <= qi[:, None]) & (kj[None, :] < t)
        if window is not None:
            ok &= (qi[:, None] - kj[None, :]) < window
        bias = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kblk
                            ).astype(jnp.float32) * scale + bias
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), vblk
                        ).astype(jnp.float32)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new, blk + 1), None

    # -1e30 (not -inf): a fully-masked block then yields p=exp(-inf+1e30)=0
    # instead of exp(-inf - -inf)=nan.
    m0 = jnp.full((b, kv, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s), jnp.float32)
    acc0 = jnp.zeros((b, s, kv, g, dv), jnp.float32)   # f32 accumulator
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, 0), (kb, vb),
                                     unroll=unroll)
    out = (acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
           ).astype(q.dtype)
    return out.reshape(b, s, h, dv)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token CE in fp32.  logits [B,S,V], labels [B,S] int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
