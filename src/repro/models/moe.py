"""Mixture-of-Experts FFN: sort-based capacity dispatch, EP-shardable.

Design (DESIGN.md §6, hardware adaptation): tokens are routed with a
sort + rank + capacity-bounded gather into per-expert buffers, computed
**per batch row** (the batch dim doubles as the dispatch group), so the
whole layer is expressed with batched gathers/scatter-adds and three
grouped einsums:

    buffer[g, e, c, :] = tokens[g, token_for[g, e, c], :]      (gather)
    h = einsum('gecd,edf->gecf', buffer, w_gate/w_up)          (expert GEMM)
    out[g, t, :] += w_slot * y[g, e, c, :]                     (scatter-add)

Why not the GShard one-hot dispatch einsum: its [T, E, C] x d contraction
inflates HLO FLOPs by ~E/k x over the useful expert GEMMs, wrecking the
MODEL_FLOPS/HLO_FLOPS ratio; gathers move the same bytes with zero FLOPs.

Sharding: experts over 'model' (EP), batch groups over ('pod','data') (DP),
expert weights additionally FSDP-sharded over 'data'.  The gather/scatter
indices are tiny int arrays; GSPMD keeps them replicated and the heavy
tensors fully local, with one all-reduce over 'model' at the combine.

Routers: 'softmax' (Qwen-style top-k) and 'sigmoid' (DeepSeek-V3 style,
aux-loss-free bias correction applied to selection only).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.dist.sharding import shard_map as _shard_map
from repro.models.common import init_dense

__all__ = ["MoEConfig", "init_moe_params", "moe_ffn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router: str = "softmax"           # softmax | sigmoid
    router_scale: float = 1.0         # routed_scaling_factor (deepseek 2.5)
    aux_coeff: float = 0.001
    first_dense: int = 0              # leading dense-FFN layers
    # expert weights additionally FSDP-sharded over 'data' (deepseek scale);
    # the shard_map EP path then all-gathers them explicitly per layer.
    fsdp_experts: bool = False


def init_moe_params(key: jax.Array, d_model: int, cfg: MoEConfig,
                    dtype=jnp.bfloat16) -> dict[str, Any]:
    ks = jax.random.split(key, 6)
    e, f = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": init_dense(ks[0], (d_model, e), jnp.float32),
        "w_gate": init_dense(ks[1], (e, d_model, f), dtype),
        "w_up": init_dense(ks[2], (e, d_model, f), dtype),
        "w_down": init_dense(ks[3], (e, f, d_model), dtype),
    }
    if cfg.router == "sigmoid":
        p["router_bias"] = jnp.zeros((e,), jnp.float32)
    if cfg.n_shared:
        fs = cfg.n_shared * f
        p["shared_gate"] = init_dense(ks[4], (d_model, fs), dtype)
        p["shared_up"] = init_dense(ks[5], (d_model, fs), dtype)
        p["shared_down"] = init_dense(ks[4], (fs, d_model), dtype)
    return p


def _route(x32: jnp.ndarray, params: dict[str, Any], cfg: MoEConfig
           ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    return _route_arrays(x32, params["router"],
                         params.get("router_bias"), cfg)


def _route_arrays(x32: jnp.ndarray, router: jnp.ndarray,
                  router_bias: jnp.ndarray | None, cfg: MoEConfig
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x32: [T, d] fp32 -> (weights [T,k], experts [T,k], aux_loss scalar)."""
    logits = x32 @ router                                 # [T, E]
    if cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + router_bias[None, :]               # bias: selection only
        _, idx = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, idx, axis=1)
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        w = w * cfg.router_scale
        aux = jnp.zeros((), jnp.float32)                  # aux-loss-free
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        # Switch-style load-balance aux loss
        e = cfg.n_experts
        frac_tokens = jnp.zeros(e).at[idx.reshape(-1)].add(1.0) / \
            (idx.size + 1e-9)
        frac_probs = probs.mean(axis=0)
        aux = cfg.aux_coeff * e * jnp.sum(frac_tokens * frac_probs)
    return w, idx, aux


def _dispatch_indices(experts: jnp.ndarray, weights: jnp.ndarray,
                      n_experts: int, capacity: int
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[T, k] routing -> (token_for [E*C] (sentinel=T), weight_for [E*C]).

    Slot (e, c) holds the c-th token-slot routed to expert e, in token order
    (deterministic tie-break); overflow beyond `capacity` is dropped.
    """
    t, k = experts.shape
    flat_e = experts.reshape(-1)                          # [T*k]
    token_id = jnp.repeat(jnp.arange(t), k)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], token_id[order], flat_w[order]
    counts = jnp.zeros(n_experts, jnp.int32).at[se].add(1)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k) - offsets[se]
    keep = rank < capacity
    pos = jnp.where(keep, se * capacity + rank, n_experts * capacity)
    token_for = jnp.full(n_experts * capacity + 1, t, jnp.int32) \
        .at[pos].set(st.astype(jnp.int32))[:-1]
    weight_for = jnp.zeros(n_experts * capacity + 1, jnp.float32) \
        .at[pos].set(sw)[:-1]
    return token_for, weight_for


def moe_ffn(x: jnp.ndarray, params: dict[str, Any], cfg: MoEConfig
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar).

    Dispatches to the shard_map EP path when a mesh with a 'model' axis is
    ambient (production), else the single-device/GSPMD path below.  The
    shard_map path exists because GSPMD cannot partition the batched
    combine scatter: it falls back to replicating the full global
    activation (30 GB+ all-gathers per layer at deepseek scale) — see
    EXPERIMENTS.md §Perf hillclimb 3.
    """
    from repro.dist.sharding import current_mesh
    mesh = current_mesh()
    # single-token decode stays on the GSPMD path: per-step FSDP weight
    # all-gathers (1.4 GB/layer) would dwarf the one-token compute.
    if mesh is not None and "model" in mesh.axis_names \
            and cfg.n_experts % mesh.shape["model"] == 0 \
            and x.shape[1] > 1:
        return _moe_ffn_shardmap(x, params, cfg, mesh)
    return _moe_ffn_local(x, params, cfg)


def _moe_ffn_local(x: jnp.ndarray, params: dict[str, Any], cfg: MoEConfig
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-device / pure-GSPMD reference path."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(s * k / e * cfg.capacity_factor), 1)
    x32 = x.astype(jnp.float32)

    def route_row(xr32):
        w, idx, aux = _route(xr32, params, cfg)
        token_for, weight_for = _dispatch_indices(idx, w, e, cap)
        return token_for, weight_for, aux

    token_for, weight_for, aux = jax.vmap(route_row)(x32)   # [B, E*C], ...
    aux = aux.mean()
    token_for = constrain(token_for.reshape(b, e, cap), "batch", "experts",
                          None).reshape(b, e * cap)

    # dispatch gather (zero-FLOP): pad a sentinel row per batch group
    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    buf = jnp.take_along_axis(
        x_pad, token_for[:, :, None].astype(jnp.int32), axis=1)
    buf = constrain(buf.reshape(b, e, cap, d), "batch", "experts", None, None)

    # expert GEMMs (the useful FLOPs)
    h_g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    h_u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    h = constrain(h, "batch", "experts", None, None)
    y = jnp.einsum("becf,efd->becd", h, params["w_down"])
    y = y * weight_for.reshape(b, e, cap, 1).astype(y.dtype)

    # combine scatter-add back to token order (psum over 'model' by GSPMD)
    out = jnp.zeros((b, s + 1, d), x.dtype)
    out = out.at[jnp.arange(b)[:, None], token_for, :].add(
        y.reshape(b, e * cap, d))[:, :s, :]
    out = constrain(out, "batch", "seq", "embed")

    if cfg.n_shared:
        out = out + _shared_experts(x, params)
    return out, aux


def _shared_experts(x: jnp.ndarray, params: dict[str, Any]) -> jnp.ndarray:
    g = x @ params["shared_gate"]
    u = x @ params["shared_up"]
    hs = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return constrain(hs @ params["shared_down"], "batch", "seq", "embed")


def _moe_ffn_shardmap(x: jnp.ndarray, params: dict[str, Any], cfg: MoEConfig,
                      mesh) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel path under shard_map (production meshes).

    Along 'model' the activations are replicated, so every device already
    holds all tokens of its batch shard: each device routes locally (the
    routing computation is identical on all model-peers), gathers the
    capacity buffers of ITS local experts, runs the grouped GEMMs, does a
    LOCAL combine scatter, and the only collective is one bf16 psum of
    [b_loc, S, d] partial outputs over 'model' (+ explicit FSDP
    all-gathers of expert weights when cfg.fsdp_experts).
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_model = mesh.shape["model"]
    e_loc = e // n_model
    cap = max(int(s * k / e * cfg.capacity_factor), 1)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    bias = params.get("router_bias")
    has_bias = bias is not None
    if not has_bias:
        bias = jnp.zeros((e,), jnp.float32)

    def local_fn(x_loc, router, router_bias, w_g, w_u, w_d):
        if cfg.fsdp_experts:
            w_g = jax.lax.all_gather(w_g, "data", axis=1, tiled=True)
            w_u = jax.lax.all_gather(w_u, "data", axis=1, tiled=True)
            w_d = jax.lax.all_gather(w_d, "data", axis=1, tiled=True)
        my_e0 = jax.lax.axis_index("model") * e_loc

        def row(xr):
            w, idx, aux = _route_arrays(xr.astype(jnp.float32), router,
                                        router_bias if has_bias else None,
                                        cfg)
            token_for, weight_for = _dispatch_indices(idx, w, e, cap)
            tf = jax.lax.dynamic_slice_in_dim(token_for, my_e0 * cap,
                                              e_loc * cap)
            wf = jax.lax.dynamic_slice_in_dim(weight_for, my_e0 * cap,
                                              e_loc * cap)
            x_pad = jnp.concatenate([xr, jnp.zeros((1, d), xr.dtype)], 0)
            buf = x_pad[tf].reshape(e_loc, cap, d)
            h_g = jnp.einsum("ecd,edf->ecf", buf, w_g)
            h_u = jnp.einsum("ecd,edf->ecf", buf, w_u)
            h = jax.nn.silu(h_g.astype(jnp.float32)).astype(xr.dtype) * h_u
            y = jnp.einsum("ecf,efd->ecd", h, w_d)
            y = y * wf.reshape(e_loc, cap, 1).astype(y.dtype)
            out = jnp.zeros((s + 1, d), xr.dtype) \
                .at[tf].add(y.reshape(-1, d))[:s]
            return out, aux

        out, aux = jax.vmap(row)(x_loc)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux.mean(), mesh.axis_names)
        return out, aux

    wspec = P("model", "data" if cfg.fsdp_experts else None, None)
    routed, aux = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(), P(), wspec, wspec, wspec),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(x, params["router"], bias, params["w_gate"], params["w_up"],
      params["w_down"])
    if cfg.n_shared:
        routed = routed + _shared_experts(x, params)
    return routed, aux
