"""Minimal offline fallback for the `hypothesis` property-testing API.

This repo's tests use a small slice of hypothesis (`given`, `settings`,
`assume`, and a few strategies).  The canonical dependency is the real
package (see requirements-dev.txt); this fallback exists so the tier-1
suite runs in hermetic environments where installing it is impossible.

Because the repo is driven with ``PYTHONPATH=src``, this package would
shadow a real installation — so on import it first looks for a real
`hypothesis` elsewhere on sys.path and transparently delegates to it.
Only when none exists does the fallback engine below activate: it draws
`max_examples` pseudo-random examples per test from a fixed seed
(deterministic across runs; no shrinking, no database).
"""

from __future__ import annotations

import os as _os
import sys as _sys

__version__ = "0.0-repro-fallback"


def _delegate_to_real() -> bool:
    """Load a real hypothesis installation if one exists elsewhere."""
    here = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
    src_paths = {here}
    found = None
    for p in _sys.path:
        ap = _os.path.abspath(p or ".")
        if ap in src_paths:
            continue
        if _os.path.exists(_os.path.join(ap, "hypothesis", "__init__.py")):
            found = ap
            break
    if found is None:
        return False
    self_mod = _sys.modules.get(__name__)
    try:
        saved = list(_sys.path)
        _sys.modules.pop("hypothesis", None)
        _sys.path = [p for p in _sys.path
                     if _os.path.abspath(p or ".") not in src_paths]
        try:
            import hypothesis as _real  # noqa: F811 — the real package
        finally:
            _sys.path = saved
        _sys.modules["hypothesis"] = _real
        globals().update({k: v for k, v in _real.__dict__.items()
                          if not k.startswith("__")})
        return True
    except Exception:  # noqa: BLE001 — any failure: use the fallback
        if self_mod is not None:
            _sys.modules["hypothesis"] = self_mod
        return False


if not _delegate_to_real():
    import functools as _functools
    import inspect as _inspect
    import random as _random

    from hypothesis import strategies  # noqa: F401 — submodule re-export

    class _Unsatisfied(Exception):
        """Raised by assume() to discard the current example."""

    def assume(condition) -> bool:
        if not condition:
            raise _Unsatisfied()
        return True

    class HealthCheck:          # accepted and ignored
        all = staticmethod(lambda: [])
        too_slow = "too_slow"
        filter_too_much = "filter_too_much"
        data_too_large = "data_too_large"

    class _Settings:
        def __init__(self, max_examples: int = 25, deadline=None,
                     **_ignored) -> None:
            self.max_examples = int(max_examples)
            self.deadline = deadline

        def __call__(self, fn):
            fn._hypothesis_settings = self
            return fn

    settings = _Settings

    def example(*_args, **_kwargs):
        """Accepted for API compatibility; explicit examples are skipped."""
        return lambda fn: fn

    def given(*arg_strategies, **kw_strategies):
        """Run the test on `max_examples` deterministic random draws.

        Positional strategies bind to the test's first parameters in
        order; keyword strategies bind by name (the only form the repo's
        tests use).  No shrinking: the failing draw is re-raised as-is.
        """

        def decorate(fn):
            inner = getattr(fn, "_hypothesis_inner", fn)

            @_functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = (getattr(wrapper, "_hypothesis_settings", None)
                       or getattr(fn, "_hypothesis_settings", None)
                       or _Settings())
                rnd = _random.Random(0xC0FFEE)
                ran = 0
                attempts = 0
                while ran < cfg.max_examples \
                        and attempts < 10 * cfg.max_examples:
                    attempts += 1
                    drawn = [s.draw(rnd) for s in arg_strategies]
                    drawn_kw = {k: s.draw(rnd)
                                for k, s in kw_strategies.items()}
                    try:
                        fn(*args, *drawn, **kwargs, **drawn_kw)
                    except _Unsatisfied:
                        continue
                    ran += 1
                if ran == 0:
                    raise RuntimeError(
                        f"{fn.__name__}: assume() rejected every drawn "
                        f"example ({attempts} attempts) — the test "
                        "asserted nothing")

            # hide strategy-bound parameters from pytest's fixture
            # resolution (mirrors real hypothesis behaviour)
            sig = _inspect.signature(inner)
            params = list(sig.parameters.values())
            params = params[len(arg_strategies):]
            params = [p for p in params if p.name not in kw_strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            wrapper._hypothesis_inner = inner
            return wrapper

        return decorate
