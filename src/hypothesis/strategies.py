"""Strategies for the offline hypothesis fallback (see package docstring).

Each strategy is a tiny object with ``draw(rnd)``; ``map`` and ``filter``
compose.  Only the strategies this repo's tests need are implemented.
"""

from __future__ import annotations

import random
from typing import Any, Callable, Sequence

__all__ = ["integers", "floats", "booleans", "binary", "sampled_from",
           "lists", "tuples", "just", "text"]


class SearchStrategy:
    def __init__(self, draw_fn: Callable[[random.Random], Any]) -> None:
        self._draw_fn = draw_fn

    def draw(self, rnd: random.Random) -> Any:
        return self._draw_fn(rnd)

    def map(self, f: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rnd: f(self.draw(rnd)))

    def filter(self, pred: Callable[[Any], bool],
               max_tries: int = 100) -> "SearchStrategy":
        def drawer(rnd: random.Random) -> Any:
            for _ in range(max_tries):
                v = self.draw(rnd)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(drawer)


def integers(min_value: int | None = None,
             max_value: int | None = None) -> SearchStrategy:
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 if max_value is None else int(max_value)
    return SearchStrategy(lambda rnd: rnd.randint(lo, hi))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.random() < 0.5)


def binary(min_size: int = 0, max_size: int = 64) -> SearchStrategy:
    def drawer(rnd: random.Random) -> bytes:
        n = rnd.randint(min_size, max_size)
        return bytes(rnd.getrandbits(8) for _ in range(n))
    return SearchStrategy(drawer)


def sampled_from(options: Sequence[Any]) -> SearchStrategy:
    options = list(options)
    return SearchStrategy(lambda rnd: options[rnd.randrange(len(options))])


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 16, **_ignored) -> SearchStrategy:
    def drawer(rnd: random.Random) -> list:
        n = rnd.randint(min_size, max_size)
        return [elements.draw(rnd) for _ in range(n)]
    return SearchStrategy(drawer)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rnd: tuple(s.draw(rnd)
                                            for s in strategies))


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rnd: value)


def text(alphabet: str = "abcdefghijklmnopqrstuvwxyz", min_size: int = 0,
         max_size: int = 16) -> SearchStrategy:
    def drawer(rnd: random.Random) -> str:
        n = rnd.randint(min_size, max_size)
        return "".join(rnd.choice(alphabet) for _ in range(n))
    return SearchStrategy(drawer)
