"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the yi-6b architecture family scaled to ~100M params (same code path
as the full config — GQA + RoPE + SwiGLU + scan + remat), synthetic token
stream, AdamW, checkpointing every 50 steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.loaders import token_batches
from repro.models.transformer import LMConfig, init_params, lm_loss
from repro.train.trainer import TrainConfig, Trainer


def make_100m_config() -> LMConfig:
    # ~100M params: 12L, d=768, 12H (GQA kv=4), ffn 2048, vocab 32k
    return LMConfig(name="yi-100m", n_layers=12, d_model=768, n_heads=12,
                    n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000,
                    dtype=jnp.float32, remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = make_100m_config()
    print(f"config: {cfg.name}, {cfg.param_count() / 1e6:.0f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    trainer = Trainer(
        lambda p, b: lm_loss(p, cfg, b[0], b[1]), params,
        TrainConfig(n_steps=args.steps, lr=3e-4, ckpt_dir=args.ckpt,
                    ckpt_every=50, log_every=10))
    t0 = time.time()
    hist = trainer.fit(iter(token_batches(args.batch, args.seq, cfg.vocab)))
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"trained {args.steps} steps ({toks} tokens) in {dt:.0f}s "
          f"({toks / dt:.0f} tok/s on CPU)")
    for h in hist[:: max(len(hist) // 8, 1)]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}")
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
