"""Serve a small LM with batched requests + KV cache (decode loop).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (LMConfig, decode_step, init_cache,
                                      init_params)


def main() -> None:
    cfg = LMConfig(name="serve-demo", n_layers=4, d_model=256, n_heads=8,
                   n_kv_heads=2, head_dim=32, d_ff=704, vocab=32_000,
                   sliding_window=64, dtype=jnp.float32, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch, prompt_len, gen_len = 8, 16, 48
    t_max = min(prompt_len + gen_len, cfg.sliding_window)
    cache = init_cache(cfg, batch, t_max)
    step = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i))

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                         jnp.int32)
    tok = prompt[:, :1]
    t0 = time.time()
    generated = []
    for i in range(prompt_len + gen_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        if i + 1 < prompt_len:
            tok = prompt[:, i + 1:i + 2]
        else:
            # greedy decode
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            generated.append(tok)
    dt = time.time() - t0
    total = gen_len * batch
    print(f"served {batch} requests x {gen_len} tokens in {dt:.2f}s "
          f"({total / dt:.0f} tok/s, SWA-bounded KV cache of {t_max})")


if __name__ == "__main__":
    main()
