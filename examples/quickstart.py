"""Quickstart: end-to-end distributed exact subgraph matching in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.data.synthetic import make_workload, nws_graph
from repro.dist.cluster import DistributedGNNPE


def main() -> None:
    # 1. a synthetic labeled data graph (Newman-Watts-Strogatz, 6 labels)
    graph = nws_graph(n=600, k=6, p=0.1, n_labels=6, seed=0)
    print(f"data graph: {graph.n_vertices} vertices, {graph.n_edges} edges")

    # 2. build the distributed engine: 4 machines, 16 ultra-fine shards,
    #    per-shard dominance embeddings + aR-trees, PE-score model, caches
    engine = DistributedGNNPE.build(graph, n_machines=4,
                                    shards_per_machine=4, seed=0)
    print(f"offline: {engine.offline_report}")

    # 3. run a query workload with all three innovations active
    queries = make_workload(graph, 10, seed=1, hot_fraction=0.5)
    for i, q in enumerate(queries[:5]):
        matches, tel = engine.query(q)
        print(f"q{i}: |V(q)|={q.n_vertices} -> {len(matches)} exact matches "
              f"({tel.latency_ms:.1f} virtual ms, "
              f"{tel.shards_skipped} shards pruned, "
              f"{tel.cache_hits} cache hits)")

    # 4. full workload with dynamic load balancing
    engine.run_workload(queries, rebalance=True)
    print(f"workload: cache hit rate {engine.cache.hit_rate:.2f}, "
          f"{len(engine.migrations)} migration batches, "
          f"load sigma {engine.load_sigma():.3f}")


if __name__ == "__main__":
    main()
