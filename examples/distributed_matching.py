"""Distributed matching deep-dive: migration, failover, plan comparison.

    PYTHONPATH=src python examples/distributed_matching.py
"""

from repro.data.synthetic import make_workload, nws_graph
from repro.dist.chaos import CRASH, HOOK_QUERY, FaultPlan, FaultSpec
from repro.dist.cluster import DistributedGNNPE
from repro.dist.router import QueryBudget
from repro.train.elastic import WorkerFailover


def main() -> None:
    graph = nws_graph(800, 6, 0.1, 10, seed=2, label_skew=0.5)
    engine = DistributedGNNPE.build(graph, n_machines=4,
                                    shards_per_machine=4, seed=2)
    queries = make_workload(graph, 16, seed=2, hot_fraction=0.8, n_hot=2)

    # --- skewed load -> migrations ---------------------------------- #
    engine.run_workload(queries, rebalance=True, corrupt_prob=0.1)
    print(f"sigma history: {[round(h['sigma'], 3) for h in engine.history]}")
    for m in engine.migrations:
        print(f"  migrated {m.migrated} ({m.bytes_moved}B, "
              f"{m.retransmissions} retrans, {m.virtual_ms:.1f} vms)")

    # --- query plan comparison --------------------------------------- #
    engine.use_cache = False
    for mode in ("pescore", "degree", "natural"):
        tel = [engine.query(q, plan_mode=mode)[1] for q in queries[:6]]
        print(f"plan={mode:8s}: comm={sum(t.comm_bytes for t in tel):9d}B "
              f"latency={sum(t.latency_ms for t in tel):7.1f}vms")
    engine.use_cache = True

    # --- kill a machine, verify exactness ----------------------------- #
    fo = WorkerFailover(engine)
    dead = fo.fail_machine(2)
    print(f"machine 2 died; re-homed shards {dead}")
    m, tel = engine.query(queries[0])
    print(f"post-failover query: {len(m)} matches "
          f"({tel.latency_ms:.1f} vms) — service continued")

    # --- chaos: seeded faults, exact answers or typed failure --------- #
    engine.enable_replication(1)         # standbys: failover = promotion
    engine.set_fault_plan(FaultPlan(
        [FaultSpec(kind=CRASH, hook=HOOK_QUERY, at=2, machine=0)], seed=0))
    for _ in range(3):                   # machine 0 dies mid-stream
        mm, _ = engine.query(queries[0])
        assert len(mm) == len(m), "chaos changed an answer"
    engine.set_fault_plan(None)
    assert engine.consistency_audit() == []
    print(f"chaos: crashed machine 0 mid-workload "
          f"({engine.replicas.stats()['promotions']} shards promoted "
          f"from replicas) — answers exact, state audit clean")

    # --- degraded-mode serving: standbys answer, promotion deferred -- #
    eng = DistributedGNNPE.build(graph, n_machines=4,
                                 shards_per_machine=4, seed=2,
                                 assignment=engine.assignment,
                                 params=engine.params, replication=2,
                                 failover_mode="route")
    want = len(eng.query(queries[0], probe_mode="host")[0])
    eng.use_cache = False                # measure real degraded reads
    eng.handle_machine_failure(1)        # no promotion, no re-sync
    mm, tel = eng.query(queries[0], budget=QueryBudget(hedge_after_ms=8.0))
    assert len(mm) == want, "degraded read changed the answer"
    print(f"degraded-mode: machine 1 dead, answer served from standbys "
          f"(state={eng.router.state()}, "
          f"degraded={tel.outcome.served_degraded}, "
          f"standby reads={eng.router.stats()['standby_reads']}, "
          f"0 promotions) — bit-identical")
    rec = eng.recover()                  # promotion off the read path
    print(f"recover(): promoted {rec['promoted']} -> "
          f"state={eng.router.state()}")


if __name__ == "__main__":
    main()
