"""Workload gauntlet (ISSUE 6).

Tier-1 (always on): two full three-oracle smoke cells, generator/query
property tests, degenerate-query contracts through the whole engine,
and a backtrack_join table-vs-recursive regression on a high-match
cell.

`@pytest.mark.gauntlet` (opt in with --run-gauntlet / RUN_GAUNTLET=1):
the full standing matrix — every (topology x shape x regime) cell
verified against all three oracles, the pristine-graph regime promises,
and a megabatch `run_workload` counter-identity sweep.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graph import LabeledGraph
from repro.core import matching
from repro.data.gauntlet import (MODE_COUNTERS, TOPOLOGY_BUILDERS, CellSpec,
                                 Gauntlet, brute_force_matches,
                                 build_topology)
from repro.data.synthetic import (SHAPE_NAMES, bipartite_graph,
                                  community_graph, is_connected,
                                  near_clique_graph, shape_query,
                                  skewed_label_graph)

# one engine per topology, shared across this module's cells; the
# harness is designed to accumulate migration/update state (see
# repro.data.gauntlet docstring)
_GAUNTLETS: dict[str, Gauntlet] = {}


def _gauntlet(topology: str) -> Gauntlet:
    if topology not in _GAUNTLETS:
        _GAUNTLETS[topology] = Gauntlet(build_topology(topology), seed=0)
    return _GAUNTLETS[topology]


def _resolve_cell(topo: str, shape: str, regime: str) -> CellSpec:
    """Mirror default_matrix's per-cell resolution: even cycles on
    bipartite graphs, dense cells retried over 3 template seeds and
    skipped when the shape is structurally absent."""
    size = 6 if (shape == "cycle" and topo == "bipartite") else None
    if regime == "free":
        return CellSpec(topo, shape, "free", size=size)
    graph = _gauntlet(topo).graph
    for s in range(1, 4):
        try:
            shape_query(graph, shape, "dense", size=size, seed=s)
            return CellSpec(topo, shape, "dense", query_seed=s, size=size)
        except ValueError:
            continue
    pytest.skip(f"{topo}/{shape}: no dense embedding (structurally absent)")


# --------------------------------------------------------------------------- #
# tier-1 smoke: full three-oracle verification on 2 cells
# --------------------------------------------------------------------------- #
SMOKE_CELLS = (CellSpec("community", "triangle_tail", "dense"),
               CellSpec("community", "star", "free"))


@pytest.mark.parametrize("spec", SMOKE_CELLS, ids=lambda s: s.name)
def test_smoke_cell_three_oracles(spec):
    rep = _gauntlet(spec.topology).run_cell(spec)
    assert rep.ok
    assert set(rep.counters) == set(MODE_COUNTERS)
    if spec.regime == "dense":
        assert rep.n_matches >= 1
    else:
        assert rep.n_matches == 0


# --------------------------------------------------------------------------- #
# property tests: generators (offline-hypothesis)
# --------------------------------------------------------------------------- #
_GENERATORS = {
    "community": lambda seed: community_graph(60, 3, 0.2, 0.02, 8,
                                              seed=seed),
    "bipartite": lambda seed: bipartite_graph(30, 30, 3, 8, seed=seed),
    "nearclique": lambda seed: near_clique_graph(50, 8, 0.8, 2.0, 8,
                                                 seed=seed),
    "skewlabel": lambda seed: skewed_label_graph(60, 4, 8, skew=1.4,
                                                 seed=seed),
}


@settings(max_examples=10, deadline=None)
@given(name=st.sampled_from(sorted(_GENERATORS)),
       seed=st.integers(min_value=0, max_value=10_000))
def test_generators_valid_and_deterministic(name, seed):
    g1 = _GENERATORS[name](seed)
    g2 = _GENERATORS[name](seed)
    # deterministic per seed
    assert g1.n_vertices == g2.n_vertices
    assert np.array_equal(g1.edge_list, g2.edge_list)
    assert np.array_equal(g1.labels, g2.labels)
    # valid LabeledGraph: no self-loops, labels in range, connected
    # (every generator above promises connected=True by default)
    assert (g1.edge_list[:, 0] != g1.edge_list[:, 1]).all()
    assert g1.labels.min() >= 0 and g1.labels.max() < 8
    assert is_connected(g1)


def test_bipartite_sides_disjoint():
    g = bipartite_graph(25, 25, 3, 8, seed=3)
    side = (np.arange(50) >= 25)
    u, v = g.edge_list[:, 0], g.edge_list[:, 1]
    assert (side[u] != side[v]).all()           # edges only cross sides


@settings(max_examples=8, deadline=None)
@given(shape=st.sampled_from(SHAPE_NAMES),
       seed=st.integers(min_value=1, max_value=50))
def test_query_regimes_and_determinism(shape, seed):
    g = _GENERATORS["community"](seed % 4)
    q_free = shape_query(g, shape, "free", seed=seed)
    assert np.array_equal(
        q_free.labels, shape_query(g, shape, "free", seed=seed).labels)
    assert len(brute_force_matches(g, q_free, limit=1)) == 0
    try:
        q_dense = shape_query(g, shape, "dense", seed=seed)
    except ValueError:
        return                                  # unminable on this graph
    assert np.array_equal(
        q_dense.labels, shape_query(g, shape, "dense", seed=seed).labels)
    assert np.array_equal(
        q_dense.edge_list, shape_query(g, shape, "dense",
                                       seed=seed).edge_list)
    assert len(brute_force_matches(g, q_dense, limit=1)) >= 1


# --------------------------------------------------------------------------- #
# degenerate queries through the full engine
# --------------------------------------------------------------------------- #
_DEGEN: list = []


def _degen_engine():
    """Small engine whose data graph leaves label 1 unused (in-range but
    absent) — the label-absent degenerate case."""
    if not _DEGEN:
        from repro.data.synthetic import nws_graph
        from repro.dist.cluster import DistributedGNNPE
        g0 = nws_graph(80, 4, 0.1, 6, seed=2)
        labels = g0.labels.copy()
        labels[labels == 1] = 0                 # label 1: in range, unused
        labels[0] = 5                           # keep n_labels = 6
        g = LabeledGraph.from_edges(g0.n_vertices, g0.edge_list, labels)
        eng = DistributedGNNPE.build(g, 2, shards_per_machine=2,
                                     gnn_train_steps=8, seed=0,
                                     max_path_length=2)
        eng.use_cache = False
        _DEGEN.append((g, eng))
    return _DEGEN[0]


def _assert_all_modes_match_brute(eng, g, q):
    ref = brute_force_matches(g, q)
    for mode in ("host", "device", "plane"):
        matches, _ = eng.query(q, probe_mode=mode)
        assert set(matches) == ref, f"{mode} diverged from brute force"
        assert len(matches) == len(set(matches))
    mega, _ = eng.query_batch([q])[0]
    assert set(mega) == ref
    return ref


def test_degenerate_single_edge_query():
    g, eng = _degen_engine()
    u, v = (int(x) for x in g.edge_list[0])
    q = LabeledGraph.from_edges(
        2, [(0, 1)], [int(g.labels[u]), int(g.labels[v])])
    ref = _assert_all_modes_match_brute(eng, g, q)
    assert len(ref) >= 2                        # (u,v) and (v,u) at least


def test_degenerate_disconnected_query():
    """Contract pin: disconnected patterns are SUPPORTED and exact —
    the planner decomposes per component and the join enforces global
    injectivity across components."""
    g, eng = _degen_engine()
    (u, v), (x, y) = g.edge_list[0], g.edge_list[10]
    q = LabeledGraph.from_edges(
        4, [(0, 1), (2, 3)],
        [int(g.labels[u]), int(g.labels[v]),
         int(g.labels[x]), int(g.labels[y])])
    ref = _assert_all_modes_match_brute(eng, g, q)
    assert len(ref) >= 1


def test_degenerate_query_larger_than_decomposition():
    """A 6-vertex path: every decomposed piece is <= max_path_length=2
    edges, the full pattern is re-verified by the join."""
    g, eng = _degen_engine()
    q = shape_query(g, "cycle", "dense", size=6, seed=1)
    _assert_all_modes_match_brute(eng, g, q)


def test_degenerate_label_absent_query_empty():
    g, eng = _degen_engine()
    q = LabeledGraph.from_edges(2, [(0, 1)], [1, 1])    # label 1 unused
    for mode in ("host", "device", "plane"):
        matches, tel = eng.query(q, probe_mode=mode)
        assert matches == []
        assert tel.n_matches == 0
    mega, _ = eng.query_batch([q])[0]
    assert mega == []


# --------------------------------------------------------------------------- #
# backtrack_join: frontier-table vs recursive fallback (high-match cell)
# --------------------------------------------------------------------------- #
def _label_candidates(data: LabeledGraph, query: LabeledGraph):
    """Boolean candidate masks (backtrack_join's input contract)."""
    return [data.labels == query.labels[v]
            for v in range(query.n_vertices)]


def test_backtrack_join_table_equals_recursive_high_match(monkeypatch):
    """Regression for the table/recursive duality: on a match-dense
    star cell both engines must return the SAME list (order included),
    across the pure-table path, the forced-recursive path, and the
    mid-join table->recursive spill."""
    g = skewed_label_graph(120, 6, 4, skew=1.5, seed=3)
    q = shape_query(g, "star", "dense", seed=2)
    cands = _label_candidates(g, q)

    table = matching.backtrack_join(q, g, [c.copy() for c in cands])
    assert len(table) >= 100, "cell not match-dense enough to stress join"
    assert set(table) == brute_force_matches(g, q)

    with monkeypatch.context() as m:            # force recursive from row 0
        m.setattr(matching, "_JOIN_BITMAP_MAX_N", 0)
        rec = matching.backtrack_join(q, g, [c.copy() for c in cands])
    assert table == rec

    with monkeypatch.context() as m:            # force mid-join spill
        m.setattr(matching, "_JOIN_STEP_MAX_ELEMS", 1)
        spill = matching.backtrack_join(q, g, [c.copy() for c in cands])
    assert table == spill

    capped = matching.backtrack_join(q, g, [c.copy() for c in cands],
                                     max_matches=17)
    assert capped == table[:17]                 # DFS prefix property


# --------------------------------------------------------------------------- #
# full standing matrix (gauntlet tier)
# --------------------------------------------------------------------------- #
@pytest.mark.gauntlet
@pytest.mark.parametrize("regime", ["dense", "free"])
@pytest.mark.parametrize("shape", SHAPE_NAMES)
@pytest.mark.parametrize("topo", sorted(TOPOLOGY_BUILDERS))
def test_matrix_cell(topo, shape, regime):
    spec = _resolve_cell(topo, shape, regime)
    rep = _gauntlet(topo).run_cell(spec)
    assert rep.ok


@pytest.mark.gauntlet
@pytest.mark.parametrize("topo", sorted(TOPOLOGY_BUILDERS))
def test_pristine_regime_promises(topo):
    """On the PRISTINE standing graph (before any engine mutation):
    dense queries have >= 1 embedding, free queries have 0."""
    graph = build_topology(topo)
    for shape in SHAPE_NAMES:
        size = 6 if (shape == "cycle" and topo == "bipartite") else None
        q = shape_query(graph, shape, "free", size=size, seed=1)
        assert len(brute_force_matches(graph, q, limit=1)) == 0
        for s in range(1, 4):
            try:
                q = shape_query(graph, shape, "dense", size=size, seed=s)
            except ValueError:
                continue
            assert len(brute_force_matches(graph, q, limit=1)) >= 1
            break


@pytest.mark.gauntlet
def test_workload_megabatch_counter_identity():
    """`run_workload(batch_size=3)` over a mixed gauntlet workload
    keeps every deterministic per-query counter identical to the
    serial host path (launch attribution differs by design)."""
    gnt = _gauntlet("community")
    qs = []
    for shape in SHAPE_NAMES:
        for regime in ("dense", "free"):
            try:
                qs.append(shape_query(gnt.graph, shape, regime, seed=1))
            except ValueError:
                pass
    serial = [gnt.eng.query(q, probe_mode="host")[1] for q in qs]
    batched = gnt.eng.run_workload(qs, batch_size=3, probe_mode="plane")
    assert len(serial) == len(batched)
    for ts, tb in zip(serial, batched):
        assert Gauntlet.counters(ts) == Gauntlet.counters(tb)
