"""Training substrate: optimizers, checkpointing, recovery, accumulation."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (list_checkpoints, restore_latest,
                                    save_checkpoint)
from repro.train.optimizer import (adam8bit_init, adam8bit_update, adam_init,
                                   adam_update, adamw_init, adamw_update,
                                   clip_by_global_norm, global_norm)


def _quadratic_problem(seed=0, d=64):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(d, d)) / np.sqrt(d), jnp.float32)
    target = jnp.asarray(rng.normal(size=d), jnp.float32)

    def loss(p):
        return jnp.sum((a @ p["x"] - target) ** 2)

    params = {"x": jnp.zeros(d)}
    return loss, params


def test_adam_converges():
    loss, params = _quadratic_problem()
    opt = adam_init(params)
    step = jax.jit(lambda p, o: adam_update(p, jax.grad(loss)(p), o, lr=0.05))
    l0 = float(loss(params))
    for _ in range(500):
        params, opt = step(params, opt)
    # random quadratics are ill-conditioned; 20x reduction is convergence
    assert float(loss(params)) < 5e-2 * l0


def test_adam8bit_tracks_adam():
    loss, params = _quadratic_problem(seed=1)
    p32, o32 = dict(params), adam_init(params)
    p8, o8 = dict(params), adam8bit_init(params)
    for _ in range(100):
        g = jax.grad(loss)(p32)
        p32, o32 = adam_update(p32, g, o32, lr=0.03)
        g8 = jax.grad(loss)(p8)
        p8, o8 = adam8bit_update(p8, g8, o8, lr=0.03, b2=0.999,
                                 weight_decay=0.0)
    l32, l8 = float(loss(p32)), float(loss(p8))
    assert l8 < 0.5 * float(loss({"x": jnp.zeros_like(p8["x"])}))
    assert l8 < 10 * max(l32, 1e-3), (l8, l32)


def test_adam8bit_state_is_actually_8bit():
    params = {"x": jnp.zeros(4096), "y": jnp.zeros((64, 64))}
    st = adam8bit_init(params)
    assert all(c.dtype == jnp.int8 for c in jax.tree.leaves(st.mu_codes))
    # quantized state bytes ~= n + n/256 scales (vs 4n for fp32 Adam)
    n = sum(x.size for x in jax.tree.leaves(params))
    q_bytes = sum(x.size for x in jax.tree.leaves(st.mu_codes)) \
        + 4 * sum(x.size for x in jax.tree.leaves(st.mu_scales))
    assert q_bytes <= 1.3 * n


def test_clip_by_global_norm():
    tree = {"a": jnp.full(4, 3.0), "b": jnp.full(9, 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(
        float(jnp.sqrt(4 * 9.0 + 9 * 16.0)), rel=1e-5)


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    state = {"w": jnp.arange(10.0), "step": jnp.int32(7)}
    save_checkpoint(str(tmp_path), 7, state, extra={"note": "x"})
    out = restore_latest(str(tmp_path), state)
    assert out is not None
    restored, manifest = out
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(10.0))
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"


def test_checkpoint_corruption_falls_back(tmp_path):
    state = {"w": jnp.arange(8.0)}
    save_checkpoint(str(tmp_path), 1, state)
    save_checkpoint(str(tmp_path), 2,
                    {"w": jnp.arange(8.0) * 2})
    # corrupt newest
    _, newest = list_checkpoints(str(tmp_path))[-1]
    npz = os.path.join(newest, "arrays.npz")
    blob = bytearray(open(npz, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(blob))
    out = restore_latest(str(tmp_path), state)
    assert out is not None
    restored, manifest = out
    assert manifest["step"] == 1, "must fall back to last VALID checkpoint"
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))


def test_failure_recovery_resumes(tmp_path):
    """Kill training mid-run; resumed run continues from the checkpoint."""
    from repro.train.elastic import simulate_failure_and_restore
    from repro.train.trainer import TrainConfig, Trainer
    loss, params0 = _quadratic_problem(seed=2)

    def factory(ckpt_dir):
        return Trainer(lambda p, b: loss(p), dict(params0),
                       TrainConfig(n_steps=40, lr=0.05, ckpt_dir=ckpt_dir,
                                   ckpt_every=10, log_every=5))

    batches = iter(lambda: jnp.zeros(()), None)
    h1, h2 = simulate_failure_and_restore(factory, batches, fail_at=20,
                                          total_steps=40,
                                          ckpt_dir=str(tmp_path))
    assert h2[-1]["step"] == 40
    assert h2[-1]["loss"] <= h1[-1]["loss"] + 1e-6


def test_grad_accumulation_equivalence():
    """accum_steps=4 over a batch == one step over the full batch."""
    from repro.train.trainer import make_accum_step
    rng = np.random.default_rng(0)
    w = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)

    def loss_fn(p, batch):
        xb, yb = batch
        return jnp.mean((xb @ p["w"] - yb) ** 2)

    upd = lambda p, g, o: adamw_update(p, g, o, lr=1e-2, weight_decay=0.0)
    s1 = make_accum_step(loss_fn, upd, clip_norm=1e9, accum_steps=1)
    s4 = make_accum_step(loss_fn, upd, clip_norm=1e9, accum_steps=4)
    p1, o1, m1 = s1(w, adamw_init(w), (x, y))
    p4, o4, m4 = s4(w, adamw_init(w), (x, y))
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-4, atol=1e-5)
