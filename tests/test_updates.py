"""Streaming graph updates (ISSUE 5).

Contracts under test:

  * rebuild equivalence — `apply_updates` followed by `query` /
    `query_batch` is bit-identical (matches, node counters, comm bytes,
    and the shard byte images themselves) to a freshly built engine on
    the updated graph with the same partition assignment and GNN
    params, in all of probe_mode {host, device, plane};
  * invalidation scope — only touched shards repack their resident
    probe planes after an update; untouched shards keep their warm
    slabs (plane tokens unchanged, zero slab h2d bytes);
  * epoch consistency — result-cache keys embed the data epoch, so a
    post-update query can never be served a pre-update answer, and
    superseded results are purged from every tier;
  * in-flight megabatch — a batch dispatched before an update and
    consumed after it falls back to the serial plane path (epoch stamp
    + stale-assembly backstop) and returns post-update answers;
  * updates under concurrent rebalancing — interleaving apply_updates
    with rebalancing workload epochs preserves the rebuild-equivalence
    invariant and exactness (offline-hypothesis property).

The test graphs are built from disjoint communities with the partition
assignment injected along community lines: with 2-hop halos a
small-world update touches every shard (the halo legitimately spans the
graph), so locality claims need a topology that HAS locality.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graph import GraphDelta, LabeledGraph, apply_graph_delta
from tests.conftest import vf2_oracle

_COUNTERS = ("comm_bytes", "cross_shard_rows", "shards_skipped",
             "paths_executed", "paths_skipped", "n_matches", "cache_hits")
_MODES = ("host", "device", "plane")


def clustered_graph(n_comp=4, size=55, n_labels=5, seed=0) -> LabeledGraph:
    """Disjoint ring-plus-chords communities (one shard each)."""
    rng = np.random.default_rng(seed)
    edges, labels = [], []
    for c in range(n_comp):
        base = c * size
        for i in range(size):
            edges.append([base + i, base + (i + 1) % size])
        extra = rng.integers(0, size, (size, 2)) + base
        edges.extend(extra.tolist())
        labels.extend(rng.integers(0, n_labels, size).tolist())
    return LabeledGraph.from_edges(n_comp * size, np.asarray(edges),
                                   np.asarray(labels))


def _build(seed=1, n_comp=4, size=55):
    from repro.dist.cluster import DistributedGNNPE
    g = clustered_graph(n_comp=n_comp, size=size, seed=seed)
    assignment = np.repeat(np.arange(n_comp), size).astype(np.int32)
    eng = DistributedGNNPE.build(g, 2, shards_per_machine=n_comp // 2,
                                 gnn_train_steps=8, seed=seed,
                                 assignment=assignment)
    return g, eng


_ENGINE = None


def _engine():
    """Module-shared engine for READ-ONLY tests (no-op delta,
    validation errors).  Tests that apply real updates or flip engine
    flags must call `_build()` for a private instance — shared mutable
    state would make their assertions order-dependent."""
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = _build()
    return _ENGINE


def random_delta(graph: LabeledGraph, rng: np.random.Generator,
                 component=0, size=55, n_labels=5,
                 with_vertices=True) -> GraphDelta:
    """A random insert+delete mix confined to one community."""
    base = component * size
    comp_edges = graph.edge_list[
        (graph.edge_list[:, 0] >= base)
        & (graph.edge_list[:, 0] < base + size)]
    n_del = int(rng.integers(1, 4))
    dels = comp_edges[rng.choice(comp_edges.shape[0],
                                 min(n_del, comp_edges.shape[0]),
                                 replace=False)]
    adds = rng.integers(base, base + size, (int(rng.integers(1, 4)), 2))
    deleted = {tuple(sorted(e)) for e in dels.tolist()}
    adds = np.asarray([e for e in adds.tolist()
                       if tuple(sorted(e)) not in deleted],
                      np.int64).reshape(-1, 2)
    add_labels, extra_edges = (), []
    if with_vertices and rng.random() < 0.5:
        n0 = graph.n_vertices
        add_labels = rng.integers(0, n_labels, 1)
        extra_edges = [[n0, int(rng.integers(base, base + size))]]
    return GraphDelta.make(
        add_vertex_labels=add_labels,
        add_edges=np.concatenate([adds, np.asarray(extra_edges,
                                                   np.int64).reshape(-1, 2)])
        if len(extra_edges) else adds,
        del_edges=dels)


def assert_engines_equivalent(eng, ref, queries, modes=_MODES):
    """matches + deterministic counters + shard images bit-identical."""
    for sid in eng.shards:
        assert eng.shards[sid].serialize() == ref.shards[sid].serialize(), \
            f"shard {sid} byte image diverged from the rebuild oracle"
    for mode in modes:
        for q in queries:
            m1, t1 = eng.query(q, probe_mode=mode)
            m2, t2 = ref.query(q, probe_mode=mode)
            assert m1 == m2, f"matches diverged in {mode}"
            for f in _COUNTERS:
                assert getattr(t1, f) == getattr(t2, f), (mode, f)


# --------------------------------------------------------------------------- #
# tentpole: rebuild equivalence
# --------------------------------------------------------------------------- #


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_update_rebuild_equivalence_property(seed):
    """For random insert+delete batches, update-then-query is
    bit-identical to a from-scratch build on the updated graph — all
    probe modes, plus the exactness oracle."""
    from repro.data.synthetic import make_workload
    rng = np.random.default_rng(seed)
    g, eng = _build(seed=2)
    eng.use_cache = False
    for component in (0, int(rng.integers(0, 4))):
        rep = eng.apply_updates(random_delta(eng.graph, rng,
                                             component=component))
        assert rep.data_epoch == eng._data_epoch
    ref = eng.rebuild_reference()
    ref.use_cache = False
    qs = make_workload(eng.graph, 3, seed=seed)
    assert_engines_equivalent(eng, ref, qs)
    # exactness against the VF2 oracle on the UPDATED graph
    m, _ = eng.query(qs[0])
    assert set(m) == vf2_oracle(eng.graph, qs[0])


def test_update_query_batch_matches_serial_and_reference():
    from repro.data.synthetic import make_workload
    g, eng = _build(seed=3)
    eng.use_cache = False
    eng.apply_updates(random_delta(g, np.random.default_rng(5)))
    ref = eng.rebuild_reference()
    ref.use_cache = False
    qs = make_workload(eng.graph, 4, seed=11)
    got = eng.query_batch(qs)
    want = [ref.query(q, probe_mode="plane") for q in qs]
    for (m_b, t_b), (m_s, t_s) in zip(got, want):
        assert m_b == m_s
        for f in _COUNTERS:
            assert getattr(t_b, f) == getattr(t_s, f), f


def test_update_reuses_clean_paths_and_ships_deltas():
    """The perf contract: only paths through dirty vertices re-embed,
    and the CRC'd delta is a fraction of the full-cluster image."""
    g, eng = _build(seed=11)
    u, v = map(int, g.edge_list[3])
    rep = eng.apply_updates(GraphDelta.make(del_edges=[[u, v]]))
    assert rep.touched_shards and len(rep.touched_shards) < rep.n_shards
    assert rep.paths_reused > 0, "clean paths must be spliced, not recomputed"
    assert rep.delta_bytes < rep.full_image_bytes / 2
    assert rep.retransmissions == 0


def test_update_delta_transfer_retransmits_under_corruption():
    """The delta protocol rides the migration CRC/retry machinery:
    injected corruption must retransmit, never install a bad image."""
    _, eng = _build(seed=4, n_comp=2, size=40)
    eng.use_cache = False
    rng = np.random.default_rng(0)
    total_retrans = 0
    for k in range(4):      # corruption is stochastic; sample several
        # a corrupted delta must never install: apply_updates raises
        # before any commit if delivery fails CRC, so returning at all
        # certifies every installed image was verified
        rep = eng.apply_updates(
            random_delta(eng.graph, rng,
                         component=k % 2, size=40,
                         n_labels=5, with_vertices=False),
            corrupt_prob=0.8)
        total_retrans += rep.retransmissions
    assert total_retrans > 0, "corruption should force retransmissions"
    ref = eng.rebuild_reference()
    for sid in eng.shards:
        assert eng.shards[sid].serialize() == ref.shards[sid].serialize()


# --------------------------------------------------------------------------- #
# invalidation scope: untouched shards keep warm slabs
# --------------------------------------------------------------------------- #


def test_untouched_shards_keep_warm_slabs():
    from repro.data.synthetic import make_workload
    g, eng = _build(seed=12)
    eng.use_cache = False
    qs = make_workload(eng.graph, 2, seed=5)
    for q in qs:
        eng.query(q, probe_mode="plane")        # pack + warm every plane
    tokens_before = dict(eng.planes.tokens())
    builds_before = eng.planes.stats["plane_builds"]

    e = eng.graph.edge_list
    u, v = map(int, e[int(e.shape[0] // 2)])
    rep = eng.apply_updates(GraphDelta.make(del_edges=[[u, v]]))
    touched = set(rep.touched_shards)
    assert touched and touched < set(eng.shards), \
        "fixture must leave untouched shards"

    for q in qs:
        eng.query(q, probe_mode="plane")        # repack only what changed
    tokens_after = eng.planes.tokens()
    untouched_keys = [k for k in tokens_before if k[0] not in touched]
    assert untouched_keys
    for k in untouched_keys:
        assert tokens_after.get(k) == tokens_before[k], \
            f"untouched plane {k} was repacked (slab h2d > 0)"
    # every new pack belongs to a touched shard
    repacked = [k for k, t in tokens_after.items()
                if tokens_before.get(k) != t]
    assert all(k[0] in touched for k in repacked)
    assert eng.planes.stats["plane_builds"] - builds_before == len(repacked)


# --------------------------------------------------------------------------- #
# epoch consistency: caches can never serve pre-update answers
# --------------------------------------------------------------------------- #


def test_cache_epoch_never_serves_stale_answer():
    from repro.data.synthetic import random_walk_query
    g, eng = _build(seed=6, n_comp=2, size=40)
    assert eng.use_cache
    q = random_walk_query(eng.graph, 3, seed=3)
    m0, t0 = eng.query(q)
    m_cached, t_cached = eng.query(q)
    assert t_cached.cache_hits == 1 and m_cached == m0

    # delete an edge of an actual match (guaranteed answer change
    # candidate) — or any edge if the query had no matches
    if m0:
        qe = q.edge_list[0]
        mapped = [[m[qe[0]], m[qe[1]]] for m in m0]
        delta = GraphDelta.make(del_edges=mapped)
    else:
        delta = GraphDelta.make(del_edges=[eng.graph.edge_list[0]])
    rep = eng.apply_updates(delta)
    assert rep.results_purged >= 1

    m1, t1 = eng.query(q)
    assert t1.cache_hits == 0, \
        "post-update query must re-execute, never hit a pre-update entry"
    assert set(m1) == vf2_oracle(eng.graph, q)
    if m0:
        assert set(m1) != set(m0), "fixture should have changed the answer"
    # stale keys are gone from every tier
    assert all(k[0] == eng._data_epoch for store in eng._slave_store.values()
               for k in store)
    assert all(k[0] == eng._data_epoch for k in eng.cache.location)


def test_inflight_megabatch_spanning_update_falls_back_serially():
    """Dispatch -> apply_updates -> consume: the flight's epoch stamp
    (and the stale-assembly backstop) force the serial plane path, so
    every answer reflects the POST-update graph."""
    from repro.data.synthetic import make_workload
    g, eng = _build(seed=7)
    eng.use_cache = False
    qs = make_workload(eng.graph, 3, seed=13)
    mb = eng._mb_dispatch(qs, "pescore")
    rep = eng.apply_updates(
        GraphDelta.make(add_vertex_labels=[1],
                        add_edges=[[eng.graph.n_vertices, 0]],
                        del_edges=[eng.graph.edge_list[0]]))
    assert rep.data_epoch == eng._data_epoch
    got = eng._mb_consume(mb)
    ref = eng.rebuild_reference()
    ref.use_cache = False
    for (m_b, t_b), q in zip(got, qs):
        m_s, t_s = ref.query(q, probe_mode="plane")
        assert m_b == m_s
        for f in _COUNTERS:
            assert getattr(t_b, f) == getattr(t_s, f), f
        assert set(m_b) == vf2_oracle(eng.graph, q)


# --------------------------------------------------------------------------- #
# property: updates under concurrent rebalancing epochs
# --------------------------------------------------------------------------- #


@settings(max_examples=2, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_updates_interleaved_with_rebalancing_property(seed):
    """apply_updates interleaved with rebalancing (and megabatch)
    workload epochs keeps the rebuild-equivalence invariant and never
    leaves a plane or cached result epoch-stale."""
    from repro.data.synthetic import make_workload
    rng = np.random.default_rng(seed)
    g, eng = _build(seed=8)
    for step in range(2):
        qs = make_workload(eng.graph, 6, seed=seed + step,
                           hot_fraction=0.5)
        eng.run_workload(qs, rebalance=True,
                         batch_size=3 if step else None,
                         probe_mode="plane")
        eng.apply_updates(random_delta(eng.graph, rng,
                                       component=int(rng.integers(0, 4))))
        # post-update stream is served fresh and exactly
        q = make_workload(eng.graph, 1, seed=seed + 91)[0]
        m, tel = eng.query(q, probe_mode="plane")
        assert tel.cache_hits == 0
        assert set(m) == vf2_oracle(eng.graph, q)
    eng.use_cache = False
    ref = eng.rebuild_reference()
    ref.use_cache = False
    assert_engines_equivalent(eng, ref,
                              make_workload(eng.graph, 2, seed=seed + 7),
                              modes=("plane",))
    assert all(k[0] == eng._data_epoch for k in eng.cache.location)


# --------------------------------------------------------------------------- #
# GraphDelta semantics + guardrails
# --------------------------------------------------------------------------- #


def test_graph_delta_semantics():
    g = LabeledGraph.from_edges(4, [[0, 1], [1, 2], [2, 3]], [0, 1, 0, 1])
    new, info = apply_graph_delta(g, GraphDelta.make(
        add_vertex_labels=[1], add_edges=[[4, 0], [0, 1]],
        del_edges=[[2, 3], [0, 3]], del_vertices=[2]))
    # [0,1] existed (no-op add), [0,3] absent (no-op del); vertex 2
    # detaches (removes [1,2] implicitly, [2,3] was deleted anyway)
    assert new.n_vertices == 5
    assert info["n_added_edges"] == 1 and info["n_removed_edges"] == 2
    assert sorted(map(tuple, new.edge_list.tolist())) == [(0, 1), (0, 4)]
    assert new.degrees[2] == 0 and new.labels[2] == 0    # tombstone
    assert 2 in info["seeds"] and 4 in info["seeds"]


def test_graph_delta_validation():
    g = LabeledGraph.from_edges(3, [[0, 1], [1, 2]], [0, 1, 0])
    with pytest.raises(ValueError):
        apply_graph_delta(g, GraphDelta.make(add_edges=[[0, 7]]))
    with pytest.raises(ValueError):
        apply_graph_delta(g, GraphDelta.make(del_vertices=[9]))
    with pytest.raises(ValueError):
        apply_graph_delta(g, GraphDelta.make(del_vertices=[1],
                                             add_edges=[[0, 1]]))
    # an edge in BOTH lists would resolve state-dependently: reject
    # (either orientation — canonicalization runs first)
    with pytest.raises(ValueError):
        apply_graph_delta(g, GraphDelta.make(add_edges=[[0, 1]],
                                             del_edges=[[1, 0]]))
    with pytest.raises(ValueError):
        apply_graph_delta(g, GraphDelta.make(add_edges=[[0, 2]],
                                             del_edges=[[0, 2]]))


def test_empty_delta_is_noop():
    g, eng = _engine()
    epoch = eng._data_epoch
    tokens = dict(eng.planes.tokens())
    rep = eng.apply_updates(GraphDelta.make())
    assert rep.noop and eng._data_epoch == epoch
    assert eng.planes.tokens() == tokens


def test_effectively_empty_delta_keeps_caches():
    """Idempotent upserts (insert an existing edge, delete an absent
    one) change nothing: no epoch bump, no cache purge, no PE refit —
    a streaming-ingest upsert storm must not destroy the warm state."""
    g, eng = _engine()
    u, v = map(int, eng.graph.edge_list[0])
    epoch = eng._data_epoch
    graph_before = eng.graph
    tokens = dict(eng.planes.tokens())
    rep = eng.apply_updates(GraphDelta.make(add_edges=[[u, v]],
                                            del_edges=[[0, 0]]))
    assert rep.noop and rep.touched_shards == []
    assert eng._data_epoch == epoch and eng.graph is graph_before
    assert eng.planes.tokens() == tokens


def test_new_label_out_of_vocabulary_raises():
    g, eng = _engine()
    epoch = eng._data_epoch
    with pytest.raises(ValueError):
        eng.apply_updates(GraphDelta.make(
            add_vertex_labels=[eng.cfg.n_labels]))
    with pytest.raises(ValueError):
        eng.apply_updates(GraphDelta.make(add_vertex_labels=[-1]))
    # validation precedes mutation: nothing half-applied
    assert eng._data_epoch == epoch and eng.graph is g


def test_vertex_add_and_detach_exactness():
    from repro.data.synthetic import random_walk_query
    _, eng = _build(seed=9, n_comp=2, size=40)
    eng.use_cache = False
    n0 = eng.graph.n_vertices
    hub = int(np.argmax(eng.graph.degrees))
    eng.apply_updates(GraphDelta.make(
        add_vertex_labels=[0, 1],
        add_edges=[[n0, hub], [n0 + 1, n0], [n0 + 1, hub]],
        del_vertices=[int(eng.graph.edge_list[5][0])]))
    assert eng.graph.n_vertices == n0 + 2
    for s in range(3):
        q = random_walk_query(eng.graph, 3, seed=s)
        m, _ = eng.query(q)
        assert set(m) == vf2_oracle(eng.graph, q)
    # retirement is enforced ACROSS batches: a later delta may not
    # re-attach the detached id (same-batch rejection alone would let
    # an id-mix-up silently resurrect it)
    retired = next(iter(eng.retired_ids))
    epoch = eng._data_epoch
    with pytest.raises(ValueError):
        eng.apply_updates(GraphDelta.make(add_edges=[[retired, hub]]))
    assert eng._data_epoch == epoch, "rejected batch must not mutate"
