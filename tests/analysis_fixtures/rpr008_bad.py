"""RPR008 bad fixture: serving functions dereference the shard index
directly instead of resolving through the router."""


class Engine:
    def query(self, qe, sid):
        shard = self.shards[sid]
        return shard.index

    def _consume_query(self, it, sid):
        mk = self.routing[sid]
        return mk
