"""GOOD: the key flows from _query_key, which embeds _data_epoch."""


class Engine:
    def lookup(self, query):
        key = self._query_key(query)
        return self._result_cache.access(key)
