"""GOOD: the byte image crosses the link via crc_transfer."""


def install_shard(engine, link, image):
    tr = crc_transfer(link, image)
    shard = Shard.deserialize(tr.received)
    engine.adopt(shard)
