"""GOOD: the byte image crosses the link via crc_transfer."""


def install_shard(engine, link, image):
    # reprolint: disable=RPR009 -- this fixture exercises RPR003 only
    tr = crc_transfer(link, image)
    shard = Shard.deserialize(tr.received)
    engine.adopt(shard)
