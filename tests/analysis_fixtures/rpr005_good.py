"""GOOD: device values stay opaque until the consume half."""
import numpy as np


class Planes:
    def _mb_dispatch(self, batch):
        finals = megabatch_leaf_probe_jit(batch.qmat, batch.mask_bits)
        self.inflight.append((batch, finals))

    def _mb_consume(self):
        batch, finals = self.inflight.pop(0)
        return np.asarray(finals)
