"""GOOD: rows rounded to MASK_ROW_BUCKET before the jit boundary."""
import numpy as np

from repro.kernels.dominance.ops import (MASK_ROW_BUCKET, bucket,
                                         megabatch_leaf_probe_jit)


def launch(blocks, masks):
    rows = bucket(len(masks), MASK_ROW_BUCKET)
    mask_bits = np.zeros((rows, 8), np.uint32)
    return megabatch_leaf_probe_jit(blocks, mask_bits)
