"""BAD: result-cache access keyed by raw query identity (no epoch)."""


class Engine:
    def lookup(self, query):
        raw = (query.n_vertices, query.signature())
        return self._result_cache.access(raw)
