"""BAD: wall clock + global RNG feeding a rebalance decision."""
import time

import numpy as np


def epoch_tick(engine):
    engine.clock += time.time()
    probe = np.random.choice(engine.shard_ids)
    return probe
