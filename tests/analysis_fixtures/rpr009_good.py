"""RPR009 good fixture: every cross-machine byte flows through the
engine's transport; store mutations stay with the owner."""


class Engine:
    def apply_updates(self, blob, sid):
        tr = self.transport.transfer(blob, rng=self._rng, dst=0)
        return tr.received

    def resolve(self, sid, m):
        return self.transport.fetch_replica(sid, m)

    def promote_commit(self, sid, m, shard):
        # ownership mutations (assign/delete targets) are legal
        self.replicas.copies[sid][m] = shard
        del self.replicas.copies[sid][m]

    def enumerate_holders(self, sid):
        # non-subscript traversal of the store is bookkeeping, not a read
        return sorted(self.replicas.copies.get(sid, {}))
