"""RPR008 good fixture: the serving path resolves through the router;
build/failover code legitimately owns the index dictionaries."""


class Engine:
    def query(self, qe, sid):
        rt = self.router.resolve(sid)
        return rt.shard.index

    def _consume_query(self, it, sid, budget, tel):
        rt = self.router.read(sid, budget, tel)
        return rt.machine

    def build(self, shard):
        # Store context: installing a shard is not a serving read
        self.shards[shard.sid] = shard
        self.routing[shard.sid] = 0

    def handle_machine_failure(self, sid):
        # failover owns the index — reads here are fine
        return self.shards[sid], self.routing[sid]
