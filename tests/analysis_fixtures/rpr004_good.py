"""GOOD: virtual clock, seeded RNG, and a suppressed wall diagnostic."""
import time

import numpy as np

EPOCH_VIRTUAL_S = 0.05


def epoch_tick(engine):
    engine.clock += EPOCH_VIRTUAL_S
    rng = np.random.default_rng(engine.seed)
    probe = rng.choice(engine.shard_ids)
    # reprolint: disable=RPR004 -- wall diagnostic, never asserted
    engine.telemetry["tick_walltime"] = time.time()
    return probe
