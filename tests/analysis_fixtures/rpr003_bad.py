"""BAD: decodes a byte image that never went through crc_transfer."""


def install_shard(engine, sock):
    blob = sock.recv_bytes()
    shard = Shard.deserialize(blob)
    engine.adopt(shard)
