"""RPR007 good fixture: hook handlers draw only from the plan rng;
fire-free functions may use the threaded engine rng."""


def crash_from_plan_rng(chaos, machines):
    for _f in chaos.fire("cluster.query"):
        victim = machines[int(chaos.rng.integers(len(machines)))]
        machines.remove(victim)


class Engine:
    def fire_hook(self, hook):
        for _f in self.chaos.fire(hook):
            m = int(self.chaos.rng.integers(len(self.live)))
            self.live.remove(m)


def corrupt_prob_simulation(blob, rng, corrupt_prob):
    # no hook fires here: the ENGINE rng is exactly right for the
    # reproducible corruption simulation
    if corrupt_prob > 0.0 and rng.random() < corrupt_prob:
        bad = bytearray(blob)
        bad[int(rng.integers(len(bad)))] ^= 0xFF
        blob = bytes(bad)
    return blob
