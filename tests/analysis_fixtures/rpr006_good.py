"""GOOD: bucket divides into blocks, -inf pad, uint32 mask words."""
import numpy as np

BLOCK_R = 128
ROWS_BUCKET = 256

KERNEL_CONTRACTS = {
    "probe_fixture": dict(
        caller_bucketed=dict(rows=0, mask_bits=1),
        blocks=dict(rows=BLOCK_R),
        buckets=dict(rows=ROWS_BUCKET),
        pads=dict(rows="-inf"),
        dtypes=dict(mask_bits="uint32")),
}


def launch():
    rows = np.full((ROWS_BUCKET, 4), -np.inf)
    mask_bits = np.zeros((ROWS_BUCKET, 4), np.uint32)
    return probe_fixture(rows, mask_bits)
