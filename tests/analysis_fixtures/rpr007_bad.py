"""RPR007 bad fixture: chaos hook handlers drawing the wrong rng."""
import numpy as np


def crash_from_engine_rng(chaos, rng, machines):
    for _f in chaos.fire("cluster.query"):
        victim = machines[int(rng.integers(len(machines)))]
        machines.remove(victim)


def tear_with_fresh_generator(plan, blob):
    fresh = np.random.default_rng(0)
    for _f in plan.fire("migration.transfer"):
        blob = blob[:int(fresh.integers(1, len(blob)))]
    return blob
