"""RPR009 bad fixture: engine code moves cross-machine bytes around the
transport seam — direct link-primitive calls and replica-store reads."""

from repro.dist.migration import crc_transfer


class Engine:
    def apply_updates(self, blob, sid):
        tr = crc_transfer(blob, rng=self._rng)
        return tr.received

    def _sync(self, blob, chaos):
        received, slow = _link_faults(chaos, blob)
        return received

    def resolve(self, sid, m):
        return self.replicas.copies[sid][m]

    def hedge(self, sid, m):
        shard = self._e.replicas.copies[sid][m]
        return shard
