"""BAD: forces an in-flight device value inside the dispatch region."""


class Planes:
    def _mb_dispatch(self, batch):
        finals = megabatch_leaf_probe_jit(batch.qmat, batch.mask_bits)
        hits = int(finals[0])
        self.inflight.append((batch, finals, hits))
