"""BAD: mask rows track the raw batch size -> retrace per batch mix."""
import numpy as np

from repro.kernels.dominance.ops import megabatch_leaf_probe_jit


def launch(blocks, masks):
    mask_bits = np.zeros((len(masks), 8), np.uint32)
    return megabatch_leaf_probe_jit(blocks, mask_bits)
