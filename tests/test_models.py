"""Per-arch smoke tests (required): reduced config, one forward/train step
on CPU, asserting output shapes + finite values.  Plus model-level
correctness: decode==prefill, MoE vs dense reference, equivariance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_spec

LM_ARCHS = ["yi-6b", "h2o-danube-1.8b", "glm4-9b", "qwen2-moe-a2.7b",
            "deepseek-v3-671b"]
GNN_ARCHS = ["egnn", "gatedgcn", "nequip", "meshgraphnet"]


# --------------------------------------------------------------------------- #
# LM smoke
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models.transformer import init_params, lm_loss, prefill
    spec = get_spec(arch)
    cfg = spec.make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    logits = prefill(params, cfg, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN in forward"
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, toks, toks)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_step(arch):
    from repro.models.transformer import (decode_step, init_cache,
                                          init_params)
    spec = get_spec(arch)
    cfg = spec.make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    cache = init_cache(cfg, 2, 8)
    tok = jax.random.randint(key, (2, 1), 0, cfg.vocab)
    for i in range(3):
        logits, cache = decode_step(params, cfg, cache, tok, jnp.int32(i))
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())


def test_decode_matches_prefill_all_lm_archs():
    from repro.models.transformer import (decode_step, init_cache,
                                          init_params, prefill)
    for arch in LM_ARCHS:
        cfg = get_spec(arch).make_smoke_config()
        if cfg.sliding_window is not None:
            cfg = dataclasses.replace(cfg, sliding_window=None)
        key = jax.random.PRNGKey(1)
        params = init_params(cfg, key)
        toks = jax.random.randint(key, (2, 12), 0, cfg.vocab)
        full = prefill(params, cfg, toks)
        cache = init_cache(cfg, 2, 12)
        errs = []
        for i in range(12):
            lg, cache = decode_step(params, cfg, cache, toks[:, i:i + 1],
                                    jnp.int32(i))
            errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
        assert max(errs) < 5e-4, f"{arch}: decode diverges from prefill"


# --------------------------------------------------------------------------- #
# MoE dispatch vs per-token dense reference
# --------------------------------------------------------------------------- #
def _moe_dense_ref(x, params, cfg):
    """Direct per-token loop reference (no capacity drops)."""
    from repro.models.moe import _route
    b, s, d = x.shape
    out = np.zeros((b, s, d), np.float32)
    for bi in range(b):
        w, idx, _ = _route(x[bi].astype(jnp.float32), params, cfg)
        w, idx = np.asarray(w), np.asarray(idx)
        for t in range(s):
            for j in range(cfg.top_k):
                e = int(idx[t, j])
                h_g = jax.nn.silu(x[bi, t] @ params["w_gate"][e])
                h_u = x[bi, t] @ params["w_up"][e]
                y = (h_g * h_u) @ params["w_down"][e]
                out[bi, t] += w[t, j] * np.asarray(y)
    if cfg.n_shared:
        g = jax.nn.silu(x @ params["shared_gate"])
        u = x @ params["shared_up"]
        out += np.asarray((g * u) @ params["shared_down"])
    return out


def test_moe_matches_dense_reference():
    from repro.models.moe import MoEConfig, init_moe_params, moe_ffn
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, n_shared=1,
                    capacity_factor=8.0)     # big capacity: no drops
    key = jax.random.PRNGKey(0)
    params = init_moe_params(key, 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32), jnp.float32)
    got, aux = moe_ffn(x, params, cfg)
    want = _moe_dense_ref(x, params, cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_bounded():
    """With cf=1.0, dropped tokens only reduce magnitude, never corrupt."""
    from repro.models.moe import MoEConfig, init_moe_params, moe_ffn
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, capacity_factor=1.0)
    params = init_moe_params(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 16), jnp.float32)
    y, _ = moe_ffn(x, params, cfg)
    assert bool(jnp.isfinite(y).all())


# --------------------------------------------------------------------------- #
# GNN smoke + equivariance
# --------------------------------------------------------------------------- #
def _batch(n=24, e=60, f=8, seed=0):
    from repro.models.gnn_zoo import GNNBatch
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    e = src.size
    return GNNBatch(
        nodes=jnp.asarray(rng.normal(size=(n, f)), jnp.float32),
        positions=jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
        edge_src=jnp.asarray(src, jnp.int32),
        edge_dst=jnp.asarray(dst, jnp.int32),
        edge_feats=jnp.zeros((e, 0), jnp.float32),
        node_mask=jnp.ones(n, bool), edge_mask=jnp.ones(e, bool),
        graph_ids=jnp.zeros(n, jnp.int32))


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    from repro.models.gnn_zoo import gnn_loss, init_gnn
    spec = get_spec(arch)
    cfg = dataclasses.replace(spec.make_smoke_config(), d_in=8, d_out=3)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    batch = _batch()
    tgt = jnp.zeros((24, 3))
    loss, grads = jax.value_and_grad(gnn_loss)(params, cfg, batch, tgt)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ["egnn", "nequip"])
def test_equivariance_scalar_invariance(arch):
    from scipy.spatial.transform import Rotation

    from repro.models.gnn_zoo import apply_gnn, init_gnn
    spec = get_spec(arch)
    cfg = dataclasses.replace(spec.make_smoke_config(), d_in=8, d_out=3)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    batch = _batch(seed=4)
    r = jnp.asarray(Rotation.from_euler("xyz", [0.4, -0.9, 1.7]).as_matrix(),
                    jnp.float32)
    out1, pos1 = apply_gnn(params, cfg, batch)
    b2 = dataclasses.replace(batch, positions=batch.positions @ r.T)
    out2, pos2 = apply_gnn(params, cfg, b2)
    rel = float(jnp.abs(out1 - out2).max() / (jnp.abs(out1).max() + 1e-9))
    assert rel < 2e-4, f"{arch} not rotation-invariant: {rel}"
    if arch == "egnn":
        err = float(jnp.abs(pos1 @ r.T - pos2).max())
        assert err < 1e-4, "EGNN coordinates not equivariant"


def test_neighbor_sampler_shapes(nws_small):
    from repro.data.loaders import NeighborSampler
    s = NeighborSampler(nws_small.indptr, nws_small.indices,
                        fanouts=(5, 3), seed=0)
    seeds = np.arange(16)
    nodes, src, dst, nv, ev = s.sample(seeds)
    n_pad, e_pad = s.padded_sizes(16)
    assert nodes.shape == (n_pad,) and src.shape == (e_pad,)
    assert 0 < ev <= e_pad and 16 <= nv <= n_pad
    # all sampled edges reference in-range local node positions
    assert src[:ev].max() < nv and dst[:ev].max() < nv


# --------------------------------------------------------------------------- #
# recsys smoke
# --------------------------------------------------------------------------- #
def test_bert4rec_smoke_and_bulk_topk():
    from repro.models.bert4rec import (bulk_topk_scores, init_bert4rec,
                                       sampled_cloze_loss, serve_scores)
    spec = get_spec("bert4rec")
    cfg = spec.make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = init_bert4rec(cfg, key)
    items = jax.random.randint(key, (4, cfg.seq_len), 1, cfg.n_items)
    mask_pos = jnp.tile(jnp.arange(4)[None], (4, 1)).astype(jnp.int32)
    labels = jnp.take_along_axis(items, mask_pos, axis=1)
    negs = jax.random.randint(key, (32,), 1, cfg.n_items)
    loss = sampled_cloze_loss(params, cfg, items, mask_pos, labels, negs)
    assert bool(jnp.isfinite(loss))
    # bulk top-k agrees with full serve argsort
    full = serve_scores(params, cfg, items)
    bv, bi = bulk_topk_scores(params, cfg, items, k=10, chunk=100)
    want = jnp.argsort(-full, axis=1)[:, :10]
    got_scores = jnp.take_along_axis(full, bi, axis=1)
    want_scores = jnp.take_along_axis(full, want, axis=1)
    np.testing.assert_allclose(np.asarray(got_scores),
                               np.asarray(want_scores), rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------- #
# cell-builder sanity: every (arch x shape) builds abstract args + specs
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cells_build_for_all_shapes(arch):
    spec = get_spec(arch)
    for sid, shape in spec.shapes.items():
        if sid in spec.skip_shapes:
            continue
        cfg = spec.make_config()
        cell = spec.build_cell(cfg, shape, ("data",))
        assert cell.abstract_args, f"{arch}/{sid}: no inputs"
        out = jax.eval_shape(cell.step_fn, *cell.abstract_args)
        assert out is not None
