"""Device-resident probe planes: resident slabs + whole-plan fused descent.

Three contracts under test (ISSUE 3):

  * bit-identity — a plane probe returns the same candidates (value AND
    order) and the same nodes/leaves counters as the host traversal, for
    every (shard, length, path-orientation) pair of a plan, in ONE
    launch;
  * staleness — a cached plane must never serve a probe after the shard
    index changed (migration, failover, direct mutation);
  * retrace bounds — probing workloads with varying shard counts and
    path lengths compiles at most one descent kernel per
    (shard-bucket, row-bucket) shape pair.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.artree import build_artree, query_dominating
from repro.core.probeplane import ClusterPlanes, build_tree_plane
from repro.kernels.dominance.ops import (DEPTH_BUCKET, QUERY_BUCKET,
                                         ROW_BUCKET, SHARD_BUCKET, bucket)

# --------------------------------------------------------------------------- #
# plane layer: whole-plan fused descent == host short-circuit traversal
# --------------------------------------------------------------------------- #


def _random_cluster(rng, n_shards, dims):
    """{(sid, length): tree} over `dims` = {length: D}, sizes incl. 1."""
    trees = {}
    for sid in range(n_shards):
        for length, d in dims.items():
            n = int(rng.integers(1, 200))
            pts = rng.uniform(0, 1, (n, d)).astype(np.float32)
            trees[(sid, length)] = build_artree(pts)
    return trees


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 999), s=st.integers(1, 5),
       n_q=st.integers(1, 6))
def test_plan_probe_matches_host(seed, s, n_q):
    rng = np.random.default_rng(seed)
    dims = {1: 6, 2: 9}
    trees = _random_cluster(rng, s, dims)
    planes = ClusterPlanes()
    entries = [(sid, l, t) for (sid, l), t in trees.items()]
    queries = [(rng.uniform(0, 1, dims[l]).astype(np.float32), l)
               for l in dims for _ in range(n_q)]
    res = planes.probe(entries, queries, use_pallas=False)
    for (sid, l), tree in trees.items():
        for qi, (emb, ql) in enumerate(queries):
            if ql != l:
                continue
            want, want_stats = query_dominating(tree, emb)
            np.testing.assert_array_equal(res.hits(sid, l, qi), want)
            assert res.counters(sid, l, qi) == want_stats, \
                "plane counters must mirror the host traversal exactly"


def test_plan_probe_single_point_and_readback_contract():
    """1-point trees (no internal levels) + the id-only readback: the
    shipped arrays are counts/ids/counters, never a dense R-wide mask."""
    t1 = build_artree(np.array([[0.5, 0.5]], np.float32))
    t2 = build_artree(np.random.default_rng(0).uniform(
        0, 1, (300, 2)).astype(np.float32))
    planes = ClusterPlanes()
    res = planes.probe([(0, 1, t1), (1, 1, t2)],
                       [(np.array([0.2, 0.2], np.float32), 1),
                        (np.array([0.9, 0.9], np.float32), 1)],
                       use_pallas=False)
    np.testing.assert_array_equal(res.hits(0, 1, 0), [0])
    np.testing.assert_array_equal(res.hits(0, 1, 1), np.zeros(0, np.int64))
    assert res.counters(0, 1, 0) == {"nodes_visited": 0, "nodes_pruned": 0,
                                     "leaves_tested": 1}
    # readback contract: id slice width == the largest candidate count,
    # not the bucketed row axis
    assert res.cand_rows.shape[2] == int(res.counts.max())
    assert res.cand_rows.shape[2] < ROW_BUCKET
    s_b, r_b = res.assembled.slab.shape[0], res.assembled.slab.shape[1]
    dense_mask_bytes = s_b * res.counts.shape[1] * r_b  # PR-2 readback
    assert res.d2h_bytes < dense_mask_bytes


def test_warm_plane_moves_no_slab_bytes():
    """Second probe of the same plan: cached planes + cached assembly,
    so h2d is the query rows only (orders of magnitude below the slab)."""
    rng = np.random.default_rng(3)
    trees = _random_cluster(rng, 4, {1: 6, 2: 9})
    planes = ClusterPlanes()
    entries = [(sid, l, t) for (sid, l), t in trees.items()]
    queries = [(rng.uniform(0, 1, 6).astype(np.float32), 1),
               (rng.uniform(0, 1, 9).astype(np.float32), 2)]
    planes.probe(entries, queries, use_pallas=False)
    cold = dict(planes.stats)
    res = planes.probe(entries, queries, use_pallas=False)
    assert planes.stats["assemble_reuses"] == cold["assemble_reuses"] + 1
    assert planes.stats["plane_builds"] == cold["plane_builds"]
    warm_h2d = planes.stats["h2d_bytes"] - cold["h2d_bytes"]
    assert warm_h2d == res.h2d_bytes            # queries + pair mask only
    slab_bytes = int(res.assembled.slab.size) * 4
    assert warm_h2d < slab_bytes / 10


# --------------------------------------------------------------------------- #
# staleness: a stale cached plane must never serve a probe
# --------------------------------------------------------------------------- #


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 999))
def test_stale_plane_never_serves(seed):
    """Replace one shard's tree behind the cache's back: the next probe
    must match a FRESH host probe of the new tree, not the old plane."""
    rng = np.random.default_rng(seed)
    d = 6
    trees = {sid: build_artree(rng.uniform(0, 1, (int(rng.integers(1, 150)), d)
                                           ).astype(np.float32))
             for sid in range(3)}
    planes = ClusterPlanes()
    q = rng.uniform(0, 1, d).astype(np.float32)
    entries = [(sid, 1, t) for sid, t in trees.items()]
    planes.probe(entries, [(q, 1)], use_pallas=False)
    # mutate shard 1's index (new tree object, like a migration rebuild)
    trees[1] = build_artree(rng.uniform(0, 1, (77, d)).astype(np.float32))
    entries = [(sid, 1, t) for sid, t in trees.items()]
    res = planes.probe(entries, [(q, 1)], use_pallas=False)
    assert planes.stats["invalidations"] >= 1
    for sid, t in trees.items():
        want, _ = query_dominating(t, q)
        np.testing.assert_array_equal(res.hits(sid, 1, 0), want)


def test_engine_invalidation_after_migration_and_failure():
    """After hot migration, rebalance-driven moves and machine failure,
    plane-mode candidates must equal a fresh host probe (engine level)."""
    from repro.data.synthetic import make_workload, random_walk_query
    from repro.dist.migration import hot_migrate
    from tests.test_device_probe import _engine

    g, eng = _engine()
    inval0 = eng.planes.stats["invalidations"]

    # 1. direct hot_migrate (bypasses the engine's invalidate call —
    #    the identity backstop must catch the swapped index)
    sid = next(iter(eng.shards))
    src = eng.routing[sid]
    tgt = next(k for k in range(len(eng.specs)) if k != src)
    hot_migrate(eng.shards, [(sid, src, tgt)], eng.routing,
                rng=np.random.default_rng(0))

    q = random_walk_query(g, 4, seed=123)
    m_host, _ = eng.query(q, probe_mode="host")
    m_plane, tel = eng.query(q, probe_mode="plane")
    assert m_host == m_plane
    assert tel.probe_launches <= 1

    # 2. engine-level failure handling invalidates victims eagerly
    victims = eng.handle_machine_failure(
        max(k for k in range(len(eng.specs)) if k not in eng.dead_machines))
    assert victims
    assert eng.planes.stats["invalidations"] > inval0
    for qs in make_workload(g, 2, seed=17):
        mh, _ = eng.query(qs, probe_mode="host")
        mp, _ = eng.query(qs, probe_mode="plane")
        assert mh == mp


# --------------------------------------------------------------------------- #
# retrace guard: one compile per (shard-bucket, row-bucket) pair
# --------------------------------------------------------------------------- #


def test_descent_compiles_once_per_bucket_pair():
    from repro.kernels.dominance.ops import fused_plan_descent_jit

    rng = np.random.default_rng(0)
    dims = {1: 6, 2: 9}

    def probe(n_shards, n_rows_max, n_queries):
        planes = ClusterPlanes()
        trees = {}
        for sid in range(n_shards):
            for l, d in dims.items():
                n = int(rng.integers(1, n_rows_max))
                trees[(sid, l)] = build_artree(
                    rng.uniform(0, 1, (n, d)).astype(np.float32))
        entries = [(sid, l, t) for (sid, l), t in trees.items()]
        queries = [(rng.uniform(0, 1, dims[1 + i % 2]).astype(np.float32),
                    1 + i % 2) for i in range(n_queries)]
        res = planes.probe(entries, queries, use_pallas=False)
        s_b = bucket(len(entries), SHARD_BUCKET)
        r_b = bucket(max(t.n_points for t in trees.values()), ROW_BUCKET)
        assert res.assembled.slab.shape[0] == s_b
        assert res.assembled.slab.shape[1] >= r_b

    # varying shard counts and plan sizes WITHIN one (S, R) bucket pair:
    # the first probe's compile must serve all of them
    probe(1, 180, 1)
    cache0 = fused_plan_descent_jit._cache_size()
    for n_shards, n_q in [(2, 3), (3, 5), (4, 2), (4, 8)]:
        probe(n_shards, 180, n_q)
    assert fused_plan_descent_jit._cache_size() == cache0, \
        "same (S-bucket, R-bucket) pair must not retrace"
    # crossing the row bucket compiles exactly one more kernel
    probe(2, 900, 3)
    assert fused_plan_descent_jit._cache_size() == cache0 + 1
    probe(3, 900, 5)                      # same new pair: still no retrace
    assert fused_plan_descent_jit._cache_size() == cache0 + 1
    # crossing the shard bucket compiles exactly one more kernel
    probe(9, 180, 3)
    assert fused_plan_descent_jit._cache_size() == cache0 + 2


def test_bucket_constants_are_kernel_aligned():
    """The named buckets replace the old inline 8/256 literals and must
    stay aligned to the 3-D kernel's block shape."""
    from repro.kernels.dominance.kernel import BLOCK_S_N, BLOCK_S_Q
    assert ROW_BUCKET % BLOCK_S_N == 0
    assert QUERY_BUCKET % BLOCK_S_Q == 0
    assert SHARD_BUCKET >= 1 and DEPTH_BUCKET >= 1
    assert bucket(0, ROW_BUCKET) == 0
    assert bucket(1, ROW_BUCKET) == ROW_BUCKET
    assert bucket(ROW_BUCKET, ROW_BUCKET) == ROW_BUCKET


def test_plane_parent_pointers():
    """Packed-parent layout: roots self-parented, level-k row j ->
    level-(k-1) row j//B, leaves -> last internal level."""
    tree = build_artree(np.random.default_rng(0).uniform(
        0, 1, (100, 4)).astype(np.float32), branching=4)
    plane = build_tree_plane(tree)
    sizes = [u.shape[0] for u in tree.uppers]
    offsets = np.cumsum([0] + sizes)
    assert plane.leaf_offset == offsets[-1]
    assert plane.is_root[:sizes[0]].all()
    for k in range(1, len(sizes)):
        for j in (0, sizes[k] - 1):
            assert plane.parent[offsets[k] + j] == offsets[k - 1] + j // 4
    for j in (0, 99):
        assert plane.parent[offsets[-1] + j] == offsets[-2] + j // 4
    # pad rows are inert: self-parented, no role
    pad = slice(plane.n_rows, None)
    np.testing.assert_array_equal(plane.parent[pad],
                                  np.arange(plane.n_rows,
                                            plane.parent.shape[0]))
    assert not plane.is_root[pad].any()
    assert not plane.internal[pad].any() and not plane.leaf[pad].any()


def test_plan_probe_cross_length_isolation():
    """Length-1 and length-2 planes share one launch; a query row must
    only ever hit planes of its own length (pair_valid gating)."""
    rng = np.random.default_rng(5)
    # a length-1 tree whose boxes dominate EVERYTHING a length-2 query
    # could ask for on the shared prefix dims
    t1 = build_artree(np.full((20, 4), 100.0, np.float32))
    t2 = build_artree(rng.uniform(0, 1, (50, 8)).astype(np.float32))
    planes = ClusterPlanes()
    q2 = rng.uniform(0, 1, 8).astype(np.float32)
    res = planes.probe([(0, 1, t1), (0, 2, t2)], [(q2, 2)],
                       use_pallas=False)
    want, _ = query_dominating(t2, q2)
    np.testing.assert_array_equal(res.hits(0, 2, 0), want)
    s1 = res.assembled.slot[(0, 1)]
    assert int(res.counts[s1, 0]) == 0, \
        "a length-2 query row must not produce hits on a length-1 plane"
    assert res.counters(0, 1, 0) == {"nodes_visited": 0, "nodes_pruned": 0,
                                     "leaves_tested": 0}, \
        "a gated pair was never probed and must report zero counters"
