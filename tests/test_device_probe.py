"""Device probe path: the batched [S, max_leaves, D] probe must be
bit-identical to the per-(path, shard) host path at every layer —
aR-tree descent, per-shard candidate scatter, and the full engine."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.artree import (batched_query_dominating, build_artree,
                               query_dominating)
from repro.core.matching import batched_path_candidates, path_candidates

# --------------------------------------------------------------------------- #
# aR-tree layer: batched descent == host short-circuit traversal
# --------------------------------------------------------------------------- #


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 999), s=st.integers(1, 6), d=st.integers(2, 10))
def test_batched_descent_matches_host(seed, s, d):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(0, 120, size=s)     # includes empty trees
    trees = [build_artree(rng.uniform(0, 1, (n, d)).astype(np.float32))
             for n in sizes]
    queries = rng.uniform(0, 1, (2, d)).astype(np.float32)
    hits, stats = batched_query_dominating(trees, queries)
    agg = {"nodes_visited": 0, "nodes_pruned": 0, "leaves_tested": 0}
    for t, h in zip(trees, hits):
        for qi in range(queries.shape[0]):
            want, st_host = query_dominating(t, queries[qi])
            np.testing.assert_array_equal(h[qi], want)
            for k in agg:
                agg[k] += st_host[k]
    assert {k: stats[k] for k in agg} == agg, \
        "batched stats must mirror the host counters exactly"


def test_batched_descent_single_point_tree():
    """n_levels == 0 edge: a 1-point tree has no internal levels."""
    pts = np.array([[0.5, 0.5]], np.float32)
    tree = build_artree(pts)
    queries = np.array([[0.2, 0.2], [0.9, 0.9]], np.float32)
    hits, _ = batched_query_dominating([tree], queries)
    np.testing.assert_array_equal(hits[0][0], [0])
    np.testing.assert_array_equal(hits[0][1], np.zeros(0, np.int64))


def test_batched_descent_all_empty():
    hits, stats = batched_query_dominating(
        [build_artree(np.zeros((0, 4), np.float32))],
        np.zeros((2, 4), np.float32))
    assert hits[0][0].size == 0 and hits[0][1].size == 0
    assert stats["device_launches"] == 0


# --------------------------------------------------------------------------- #
# matching layer: batched per-shard candidate scatter
# --------------------------------------------------------------------------- #


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), s=st.integers(1, 5))
def test_batched_path_candidates_matches_host(seed, s):
    from repro.core.embedding import EmbeddedPaths
    from repro.core.matching import ShardIndex
    rng = np.random.default_rng(seed)
    length, d_v = 2, 4
    indexes = []
    for _ in range(s):
        n = int(rng.integers(0, 60))
        emb = rng.uniform(0, 1, (n, (length + 1) * d_v)).astype(np.float32)
        verts = rng.integers(0, 50, (n, length + 1)).astype(np.int32)
        indexes.append(ShardIndex(
            embedded={length: EmbeddedPaths(vertices=verts, embeddings=emb,
                                            length=length)},
            trees={length: build_artree(emb)}))
    q_emb = rng.uniform(0, 1, (length + 1) * d_v).astype(np.float32)
    batched = batched_path_candidates(indexes, q_emb, length)
    for index, (verts, orient) in zip(indexes, batched):
        want_v, want_o = path_candidates(index, q_emb, length)
        np.testing.assert_array_equal(verts, want_v)
        np.testing.assert_array_equal(orient, want_o)


# --------------------------------------------------------------------------- #
# engine layer: device_probe=True is bit-identical to the host path
# --------------------------------------------------------------------------- #

_ENGINE = None


def _engine():
    """Module-lazy mini cluster (shared across the property examples)."""
    global _ENGINE
    if _ENGINE is None:
        from repro.data.synthetic import nws_graph
        from repro.dist.cluster import DistributedGNNPE
        g = nws_graph(250, 6, 0.1, 6, seed=1)
        eng = DistributedGNNPE.build(g, 3, shards_per_machine=3,
                                     gnn_train_steps=10, seed=1)
        eng.use_cache = False          # compare raw probe paths
        _ENGINE = (g, eng)
    return _ENGINE


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       plan=st.sampled_from(["pescore", "degree", "natural"]))
def test_device_probe_bit_identical(seed, plan):
    from repro.data.synthetic import random_walk_query
    g, eng = _engine()
    rng = np.random.default_rng(seed)
    q = random_walk_query(g, int(rng.integers(2, 6)), seed=seed)
    m_host, t_host = eng.query(q, plan_mode=plan, device_probe=False)
    m_dev, t_dev = eng.query(q, plan_mode=plan, device_probe=True)
    m_pln, t_pln = eng.query(q, plan_mode=plan, probe_mode="plane")
    assert m_host == m_dev == m_pln
    for t in (t_dev, t_pln):
        assert t_host.comm_bytes == t.comm_bytes
        assert t_host.cross_shard_rows == t.cross_shard_rows
        assert t_host.shards_skipped == t.shards_skipped
        assert t_host.paths_executed == t.paths_executed
        assert t_host.paths_skipped == t.paths_skipped
    # one batched launch per executed path (vs one host probe per
    # (path, shard)): the ROADMAP batching item's defining property
    assert t_dev.probe_launches <= t_dev.paths_executed
    assert t_host.probe_launches >= t_dev.probe_launches
    # resident planes go further: ONE fused launch per query PLAN, and
    # (warm) the slab never crosses the host boundary again — only the
    # query rows go up and candidate ids come back
    assert t_pln.probe_launches <= 1
    assert t_host.probe_h2d_bytes == 0
    if t_pln.probe_launches:
        assert 0 < t_pln.probe_h2d_bytes < t_dev.probe_h2d_bytes


def test_device_probe_matches_oracle():
    from repro.data.synthetic import make_workload
    from tests.conftest import vf2_oracle
    g, eng = _engine()
    for q in make_workload(g, 3, seed=7):
        matches, tel = eng.query(q, device_probe=True)
        assert tel.device_probe
        assert set(matches) == vf2_oracle(g, q)
        m_pln, t_pln = eng.query(q, probe_mode="plane")
        assert t_pln.probe_mode == "plane" and t_pln.device_probe
        assert set(m_pln) == vf2_oracle(g, q)
