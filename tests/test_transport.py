"""Transport seam + real multi-host mesh (ISSUE 10).

The contract under test: EVERY inter-machine byte flows through one
seam (``repro.dist.transport.Transport``), and the real-process
``MeshTransport`` backend is bit-identical to the ``SimTransport``
oracle — matches, per-query counters, and the per-channel logical wire
ledger, fault-free and under seeded chaos schedules alike.

Layers:

  * the seam itself — ``crc_transfer`` is now a shim over the default
    transport and preserves its full retry/backoff/timeout behaviour;
    the engine meters every channel (image/delta/rows/operands/
    readback) on its own transport instance;
  * loopback mesh — the in-process ``world=1`` MeshTransport round-trips
    delivered bytes through the local device and must stay bit-identical
    to sim in host, plane, and megabatch modes, including one seeded
    FaultPlan crash schedule with a typed Unavailable slot;
  * load-aware standby routing (satellite) — standby reads of a hot
    shard spread off the hottest live holder using the balancer's fused
    load metric, degrading to the legacy lowest-id order when no load
    telemetry exists;
  * real ranks — 2 and 4 OS processes bootstrapped over
    ``jax.distributed``; identity and megabatch scenarios replayed
    cross-process (skipped when the sandbox can't bootstrap ranks).
"""

import numpy as np
import pytest

from repro.dist.chaos import (CRASH, HOOK_TRANSFER, TIMEOUT, FaultPlan,
                              FaultSpec, TransferTimeoutError)
from repro.dist.cluster import DistributedGNNPE
from repro.dist.meshrun import (INIT_FAILED_EXIT, build_pair, launch,
                                run_scenario)
from repro.dist.migration import MAX_RETRIES, crc_transfer
from repro.dist.transport import (CH_IMAGE, CHANNELS, MeshTransport,
                                  SimTransport, make_transport,
                                  predicted_wire)

N_MACHINES = 3


@pytest.fixture(scope="module")
def graph():
    from repro.data.synthetic import nws_graph
    return nws_graph(80, 6, 0.1, 5, seed=0)


@pytest.fixture(scope="module")
def ref(graph):
    return DistributedGNNPE.build(graph, N_MACHINES, shards_per_machine=2,
                                  gnn_train_steps=4, seed=0)


def _engine(graph, ref, k=0, failover="promote", backend="sim",
            transport=None):
    return DistributedGNNPE.build(graph, N_MACHINES, shards_per_machine=2,
                                  gnn_train_steps=4, seed=0,
                                  assignment=ref.assignment,
                                  params=ref.params, replication=k,
                                  failover_mode=failover, backend=backend,
                                  transport=transport)


# ------------------------------------------------------------------------- #
# the seam: crc_transfer shim + per-channel metering
# ------------------------------------------------------------------------- #

def test_crc_transfer_shim_matches_direct_transport_transfer():
    """The legacy entrypoint and Transport.transfer draw the same rng
    stream and produce identical TransferResults under faults."""
    blob = bytes(range(256)) * 40
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_TRANSFER, at=1,
                                times=2)], seed=1)
    a = crc_transfer(blob, rng=np.random.default_rng(7),
                     corrupt_prob=0.3, chaos=plan.replay())
    b = SimTransport().transfer(blob, rng=np.random.default_rng(7),
                                corrupt_prob=0.3, chaos=plan.replay())
    assert a.received == b.received == blob
    assert a.retransmissions == b.retransmissions
    assert a.virtual_ms == b.virtual_ms


def test_crc_transfer_shim_preserves_typed_timeout():
    plan = FaultPlan([FaultSpec(kind=TIMEOUT, hook=HOOK_TRANSFER, at=1,
                                times=MAX_RETRIES + 1)], seed=0)
    with pytest.raises(TransferTimeoutError):
        crc_transfer(b"x" * 512, rng=np.random.default_rng(0),
                     chaos=plan.replay())


def test_transport_meters_every_channel(graph, ref):
    """One engine, one workload epoch: image bytes from replication
    sync, rows from cross-shard candidates, operands + readback from a
    fused megabatch — all on the engine's own transport ledger."""
    from repro.data.synthetic import make_workload
    eng = _engine(graph, ref, k=1)
    qs = make_workload(graph, n_queries=4, seed=3)
    for q in qs[:2]:
        eng.query(q, probe_mode="plane")
    eng.query_batch(qs[2:])
    wire = eng.transport.wire
    assert wire["image"] > 0, "replica full-sync must meter image bytes"
    assert wire["rows"] > 0, "cross-shard candidates must meter rows"
    assert wire["operands"] > 0 and wire["readback"] > 0, \
        "megabatch must meter operand broadcast + candidate readback"
    assert eng.transport.stats()["backend"] == "sim"
    assert set(wire) == set(CHANNELS)


def test_make_transport_backends():
    assert isinstance(make_transport("sim"), SimTransport)
    assert isinstance(make_transport("mesh"), MeshTransport)
    with pytest.raises(ValueError):
        make_transport("carrier-pigeon")


def test_engine_backend_mesh_loopback_matches_sim(graph, ref):
    """`build(backend="mesh")` with no coordinator = world-1 loopback:
    answers and the logical ledger equal sim; the physical meter sees
    the delivered image bytes (the loopback device round-trip)."""
    from repro.data.synthetic import make_workload
    sim = _engine(graph, ref, k=1)
    mesh = _engine(graph, ref, k=1, backend="mesh")
    assert mesh.transport.backend == "mesh"
    qs = make_workload(graph, n_queries=2, seed=3)
    for q in qs:
        a, ta = sim.query(q, probe_mode="host")
        b, tb = mesh.query(q, probe_mode="host")
        assert a == b
        assert ta.comm_bytes == tb.comm_bytes
    assert dict(sim.transport.wire) == dict(mesh.transport.wire)
    assert sim.transport.measured()[CH_IMAGE] == 0
    assert mesh.transport.measured()[CH_IMAGE] == \
        mesh.transport.wire[CH_IMAGE] > 0


# ------------------------------------------------------------------------- #
# cross-backend scenarios, in-process (world=1 loopback mesh)
# ------------------------------------------------------------------------- #

def test_scenario_identity_loopback():
    out = run_scenario("identity")
    assert out["identical"], out
    assert out["sim_wire"]["image"] > 0
    assert out["sim_wire"]["rows"] > 0


def test_scenario_megabatch_loopback():
    out = run_scenario("megabatch")
    assert out["identical"], out
    assert out["mesh_wire"]["operands"] > 0
    assert out["mesh_wire"]["readback"] > 0


def test_scenario_chaos_loopback_identical_typed_outcomes():
    """One seeded crash schedule replayed on both backends: every
    answer — including the typed Unavailable slot the double crash
    forces — must be identical."""
    out = run_scenario("chaos")
    assert out["identical"], out
    assert out["sim"]["fired"] > 0
    assert out["sim"]["fired"] == out["mesh"]["fired"]
    kinds = {a[0] for a in out["sim"]["answers"]}
    assert "unavailable" in kinds, \
        "the schedule must exercise a typed non-answer"
    assert out["sim"]["answers"] == out["mesh"]["answers"]


def test_predicted_wire_census_loopback(graph, ref):
    """predicted_wire over the sim ledger equals the loopback mesh's
    physical meter exactly (same process, no headers)."""
    from repro.data.synthetic import make_workload
    sim, mesh = build_pair(graph, MeshTransport())
    qs = make_workload(graph, n_queries=3, seed=5)
    for e in (sim, mesh):
        for q in qs:
            e.query(q, probe_mode="host")
    pred = predicted_wire(sim.transport, world=1)
    meas = mesh.transport.measured()
    assert pred[CH_IMAGE] == meas[CH_IMAGE] > 0


# ------------------------------------------------------------------------- #
# load-aware standby selection (satellite)
# ------------------------------------------------------------------------- #

def _standby_sid(eng, victim):
    """A victim-homed shard with >= 2 live standby holders."""
    for sid, mk in sorted(eng.routing.items()):
        if mk == victim and len(eng.router.holders(sid)) >= 2:
            return sid
    pytest.skip("no shard with 2 live holders on this placement")


def test_standby_selection_prefers_least_loaded_holder(graph, ref):
    """Regression: hot shards' standby reads used to pile onto the
    lowest-id live holder.  With load telemetry present, resolve()
    must route to the *coolest* holder; with none (all-zero loads),
    the legacy lowest-id order is preserved bit-for-bit."""
    eng = _engine(graph, ref, k=2, failover="route")
    eng.handle_machine_failure(1)
    sid = _standby_sid(eng, victim=1)
    legacy = eng.router.holders(sid)
    assert legacy == sorted(legacy), \
        "zero telemetry must degrade to lowest-id order"
    # heat every holder except the last: the coolest must now serve
    loads = np.zeros(N_MACHINES)
    for m in legacy[:-1]:
        loads[m] = 0.9
    loads[legacy[-1]] = 0.1
    eng._last_loads = loads
    assert eng.router.holders(sid)[0] == legacy[-1]
    rt = eng.router.resolve(sid)
    assert rt.degraded and rt.machine == legacy[-1]
    # flip the heat: the other holder takes over, deterministically
    eng._last_loads = 1.0 - loads
    assert eng.router.resolve(sid).machine == legacy[0]
    # served bytes come through the seam and stay CRC-identical
    from repro.dist.shard import shard_crc32
    assert shard_crc32(rt.shard.serialize()) == \
        shard_crc32(eng.shards[sid].serialize())


# ------------------------------------------------------------------------- #
# real process ranks (skipped when the sandbox can't bootstrap)
# ------------------------------------------------------------------------- #

def _launch_or_skip(world, scenario):
    out = launch(world, scenario, timeout_s=560.0)
    if out.get("init_failed"):
        pytest.skip(f"jax.distributed ranks unavailable "
                    f"(exit {INIT_FAILED_EXIT})")
    assert out["ok"], out.get("detail", out)
    return out["result"]


@pytest.mark.slow
def test_mesh_2rank_identity():
    res = _launch_or_skip(2, "identity")
    assert res["world"] == 2
    assert res["identical"], res


@pytest.mark.slow
def test_mesh_2rank_megabatch():
    res = _launch_or_skip(2, "megabatch")
    assert res["identical"], res


@pytest.mark.slow
def test_mesh_4rank_identity():
    res = _launch_or_skip(4, "identity")
    assert res["world"] == 4
    assert res["identical"], res
