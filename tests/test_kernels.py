"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.dominance import ops as dom_ops
from repro.kernels.dominance.kernel import (dominance_pallas,
                                            dominance_pallas_3d)
from repro.kernels.dominance.ops import (KERNEL_CONTRACTS,
                                         batched_dominance_mask)
from repro.kernels.dominance.ref import (dominance_mask_3d_ref,
                                         dominance_mask_ref)
from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import flash_attention_ref
from repro.kernels.segment.kernel import csr_gather_sum_pallas
from repro.kernels.segment.ref import csr_gather_sum_ref


# --------------------------------------------------------------------------- #
# dominance
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("q,n,d", [(1, 1, 2), (7, 300, 12), (128, 256, 8),
                                   (200, 1000, 24), (130, 513, 6)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dominance_sweep(q, n, d, dtype):
    rng = np.random.default_rng(q * 1000 + n)
    qq = jnp.asarray(rng.uniform(0, 2, (q, d)), dtype)
    bb = jnp.asarray(rng.uniform(0, 2, (n, d)), dtype)
    got = dominance_pallas(qq, bb, interpret=True)
    want = dominance_mask_ref(qq, bb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(q=st.integers(1, 64), n=st.integers(1, 300), d=st.integers(1, 16),
       seed=st.integers(0, 99))
def test_dominance_property(q, n, d, seed):
    rng = np.random.default_rng(seed)
    qq = jnp.asarray(rng.uniform(0, 1, (q, d)), jnp.float32)
    bb = jnp.asarray(rng.uniform(0, 1, (n, d)), jnp.float32)
    got = np.asarray(dominance_pallas(qq, bb, interpret=True))
    want = np.asarray(dominance_mask_ref(qq, bb))
    assert (got == want).all()


# --------------------------------------------------------------------------- #
# batched (3-D) dominance: the device probe slab [S, max_leaves, D]
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("s,q,l,d", [
    (1, 1, 1, 2),       # one shard, one leaf
    (3, 2, 300, 12),    # leaves not a multiple of the lane block
    (5, 2, 256, 8),     # exactly one lane block
    (2, 9, 513, 6),     # queries past the sublane block, odd leaves
    (4, 2, 1, 4),       # one leaf per shard
])
def test_dominance_3d_sweep(s, q, l, d):
    rng = np.random.default_rng(s * 1000 + l)
    qq = jnp.asarray(rng.uniform(0, 2, (q, d)), jnp.float32)
    bb = jnp.asarray(rng.uniform(0, 2, (s, l, d)), jnp.float32)
    got = dominance_pallas_3d(qq, bb, interpret=True)
    want = dominance_mask_3d_ref(qq, bb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dominance_3d_counts_mask_padding():
    """Per-shard valid counts: rows at/past the count never survive, even
    when the padded slab holds dominating garbage there."""
    rng = np.random.default_rng(0)
    qq = jnp.asarray(rng.uniform(0, 1, (2, 6)), jnp.float32)
    bb = jnp.full((3, 40, 6), 10.0, jnp.float32)    # dominates everything
    counts = jnp.asarray([0, 1, 40], jnp.int32)
    got = np.asarray(batched_dominance_mask(qq, bb, counts,
                                            use_pallas=False))
    assert got[0].sum() == 0                        # zero-leaf shard
    assert (got[1, :, 1:] == 0).all() and (got[1, :, 0] == 1).all()
    assert (got[2] == 1).all()
    pall = np.asarray(batched_dominance_mask(qq, bb, counts,
                                             use_pallas=True))
    np.testing.assert_array_equal(got, pall)


def test_dominance_3d_degenerate_shapes():
    """0 shards and 0 leaves short-circuit to empty masks."""
    qq = jnp.zeros((2, 4), jnp.float32)
    assert batched_dominance_mask(qq, jnp.zeros((0, 8, 4))).shape \
        == (0, 2, 8)
    assert batched_dominance_mask(qq, jnp.zeros((3, 0, 4))).shape \
        == (3, 2, 0)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 5), q=st.integers(1, 9), l=st.integers(1, 300),
       d=st.integers(1, 16), seed=st.integers(0, 99))
def test_dominance_3d_property(s, q, l, d, seed):
    rng = np.random.default_rng(seed)
    qq = jnp.asarray(rng.uniform(0, 1, (q, d)), jnp.float32)
    bb = jnp.asarray(rng.uniform(0, 1, (s, l, d)), jnp.float32)
    got = np.asarray(dominance_pallas_3d(qq, bb, interpret=True))
    want = np.asarray(dominance_mask_3d_ref(qq, bb))
    assert (got == want).all()


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 4), q=st.integers(1, 6), r=st.integers(2, 40),
       seed=st.integers(0, 999))
def test_survivor_propagation_matches_chain_and(s, q, r, seed):
    """Parent-pointer propagation == brute-force ancestor-chain AND."""
    from repro.kernels.dominance.ref import survivor_propagation_ref
    rng = np.random.default_rng(seed)
    ok = rng.random((s, q, r)) < 0.7
    # random forests: row i's parent is a strictly smaller row (roots
    # self-parented), so chain depth <= r
    parent = np.array([[0] + [int(rng.integers(0, i)) for i in range(1, r)]
                       for _ in range(s)], np.int32)
    is_root = np.zeros((s, r), bool)
    is_root[:, 0] = True
    alive, anc = survivor_propagation_ref(
        jnp.asarray(ok), jnp.asarray(parent), jnp.asarray(is_root),
        n_iter=r)
    alive, anc = np.asarray(alive), np.asarray(anc)
    for si in range(s):
        for qi in range(q):
            for ri in range(r):
                chain, node = [], ri
                while True:
                    chain.append(node)
                    if node == parent[si, node]:
                        break
                    node = parent[si, node]
                assert alive[si, qi, ri] == all(ok[si, qi, c]
                                                for c in chain)
                assert anc[si, qi, ri] == all(ok[si, qi, c]
                                              for c in chain[1:])


# --------------------------------------------------------------------------- #
# declared kernel contracts: KERNEL_CONTRACTS as runtime assertions
# (the same table reprolint's RPR001/RPR006 parse statically)
# --------------------------------------------------------------------------- #
_FILL = {"+inf": np.inf, "-inf": -np.inf}


def test_contract_callees_exist():
    """Every declared boundary resolves to a real callable, so the table
    cannot silently rot as the API moves."""
    for callee in KERNEL_CONTRACTS:
        if callee == "mega_dispatch":
            from repro.core.probeplane import ClusterPlanes
            assert callable(ClusterPlanes.mega_dispatch)
        else:
            assert callable(getattr(dom_ops, callee)), callee


def test_contract_declarations_consistent():
    """Buckets are whole multiples of the kernel blocks they feed, and
    packed axes keep whole bytes/words per row (mirrors reprolint's
    RPR006 declaration check, but against the *imported* constants)."""
    for callee, spec in KERNEL_CONTRACTS.items():
        blocks, buckets = spec.get("blocks", {}), spec.get("buckets", {})
        for op in set(blocks) & set(buckets):
            assert buckets[op] % blocks[op] == 0, (callee, op)
        for op, mult in spec.get("packed_multiple", {}).items():
            if op in buckets:
                assert buckets[op] % mult == 0, (callee, op)


def test_declared_pads_are_inert_2d():
    """dominance_pallas: +inf pad queries match nothing, -inf pad boxes
    dominate nothing — the exact fills KERNEL_CONTRACTS declares."""
    spec = KERNEL_CONTRACTS["dominance_pallas"]
    rng = np.random.default_rng(11)
    q, n = 5, 10
    d = 6
    qq = rng.uniform(0, 1, (q, d)).astype(np.float32)
    bb = rng.uniform(0, 1, (n, d)).astype(np.float32)
    qp = np.full((8, d), _FILL[spec["pads"]["queries"]], np.float32)
    bp = np.full((16, d), _FILL[spec["pads"]["boxes"]], np.float32)
    qp[:q], bp[:n] = qq, bb
    got = np.asarray(dominance_pallas(jnp.asarray(qp), jnp.asarray(bp),
                                      interpret=True))
    want = np.asarray(dominance_mask_ref(jnp.asarray(qq),
                                         jnp.asarray(bb)))
    assert str(got.dtype) == spec["dtypes"]["out"]
    np.testing.assert_array_equal(got[:q, :n], want)
    assert got[q:, :].sum() == 0        # pad queries match nothing
    assert got[:, n:].sum() == 0        # pad boxes dominate nothing


def test_declared_pads_are_inert_3d():
    """dominance_pallas_3d padded to the declared buckets: the valid
    region is bit-identical to the unpadded oracle and every padded
    row/column is inert."""
    spec = KERNEL_CONTRACTS["dominance_pallas_3d"]
    # *_BUCKET-named locals: these ARE the declared buckets (reprolint's
    # RPR001 trusts the naming convention, as the engine code does)
    Q_BUCKET = spec["buckets"]["queries"]
    L_BUCKET = spec["buckets"]["boxes"]
    rng = np.random.default_rng(23)
    s = 2
    q, l = 3, 5
    d = 6
    qq = rng.uniform(0, 1, (q, d)).astype(np.float32)
    bb = rng.uniform(0, 1, (s, l, d)).astype(np.float32)
    qp = np.full((Q_BUCKET, d), _FILL[spec["pads"]["queries"]], np.float32)
    bp = np.full((s, L_BUCKET, d), _FILL[spec["pads"]["boxes"]], np.float32)
    qp[:q], bp[:, :l] = qq, bb
    got = np.asarray(dominance_pallas_3d(jnp.asarray(qp),
                                         jnp.asarray(bp),
                                         interpret=True))
    want = np.asarray(dominance_mask_3d_ref(jnp.asarray(qq),
                                            jnp.asarray(bb)))
    assert str(got.dtype) == spec["dtypes"]["out"]
    np.testing.assert_array_equal(got[:, :q, :l], want)
    assert got[:, q:, :].sum() == 0    # +inf pad queries match nothing
    assert got[:, :, l:].sum() == 0    # -inf pad boxes dominate nothing


# --------------------------------------------------------------------------- #
# segment / CSR gather-sum
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n,k,v,f", [(100, 8, 64, 16), (300, 16, 200, 32),
                                     (5, 3, 10, 4), (257, 5, 31, 20)])
def test_segment_sweep(n, k, v, f):
    rng = np.random.default_rng(n)
    nbr = jnp.asarray(rng.integers(-1, v, (n, k)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(v, f)), jnp.float32)
    got = csr_gather_sum_pallas(nbr, w, feats, interpret=True)
    want = csr_gather_sum_ref(nbr, w, feats)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_segment_matches_edge_segment_sum():
    """Padded-CSR form == jax.ops.segment_sum over the edge list."""
    rng = np.random.default_rng(0)
    n, v, f = 50, 50, 8
    e = 200
    src = rng.integers(0, v, e)
    dst = rng.integers(0, n, e)
    feats = rng.normal(size=(v, f)).astype(np.float32)
    want = jax.ops.segment_sum(jnp.asarray(feats)[src], jnp.asarray(dst),
                               num_segments=n)
    from repro.kernels.segment.ref import edges_to_padded_csr
    k_max = int(np.bincount(dst, minlength=n).max())
    nbr = edges_to_padded_csr(src, dst, n, k_max)
    got = csr_gather_sum_pallas(jnp.asarray(nbr),
                                jnp.ones((n, k_max), jnp.float32),
                                jnp.asarray(feats), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s,h,kv,d,win", [
    (2, 256, 4, 2, 64, None), (1, 130, 4, 4, 32, None),
    (2, 256, 8, 2, 64, 64), (1, 192, 2, 1, 128, 32)])
def test_flash_sweep_f32(b, s, h, kv, d, win):
    rng = np.random.default_rng(s)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=win,
                                 block_q=64, block_k=64, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, block_q=128, block_k=128,
                                 interpret=True).astype(jnp.float32)
    want = flash_attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2)


def test_flash_matches_blockwise_jnp():
    """Kernel == the model's blockwise (online-softmax) attention path."""
    from repro.models.common import blockwise_attention
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    got = flash_attention_pallas(q, k, v, scale=0.2, block_q=64, block_k=64,
                                 interpret=True)
    want = blockwise_attention(q, k, v, scale=0.2, block_kv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
